"""Random-waypoint mobility over a 2-D geometric graph.

Clients move in the square [0, area]² toward independently drawn waypoints at
a fixed per-round speed; whenever a client reaches its waypoint it draws a new
one uniformly.  The D2D graph at any round is the unit-disk (geometric) graph:
clients within ``radius`` of each other are neighbors.  Adjacencies are
emitted through ``topology._validate`` so the symmetric / zero-diagonal
invariants of the ColRel algebra hold by construction.
"""
from __future__ import annotations

import numpy as np

from repro.core import topology


def geometric_adjacency(positions: np.ndarray, radius: float) -> np.ndarray:
    """Unit-disk graph of ``positions`` (n, 2): edge iff pairwise dist ≤ radius."""
    pos = np.asarray(positions, dtype=np.float64)
    if pos.ndim != 2 or pos.shape[1] != 2:
        raise ValueError(f"positions must be (n, 2), got {pos.shape}")
    diff = pos[:, None, :] - pos[None, :, :]
    d2 = np.sum(diff * diff, axis=-1)
    adj = d2 <= float(radius) ** 2
    np.fill_diagonal(adj, False)
    return topology._validate(adj)


class RandomWaypointMobility:
    """n clients on random-waypoint trajectories; ``step()`` advances one
    round of motion and returns the new geometric adjacency."""

    def __init__(
        self,
        n: int,
        *,
        radius: float,
        speed: float = 0.05,
        area: float = 1.0,
        seed: int = 0,
    ):
        if radius <= 0 or speed < 0 or area <= 0:
            raise ValueError("radius/area must be positive, speed nonnegative")
        self.n = int(n)
        self.radius = float(radius)
        self.speed = float(speed)
        self.area = float(area)
        self._rng = np.random.default_rng(seed)
        self.positions = self._rng.random((self.n, 2)) * self.area
        self._waypoints = self._rng.random((self.n, 2)) * self.area

    def adjacency(self) -> np.ndarray:
        return geometric_adjacency(self.positions, self.radius)

    def step(self) -> np.ndarray:
        to_wp = self._waypoints - self.positions
        dist = np.linalg.norm(to_wp, axis=1)
        arrived = dist <= self.speed
        moving = ~arrived & (dist > 0)
        self.positions[arrived] = self._waypoints[arrived]
        self.positions[moving] += self.speed * to_wp[moving] / dist[moving, None]
        n_new = int(arrived.sum())
        if n_new:
            self._waypoints[arrived] = self._rng.random((n_new, 2)) * self.area
        return self.adjacency()
