"""ChannelSchedule: the per-round channel state stream.

A schedule yields one :class:`ChannelState` per federated round — the realized
D2D adjacency, the uplink probability vector, and an ``epoch_id`` that
increments exactly when ``(adj, p)`` changes value.  Epochs are what the
adaptive OPT-α scheduler keys on: within an epoch the cached relay matrix is
exact, across epochs it re-optimizes (warm-started).

The simulator and the distributed round step consume only *values* from the
state (A, p, τ are traced arguments of the compiled step), so iterating a
schedule never retraces jitted code — channel dynamics are a host-side
concern, exactly like the data loader.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology
from repro.channels.drift import StaticP
from repro.obs import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class ChannelState:
    """One round's channel: realized D2D graph + uplink marginals.

    ``active`` is the client-churn membership mask over the padded client
    dimension (``None`` ⇒ full membership, the pre-churn states).  It is part
    of the value identity: a membership change opens a new epoch, and the
    adaptive scheduler's cache keys on it — the optimal relay weights over a
    different active set are a different matrix.
    """

    round: int
    epoch_id: int
    adj: np.ndarray  # (n_max, n_max) bool, symmetric, zero diagonal
    p: np.ndarray  # (n_max,) float32 in [0, 1]
    active: np.ndarray | None = None  # (n_max,) bool, None ⇒ all live

    def key(self) -> tuple[bytes, bytes, bytes]:
        """Value-identity key (the adaptive scheduler's cache key).

        Memoized on the instance: ``adj.tobytes()`` on a 10⁴-node graph is a
        ~100 MB serialization, and the key is read at least twice per round
        (epoch bookkeeping in ``_emit`` plus every scheduler-policy lookup).
        ``_emit`` pre-installs the key built from its own cached component
        bytes, so steady-state rounds never re-serialize an unchanged
        adjacency at all.
        """
        cached = self.__dict__.get("_key_cache")
        if cached is None:
            cached = (
                self.adj.tobytes(),
                self.p.tobytes(),
                b"" if self.active is None else self.active.tobytes(),
            )
            object.__setattr__(self, "_key_cache", cached)
        return cached

    @property
    def n_active(self) -> int:
        return int(self.active.sum()) if self.active is not None else self.p.shape[0]


@dataclasses.dataclass(frozen=True)
class ChannelSegment:
    """A maximal run of consecutive rounds sharing one channel value.

    ``epoch_id`` increments exactly when ``(adj, p, active)`` changes, so
    grouping consecutive states by it yields segments within which the relay
    matrix, the uplink marginals and the membership mask are all constant —
    the unit of work the epoch-segmented scan engine
    (:class:`repro.fl.engine.EpochScanEngine`) fuses into one ``lax.scan``.
    """

    epoch_id: int
    start_round: int
    states: tuple[ChannelState, ...]

    @property
    def n_rounds(self) -> int:
        return len(self.states)

    @property
    def state(self) -> ChannelState:
        """The shared channel value (any round's state; they are equal up to
        the round counter) — what a scheduler policy solves on."""
        return self.states[0]

    @property
    def adj(self) -> np.ndarray:
        return self.states[0].adj

    @property
    def p(self) -> np.ndarray:
        return self.states[0].p

    @property
    def active(self) -> np.ndarray | None:
        return self.states[0].active


class ChannelSchedule:
    """Base class: subclasses implement ``next_round``; ``_emit`` canonicalizes
    dtypes and maintains the round counter and epoch bookkeeping."""

    def __init__(self):
        self._round = 0
        self._epoch = -1
        self._last_key = None
        # (source ref, read-only snapshot, serialized bytes) of the last
        # emitted adjacency — reused when the producer declares it unchanged,
        # so a static 10⁴-node graph costs one 100 MB copy + serialization
        # per run instead of one per round.
        self._adj_cache: tuple | None = None
        # Telemetry sink: segments() marks every epoch boundary with an
        # instant event.  Plain attribute (not a ctor param) so the bench
        # harness can attach a tracer to an already-built schedule.
        self.tracer = NULL_TRACER

    def _emit(
        self,
        adj: np.ndarray,
        p: np.ndarray,
        active: np.ndarray | None = None,
        *,
        adj_unchanged: bool = False,
    ) -> ChannelState:
        # Snapshot (copy) every array: ``segments()`` holds emitted states one
        # epoch past their round (it must see the *next* state to know a run
        # ended), and a jointly-sampled process that updates its buffers in
        # place would otherwise mutate the yielded segment's (adj, p, active)
        # under the consumer — ascontiguousarray alone aliases when dtype and
        # layout already match.
        #
        # ``adj_unchanged`` is the producer's promise that its adjacency
        # process did not step since the last emit; combined with an identity
        # check on the source array, the previous round's (read-only)
        # snapshot and bytes are reused — identity alone would be unsafe, the
        # shadowing processes mutate their buffers in place when they *do*
        # step.
        if (
            adj_unchanged
            and self._adj_cache is not None
            and adj is self._adj_cache[0]
        ):
            _, adj_snap, adj_bytes = self._adj_cache
        else:
            adj_snap = np.array(adj, dtype=bool, order="C", copy=True)
            adj_snap.setflags(write=False)
            adj_bytes = adj_snap.tobytes()
            self._adj_cache = (adj, adj_snap, adj_bytes)
        p = np.array(p, dtype=np.float32, order="C", copy=True)
        if adj_snap.shape[0] != p.shape[0]:
            raise ValueError(
                f"channel size mismatch: adj is {adj_snap.shape[0]}-node, "
                f"p has {p.shape[0]} entries"
            )
        if np.any(p < 0) or np.any(p > 1):
            raise ValueError("p left [0, 1]")
        if active is not None:
            active = np.array(active, dtype=bool, order="C", copy=True)
            if active.shape != p.shape:
                raise ValueError(
                    f"active mask has shape {active.shape}, expected {p.shape}"
                )
        key = (
            adj_bytes,
            p.tobytes(),
            b"" if active is None else active.tobytes(),
        )
        if key != self._last_key:
            self._epoch += 1
            self._last_key = key
        state = ChannelState(self._round, self._epoch, adj_snap, p, active)
        object.__setattr__(state, "_key_cache", key)
        self._round += 1
        return state

    def next_round(self) -> ChannelState:
        raise NotImplementedError

    def rounds(self, n_rounds: int):
        """Iterator over the next ``n_rounds`` channel states."""
        for _ in range(n_rounds):
            yield self.next_round()

    def segments(self, n_rounds: int):
        """Iterator over the next ``n_rounds`` rounds grouped into maximal
        constant-channel :class:`ChannelSegment` runs (consecutive states
        with the same ``epoch_id``).  Concatenating ``seg.states`` over the
        yielded segments reproduces ``rounds(n_rounds)`` exactly."""
        buf: list[ChannelState] = []
        for state in self.rounds(n_rounds):
            if buf and state.epoch_id != buf[0].epoch_id:
                if self.tracer.enabled:
                    self.tracer.instant(
                        "segment",
                        cat="schedule",
                        epoch=buf[0].epoch_id,
                        start_round=buf[0].round,
                        n_rounds=len(buf),
                    )
                yield ChannelSegment(buf[0].epoch_id, buf[0].round, tuple(buf))
                buf = []
            buf.append(state)
        if buf:
            if self.tracer.enabled:
                self.tracer.instant(
                    "segment",
                    cat="schedule",
                    epoch=buf[0].epoch_id,
                    start_round=buf[0].round,
                    n_rounds=len(buf),
                )
            yield ChannelSegment(buf[0].epoch_id, buf[0].round, tuple(buf))


class StaticChannel(ChannelSchedule):
    """The seed setting: one fixed (adj, p) — a single epoch forever."""

    def __init__(self, adj: np.ndarray, p: np.ndarray):
        super().__init__()
        self._adj = topology._validate(np.asarray(adj, dtype=bool).copy())
        self._p = np.asarray(p, dtype=np.float32)

    def next_round(self) -> ChannelState:
        return self._emit(self._adj, self._p, adj_unchanged=self._round > 0)


class TimeVaryingChannel(ChannelSchedule):
    """Composes a link-state process (Markov / mobility / shadowing) with a
    p-drift process.  Either side may be static: pass ``adj=...`` instead of
    ``link_process`` and/or a plain vector ``p=...`` instead of ``p_process``.

    ``adj_every`` / ``p_every`` throttle how often each process advances
    (e.g. topology churning every round while p re-estimates every 10).
    Round 0 uses the processes' initial states.
    """

    def __init__(
        self,
        *,
        link_process=None,
        adj: np.ndarray | None = None,
        p_process=None,
        p: np.ndarray | None = None,
        adj_every: int = 1,
        p_every: int = 1,
    ):
        super().__init__()
        if (link_process is None) == (adj is None):
            raise ValueError("pass exactly one of link_process / adj")
        if (p_process is None) == (p is None):
            raise ValueError("pass exactly one of p_process / p")
        if adj_every < 1 or p_every < 1:
            raise ValueError("adj_every / p_every must be >= 1")
        self._link = link_process
        self._pproc = StaticP(p) if p_process is None else p_process
        self._adj = (
            topology._validate(np.asarray(adj, dtype=bool).copy())
            if link_process is None
            else link_process.adjacency()
        )
        self._adj_every = int(adj_every)
        self._p_every = int(p_every)

    def _membership(self) -> np.ndarray | None:
        """Churn hook: the current active mask (None ⇒ fixed membership).
        Overridden by :class:`repro.channels.churn.ChurnSchedule`."""
        return None

    def next_round(self) -> ChannelState:
        r = self._round
        adj_stepped = False
        if r > 0:
            if self._link is not None and r % self._adj_every == 0:
                self._adj = self._link.step()
                adj_stepped = True
            if r % self._p_every == 0:
                self._pproc.step()
        return self._emit(
            self._adj,
            self._pproc.value(),
            self._membership(),
            adj_unchanged=r > 0 and not adj_stepped,
        )
