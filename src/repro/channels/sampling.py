"""Per-round cohort sampling over the padded client dimension.

Sampled-cohort federated rounds (the sampled-to-sampled regime of arXiv
2511.11560, and the participation model FedDec / arXiv 2306.06715 keeps D2D
relaying useful under): each round only a cohort of the eligible clients
trains and reports, so per-round cost scales with the cohort and the live
edge set, not with n_max.  :class:`CohortSampler` is a *membership process*
(the ``value()``/``step()`` protocol of ``repro.channels.churn``), so it
plugs straight into :class:`~repro.channels.churn.ChurnSchedule` — and it
optionally wraps another membership process as the eligibility base, making
the emitted mask

    active = membership ∧ sampled

with both factors stepping on the schedule's cadence.  Downstream nothing
changes: the cohort is just the round's ``active`` mask, a traced input of
the compiled step, so per-round cohorts never retrace.

Strategies
----------
  uniform    iid Bernoulli(rate) over the eligible members — the classic
             unbiased client-sampling model (random cohort size)
  fixed_k    uniform without replacement, exactly k of the members — fixed
             cohort size, inclusion probability k/m (unbiased, and the
             static-shape-friendly choice for benchmarking)
  expander   deterministic power-of-two strides over the padded ring (à la
             the exponential-offset collaborator schedules of gossip
             learning): round r takes k slots at stride 2^(r mod L) from a
             moving offset, cycling stride lengths so consecutive cohorts
             mix across the index space — reproducible, no RNG
"""
from __future__ import annotations

import numpy as np

STRATEGIES = ("uniform", "fixed_k", "expander")


class CohortSampler:
    """Membership process emitting ``base_membership ∧ sampled_cohort``.

    ``base`` is an optional inner membership process (StaticMembership /
    MarkovChurn / RotatingCohorts / another sampler); ``None`` means every
    padded slot is eligible.  ``resample_every`` redraws the cohort every
    that many steps (the base still steps every step); the default 1 is the
    per-round-cohort regime.  The sampled mask is never empty: if the draw
    misses every eligible member, one member is force-included.
    """

    def __init__(
        self,
        n_max: int,
        *,
        strategy: str = "uniform",
        k: int | None = None,
        rate: float | None = None,
        base=None,
        resample_every: int = 1,
        seed: int = 0,
    ):
        if strategy not in STRATEGIES:
            raise ValueError(
                f"unknown sampling strategy {strategy!r} (known: {STRATEGIES})"
            )
        if n_max < 1:
            raise ValueError("n_max must be >= 1")
        if resample_every < 1:
            raise ValueError("resample_every must be >= 1")
        if strategy == "uniform":
            if rate is None or not (0.0 < rate <= 1.0):
                raise ValueError("uniform sampling needs a rate in (0, 1]")
        else:
            if k is None or not (1 <= k <= n_max):
                raise ValueError(f"{strategy} sampling needs 1 <= k <= n_max")
        self.n_max = int(n_max)
        self.strategy = strategy
        self.k = None if k is None else int(k)
        self.rate = None if rate is None else float(rate)
        self._base = base
        self._resample_every = int(resample_every)
        self._rng = np.random.default_rng(seed)
        self._step_count = 0
        self._offset = 0
        # stride cycle length for the expander schedule: powers 2^0..2^(L-1)
        self._stride_cycle = max(1, int(np.floor(np.log2(max(2, n_max)))))
        self._a = self._compose(self._draw())

    def _members(self) -> np.ndarray:
        if self._base is None:
            return np.ones((self.n_max,), dtype=bool)
        m = np.asarray(self._base.value(), dtype=bool)
        if m.shape != (self.n_max,):
            raise ValueError(
                f"base membership shape {m.shape} != ({self.n_max},)"
            )
        return m

    def _draw(self) -> np.ndarray:
        """The sampled factor alone, over the padded index space."""
        sampled = np.zeros((self.n_max,), dtype=bool)
        if self.strategy == "uniform":
            sampled = self._rng.random(self.n_max) < self.rate
        elif self.strategy == "fixed_k":
            members = np.nonzero(self._members())[0]
            take = min(self.k, members.size)
            if take > 0:
                pick = self._rng.choice(members, size=take, replace=False)
                sampled[pick] = True
        else:  # expander: deterministic stride schedule, no RNG
            stride = 1 << (self._step_count % self._stride_cycle)
            idx = (self._offset + stride * np.arange(self.k)) % self.n_max
            sampled[np.unique(idx)] = True
            self._offset = (self._offset + self.k) % self.n_max
        return sampled

    def _compose(self, sampled: np.ndarray) -> np.ndarray:
        members = self._members()
        a = members & sampled
        if not a.any() and members.any():
            # keep the round non-degenerate: force one eligible member in
            pool = np.nonzero(members)[0]
            a = a.copy()
            a[self._rng.choice(pool)] = True
        return a

    def value(self) -> np.ndarray:
        return self._a

    def step(self) -> np.ndarray:
        if self._base is not None:
            self._base.step()
        self._step_count += 1
        if self._step_count % self._resample_every == 0:
            self._a = self._compose(self._draw())
        else:
            # base may have moved even between redraws: re-intersect
            self._a = self._compose(self._a_sampled_factor())
        return self._a

    def _a_sampled_factor(self) -> np.ndarray:
        # between redraws the sampled factor is whatever survived composition
        # plus nothing new; re-deriving it from the held mask keeps a slot
        # that left-and-rejoined the base out of the cohort until a redraw
        return self._a
