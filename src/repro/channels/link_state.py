"""Markov on/off (Gilbert–Elliott) processes for the D2D links.

Each undirected edge of a *base* topology carries an independent two-state
Markov chain: state 1 = link up, state 0 = link down (deep fade).  The chain
is parameterized by the transition probabilities

    q_ud = P[up → down]      q_du = P[down → up]

whose stationary up-probability is π = q_du / (q_ud + q_du).  Edges outside
the base topology never exist — the base graph is the radio-range envelope,
the chain models fading within it.  Every emitted adjacency is symmetric with
a zero diagonal (states live on the upper triangle and are mirrored).
"""
from __future__ import annotations

import numpy as np

from repro.core import topology


class MarkovLinkProcess:
    """Independent Gilbert–Elliott chains on the edges of ``base_adj``."""

    def __init__(
        self,
        base_adj: np.ndarray,
        *,
        p_up_to_down: float,
        p_down_to_up: float,
        init: str = "stationary",
        seed: int = 0,
    ):
        base = topology._validate(np.asarray(base_adj, dtype=bool).copy())
        if not (0.0 <= p_up_to_down <= 1.0 and 0.0 <= p_down_to_up <= 1.0):
            raise ValueError("transition probabilities must lie in [0, 1]")
        if p_up_to_down + p_down_to_up == 0.0:
            raise ValueError(
                "q_ud = q_du = 0 freezes every link; use a StaticChannel instead"
            )
        self.base = base
        self.n = base.shape[0]
        self.q_ud = float(p_up_to_down)
        self.q_du = float(p_down_to_up)
        self._edges = np.argwhere(np.triu(base, 1))  # (E, 2) upper-tri edges
        self._rng = np.random.default_rng(seed)
        if init == "stationary":
            self._up = self._rng.random(len(self._edges)) < self.stationary_up_prob
        elif init == "up":
            self._up = np.ones(len(self._edges), dtype=bool)
        elif init == "down":
            self._up = np.zeros(len(self._edges), dtype=bool)
        else:
            raise ValueError(f"unknown init {init!r}")

    @property
    def stationary_up_prob(self) -> float:
        """π = q_du / (q_ud + q_du) of the per-edge chain."""
        return self.q_du / (self.q_ud + self.q_du)

    def transition_matrix(self) -> np.ndarray:
        """Row-stochastic P over states (down, up): P[s, s'] = P[s → s']."""
        return np.array(
            [[1.0 - self.q_du, self.q_du], [self.q_ud, 1.0 - self.q_ud]],
            dtype=np.float64,
        )

    def adjacency(self) -> np.ndarray:
        """Current realized D2D graph (symmetric, zero diagonal)."""
        adj = np.zeros((self.n, self.n), dtype=bool)
        up = self._edges[self._up]
        adj[up[:, 0], up[:, 1]] = True
        adj = adj | adj.T
        return topology._validate(adj)

    def step(self) -> np.ndarray:
        """Advance every edge chain one round; returns the new adjacency."""
        u = self._rng.random(len(self._edges))
        flip_down = self._up & (u < self.q_ud)
        flip_up = (~self._up) & (u < self.q_du)
        self._up = (self._up & ~flip_down) | flip_up
        return self.adjacency()


def gilbert_elliott(
    base_adj: np.ndarray,
    *,
    stay_up: float,
    stay_down: float,
    init: str = "stationary",
    seed: int = 0,
) -> MarkovLinkProcess:
    """Gilbert–Elliott parameterization by self-transition (burstiness)
    probabilities: stay_up = P[up → up], stay_down = P[down → down]."""
    return MarkovLinkProcess(
        base_adj,
        p_up_to_down=1.0 - stay_up,
        p_down_to_up=1.0 - stay_down,
        init=init,
        seed=seed,
    )
