"""Relay-matrix scheduling over a time-varying channel.

``AdaptiveOptAlpha`` is the subsystem's hot-path policy: it re-runs OPT-α
only when the channel *value* actually changes (LRU cache keyed on the
channel bytes) and, on a miss, warm-starts the Gauss–Seidel solve from the
previous epoch's optimum projected onto the new support
(:func:`repro.core.opt_alpha.warm_start_weights`) — after a small
perturbation that converges in a few sweeps instead of from scratch.  The
joint OPT-α objective is convex, so warm- and cold-started solves reach the
same S(p, A) (tested).

``StaleOptAlpha`` is the ablation baseline: solve once on the first channel
and reuse that A forever.  Because a relay matrix is only physically
realizable on the *current* graph (a down link carries nothing), stale
matrices must be projected onto the live topology at use time —
:func:`project_to_support` — which is exactly where the staleness penalty
(lost mass ⇒ bias) comes from.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core import opt_alpha, topology
from repro.channels.schedule import ChannelState


def project_to_support(
    A: np.ndarray, adj: np.ndarray, active: np.ndarray | None = None
) -> np.ndarray:
    """Zero every relay weight that the current graph cannot carry
    (j ∉ N_i ∪ {i}).  Models using an outdated A on a changed topology.
    With a churn mask ``active``, weights touching a departed client are
    zeroed too (a slot that left the run carries nothing)."""
    m = topology.closed_mask(np.asarray(adj, dtype=bool).copy())
    if active is not None:
        a = np.asarray(active, dtype=bool)
        m = m & a[:, None] & a[None, :]
    return np.where(m, np.asarray(A, dtype=np.float64), 0.0)


@dataclasses.dataclass
class SchedulerStats:
    rounds: int = 0
    cache_hits: int = 0
    solves: int = 0
    warm_solves: int = 0
    sweeps_total: int = 0

    @property
    def mean_sweeps(self) -> float:
        return self.sweeps_total / self.solves if self.solves else 0.0


class AdaptiveOptAlpha:
    """Per-round relay matrices for a :class:`ChannelSchedule` stream."""

    def __init__(
        self,
        *,
        sweeps: int = 40,
        warm_sweeps: int | None = None,
        tol: float = 1e-10,
        cache_size: int = 64,
        warm_start: bool = True,
        method: str = "bisect",
    ):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.sweeps = sweeps
        self.warm_sweeps = sweeps if warm_sweeps is None else warm_sweeps
        self.tol = tol
        self.cache_size = cache_size
        self.warm_start = warm_start
        self.method = method
        self.stats = SchedulerStats()
        self._cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._last_A: np.ndarray | None = None

    def relay_matrix(self, state: ChannelState) -> np.ndarray:
        self.stats.rounds += 1
        key = state.key()
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.stats.cache_hits += 1
            self._last_A = hit
            return hit
        A0 = None
        sweeps = self.sweeps
        masked = state.active is not None and not state.active.all()
        if masked:
            # churn: the solve lives on the active block — restrict the
            # channel first so the warm start and optimum never put mass on
            # a departed client
            a = np.asarray(state.active, dtype=bool)
            p_eff = np.where(a, state.p.astype(np.float64), 0.0)
            adj_eff = state.adj & a[:, None] & a[None, :]
        else:
            p_eff, adj_eff = state.p, state.adj
        if self.warm_start and self._last_A is not None:
            A0 = opt_alpha.warm_start_weights(p_eff, adj_eff, self._last_A)
            sweeps = self.warm_sweeps
            self.stats.warm_solves += 1
        if masked:
            res = opt_alpha.optimize_masked(
                state.p,
                state.adj,
                state.active,
                sweeps=sweeps,
                tol=self.tol,
                A0=A0,
                method=self.method,
            )
        else:
            res = opt_alpha.optimize(
                state.p,
                state.adj,
                sweeps=sweeps,
                tol=self.tol,
                A0=A0,
                method=self.method,
            )
        self.stats.solves += 1
        self.stats.sweeps_total += res.sweeps
        # the cache and the warm-start seed alias the returned array; freeze
        # it so a caller mutating A cannot silently corrupt later epochs
        res.A.setflags(write=False)
        self._cache[key] = res.A
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        self._last_A = res.A
        return res.A


class StaleOptAlpha:
    """Solve OPT-α on the first channel only; every later round reuses that A
    projected onto the live topology (the channel-oblivious baseline)."""

    def __init__(
        self, *, sweeps: int = 40, tol: float = 1e-10, method: str = "bisect"
    ):
        self.sweeps = sweeps
        self.tol = tol
        self.method = method
        self._A: np.ndarray | None = None

    def relay_matrix(self, state: ChannelState) -> np.ndarray:
        if self._A is None:
            if state.active is not None and not state.active.all():
                self._A = opt_alpha.optimize_masked(
                    state.p,
                    state.adj,
                    state.active,
                    sweeps=self.sweeps,
                    tol=self.tol,
                    method=self.method,
                ).A
            else:
                self._A = opt_alpha.optimize(
                    state.p,
                    state.adj,
                    sweeps=self.sweeps,
                    tol=self.tol,
                    method=self.method,
                ).A
        return project_to_support(self._A, state.adj, state.active)
