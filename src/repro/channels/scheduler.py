"""Relay-matrix scheduling over a time-varying channel.

``AdaptiveOptAlpha`` is the subsystem's hot-path policy: it re-runs OPT-α
only when the channel *value* actually changes (LRU cache keyed on the
channel bytes) and, on a miss, warm-starts the Gauss–Seidel solve from the
previous epoch's optimum projected onto the new support
(:func:`repro.core.opt_alpha.warm_start_weights`) — after a small
perturbation that converges in a few sweeps instead of from scratch.  The
joint OPT-α objective is convex, so warm- and cold-started solves reach the
same S(p, A) (tested).

``SparseOptAlpha`` is the same policy on the neighborhood-blocked solver
(:func:`repro.core.opt_alpha.optimize_sparse`): it returns
:class:`~repro.core.relay.EdgeRelay` operands for the ``segment`` relay
backend and keeps every per-round cost and cache entry O(E) — the policy to
pair with per-round cohort sampling at n ≫ 10³.

``StaleOptAlpha`` is the ablation baseline: solve once on the first channel
and reuse that A forever.  Because a relay matrix is only physically
realizable on the *current* graph (a down link carries nothing), stale
matrices must be projected onto the live topology at use time —
:func:`project_to_support` — which is exactly where the staleness penalty
(lost mass ⇒ bias) comes from.

``SegmentPrefetcher`` is the host side of the pipelined execution path
(:class:`repro.fl.engine.PipelinedScanEngine`): it walks
``ChannelSchedule.segments()``, solves the relay matrix per segment and
stages per-chunk batch stacks, so that all host work for epoch k+1 (OPT-α
re-solve, batch stacking, segment sampling) overlaps the device's
in-flight chunk of epoch k instead of serializing with it.  Staging runs
inline behind JAX's async dispatch by default (no extra thread), or on a
background worker thread feeding a small bounded queue
(``threaded=True``).
"""
from __future__ import annotations

import dataclasses
import queue
import sys
import threading
import time
import weakref
from collections import OrderedDict
from typing import Any, Callable

import numpy as np

from repro.core import opt_alpha, topology
from repro.core import relay as relay_lib
from repro.channels.schedule import ChannelSegment, ChannelState
from repro.obs import NULL_TRACER


def project_to_support(
    A: np.ndarray, adj: np.ndarray, active: np.ndarray | None = None
) -> np.ndarray:
    """Zero every relay weight that the current graph cannot carry
    (j ∉ N_i ∪ {i}).  Models using an outdated A on a changed topology.
    With a churn mask ``active``, weights touching a departed client are
    zeroed too (a slot that left the run carries nothing)."""
    m = topology.closed_mask(np.asarray(adj, dtype=bool).copy())
    if active is not None:
        a = np.asarray(active, dtype=bool)
        m = m & a[:, None] & a[None, :]
    return np.where(m, np.asarray(A, dtype=np.float64), 0.0)


@dataclasses.dataclass
class SchedulerStats:
    """Per-policy counters.  ``rounds == cache_hits + cache_misses`` always
    (every ``relay_matrix`` call is exactly one or the other), and
    ``cache_misses == solves`` (a miss is what triggers a solve);
    ``evictions`` counts entries the LRU bound pushed out."""

    rounds: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    solves: int = 0
    warm_solves: int = 0
    sweeps_total: int = 0
    evictions: int = 0

    @property
    def mean_sweeps(self) -> float:
        return self.sweeps_total / self.solves if self.solves else 0.0


class AdaptiveOptAlpha:
    """Per-round relay matrices for a :class:`ChannelSchedule` stream."""

    def __init__(
        self,
        *,
        sweeps: int = 40,
        warm_sweeps: int | None = None,
        tol: float = 1e-10,
        cache_size: int = 64,
        warm_start: bool = True,
        method: str = "bisect",
        tracer=None,
    ):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.sweeps = sweeps
        self.warm_sweeps = sweeps if warm_sweeps is None else warm_sweeps
        self.tol = tol
        self.cache_size = cache_size
        self.warm_start = warm_start
        self.method = method
        self.stats = SchedulerStats()
        # telemetry (repro.obs): cache hit/miss/eviction counters plus one
        # span per solve, keyed by the masked client count — the NULL_TRACER
        # default keeps the untraced path to a single attribute check
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._last_A: np.ndarray | None = None

    def relay_matrix(self, state: ChannelState) -> np.ndarray:
        self.stats.rounds += 1
        key = state.key()
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.stats.cache_hits += 1
            if self.tracer.enabled:
                self.tracer.count("opt_alpha.cache_hits")
            self._last_A = hit
            return hit
        self.stats.cache_misses += 1
        if self.tracer.enabled:
            self.tracer.count("opt_alpha.cache_misses")
        A0 = None
        sweeps = self.sweeps
        masked = state.active is not None and not state.active.all()
        if masked:
            # churn: the solve lives on the active block — restrict the
            # channel first so the warm start and optimum never put mass on
            # a departed client
            a = np.asarray(state.active, dtype=bool)
            p_eff = np.where(a, state.p.astype(np.float64), 0.0)
            adj_eff = state.adj & a[:, None] & a[None, :]
        else:
            p_eff, adj_eff = state.p, state.adj
        if self.warm_start and self._last_A is not None:
            A0 = opt_alpha.warm_start_weights(p_eff, adj_eff, self._last_A)
            sweeps = self.warm_sweeps
            self.stats.warm_solves += 1
        def _solve():
            if masked:
                return opt_alpha.optimize_masked(
                    state.p,
                    state.adj,
                    state.active,
                    sweeps=sweeps,
                    tol=self.tol,
                    A0=A0,
                    method=self.method,
                )
            return opt_alpha.optimize(
                state.p,
                state.adj,
                sweeps=sweeps,
                tol=self.tol,
                A0=A0,
                method=self.method,
            )

        if self.tracer.enabled:
            with self.tracer.span(
                "opt_alpha.solve",
                cat="solve",
                epoch=state.epoch_id,
                n_active=state.n_active,
                warm=A0 is not None,
            ):
                res = _solve()
            self.tracer.count("opt_alpha.solves")
            self.tracer.count("opt_alpha.sweeps", res.sweeps)
        else:
            res = _solve()
        self.stats.solves += 1
        self.stats.sweeps_total += res.sweeps
        # the cache and the warm-start seed alias the returned array; freeze
        # it so a caller mutating A cannot silently corrupt later epochs
        res.A.setflags(write=False)
        self._cache[key] = res.A
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
            self.stats.evictions += 1
            if self.tracer.enabled:
                self.tracer.count("opt_alpha.evictions")
        self._last_A = res.A
        return res.A


class SparseOptAlpha:
    """Neighborhood-blocked OPT-α policy: ``relay_matrix`` returns an
    :class:`~repro.core.relay.EdgeRelay` instead of a dense matrix.

    The scale-path sibling of :class:`AdaptiveOptAlpha` for
    ``relay_backend="segment"``: nothing here is O(n²) or O(n²)-sized —
    the closed-neighborhood CSC structure is extracted once per distinct
    adjacency (memoized on the channel key's adjacency bytes, which the
    schedule interns for an unchanged graph, so the comparison is a pointer
    check) and every solve reuses it; the LRU cache stores (E,) value
    vectors, not (n, n) matrices, so per-round cohorts at n = 10⁴ don't
    hoard gigabytes; warm starts project the previous cohort's edge values
    (:func:`repro.core.opt_alpha.warm_start_vals`).  Same counters and
    telemetry as the dense policy.

    Every returned EdgeRelay shares the graph's index arrays and spans the
    *full* closed structure with zeros on inactive entries — constant edge
    count, so downstream jitted steps never retrace on a cohort change.
    """

    def __init__(
        self,
        *,
        sweeps: int = 40,
        warm_sweeps: int | None = None,
        tol: float = 1e-10,
        cache_size: int = 64,
        warm_start: bool = True,
        method: str = "bisect",
        tracer=None,
    ):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.sweeps = sweeps
        self.warm_sweeps = sweeps if warm_sweeps is None else warm_sweeps
        self.tol = tol
        self.cache_size = cache_size
        self.warm_start = warm_start
        self.method = method
        self.stats = SchedulerStats()
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._cache: OrderedDict[tuple, relay_lib.EdgeRelay] = OrderedDict()
        self._graph: topology.ClosedGraph | None = None
        self._graph_bytes: bytes | None = None
        self._rows32: np.ndarray | None = None
        self._cols32: np.ndarray | None = None
        self._last_vals: np.ndarray | None = None

    def relay_matrix(self, state: ChannelState) -> relay_lib.EdgeRelay:
        self.stats.rounds += 1
        key = state.key()
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.stats.cache_hits += 1
            if self.tracer.enabled:
                self.tracer.count("opt_alpha.cache_hits")
            self._last_vals = np.asarray(hit.vals, dtype=np.float64)
            return hit
        self.stats.cache_misses += 1
        if self.tracer.enabled:
            self.tracer.count("opt_alpha.cache_misses")
        adj_bytes = key[0]
        if self._graph is None or self._graph_bytes != adj_bytes:
            self._graph = topology.closed_csc(state.adj)
            self._graph_bytes = adj_bytes
            self._rows32 = self._graph.rows.astype(np.int32)
            self._cols32 = self._graph.cols.astype(np.int32)
            self._last_vals = None  # old vals index a different structure
        g = self._graph
        p = state.p.astype(np.float64)
        vals0 = None
        sweeps = self.sweeps
        if self.warm_start and self._last_vals is not None:
            vals0 = opt_alpha.warm_start_vals(p, g, self._last_vals, state.active)
            sweeps = self.warm_sweeps
            self.stats.warm_solves += 1

        def _solve():
            return opt_alpha.optimize_sparse(
                p,
                active=state.active,
                graph=g,
                sweeps=sweeps,
                tol=self.tol,
                vals0=vals0,
                method=self.method,
            )

        if self.tracer.enabled:
            with self.tracer.span(
                "opt_alpha.solve",
                cat="solve",
                epoch=state.epoch_id,
                n_active=state.n_active,
                warm=vals0 is not None,
                sparse=True,
            ):
                res = _solve()
            self.tracer.count("opt_alpha.solves")
            self.tracer.count("opt_alpha.sweeps", res.sweeps)
        else:
            res = _solve()
        self.stats.solves += 1
        self.stats.sweeps_total += res.sweeps
        vals32 = res.vals.astype(np.float32)
        vals32.setflags(write=False)
        er = relay_lib.EdgeRelay(rows=self._rows32, cols=self._cols32, vals=vals32)
        self._cache[key] = er
        if len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
            self.stats.evictions += 1
            if self.tracer.enabled:
                self.tracer.count("opt_alpha.evictions")
        self._last_vals = res.vals
        return er


@dataclasses.dataclass(frozen=True)
class StagedChunk:
    """One unit of prefetched work: at most ``chunk`` rounds of a single
    channel segment, with everything the device dispatch needs already
    materialized on the host.

    ``segment`` is the *snapshot* the schedule emitted — ``ChannelSchedule.
    _emit`` copies (adj, p, active), so a staged chunk can never observe a
    post-dated field state even though the worker thread has advanced the
    underlying channel processes several epochs past it (tested:
    ``test_prefetched_segments_never_use_postdated_state``).
    """

    segment: ChannelSegment
    # the segment's relay operator (None ⇒ no relaying): a dense matrix from
    # AdaptiveOptAlpha/StaleOptAlpha, or an EdgeRelay from SparseOptAlpha
    A: np.ndarray | relay_lib.EdgeRelay | None
    batches: Any  # pytree, leaves stacked (n_rounds, ...), already on device
    start: int  # offset of this chunk within the segment
    n_rounds: int  # real rounds in this chunk (≤ chunk)
    last_in_segment: bool


@dataclasses.dataclass
class PrefetchStats:
    """Measured host/device overlap of one prefetched run.

    ``prep_s`` is the total staging time (OPT-α solves, ``next_batch``
    calls, stacking, the H2D transfer); ``wait_s`` is the part of it that
    stayed on the consumer's critical path — in threaded mode, how long the
    consumer actually blocked on the queue; in inline mode, staging time
    during which the device had no dispatch in flight to hide it behind.
    ``overlap_fraction = 1 - wait_s / prep_s`` (clamped to [0, 1]) is the
    fraction of host work the pipeline removed from the critical path.

    The first chunk can never overlap (pipeline fill: there is no dispatch
    in flight yet), so ``overlap_fraction`` is < 1 even at perfect
    steady-state overlap — and on short runs the fill chunk biases it badly
    low.  ``first_prep_s`` / ``first_wait_s`` isolate that chunk, and
    ``steady_overlap_fraction`` is the same ratio with it excluded — the
    number that actually answers "does the pipeline hide host work once
    running".  ``chunks`` counts chunks the consumer dequeued,
    ``chunks_staged`` chunks the staging side produced (equal after a full
    run; staged may lead consumed mid-run in threaded mode).
    """

    chunks: int = 0
    chunks_staged: int = 0
    segments: int = 0
    prep_s: float = 0.0
    wait_s: float = 0.0
    first_prep_s: float = 0.0
    first_wait_s: float = 0.0

    @property
    def overlap_fraction(self) -> float:
        if self.prep_s <= 0.0:
            return 0.0
        return min(1.0, max(0.0, 1.0 - self.wait_s / self.prep_s))

    @property
    def steady_overlap_fraction(self) -> float:
        """``overlap_fraction`` excluding the pipeline-fill chunk (0.0 when
        the run had no steady-state chunks to measure)."""
        prep = self.prep_s - self.first_prep_s
        wait = self.wait_s - self.first_wait_s
        if prep <= 0.0:
            return 0.0
        return min(1.0, max(0.0, 1.0 - wait / prep))


class _Failure:
    """Worker-thread exception, re-raised on the consumer side."""

    def __init__(self, exc: BaseException):
        self.exc = exc


_DONE = object()

# Process-global, refcounted guard around the GIL switch interval: while any
# threaded prefetcher is alive the interval is shortened once, and the saved
# value is restored only when the last one closes — overlapping prefetchers
# must not restore each other's setting mid-run or leave the shortened
# interval behind.
_fast_switch_lock = threading.Lock()
_fast_switch_depth = 0
_fast_switch_saved: float | None = None


def _acquire_fast_switch_interval() -> None:
    global _fast_switch_depth, _fast_switch_saved
    with _fast_switch_lock:
        if _fast_switch_depth == 0:
            _fast_switch_saved = sys.getswitchinterval()
            sys.setswitchinterval(min(_fast_switch_saved, 1e-3))
        _fast_switch_depth += 1


def _release_fast_switch_interval() -> None:
    global _fast_switch_depth, _fast_switch_saved
    with _fast_switch_lock:
        if _fast_switch_depth == 0:
            return
        _fast_switch_depth -= 1
        if _fast_switch_depth == 0 and _fast_switch_saved is not None:
            sys.setswitchinterval(_fast_switch_saved)
            _fast_switch_saved = None


def _shutdown_worker(stop: threading.Event, q: queue.Queue, thread) -> None:
    """Stop a threaded prefetcher's worker and restore the switch interval.

    Module-level so ``weakref.finalize`` can hold it without keeping the
    prefetcher alive: a threaded prefetcher that is abandoned un-iterated
    (e.g. its consumer raised before the loop) must not leave a polling
    daemon thread and a shortened GIL switch interval behind for the rest
    of the process.  (The worker itself holds no reference to the
    prefetcher either — see :func:`_worker_loop` — or the abandoned object
    could never be collected and this finalizer would never fire.)
    """
    stop.set()
    while True:  # unblock a worker stuck on a full queue
        try:
            q.get_nowait()
        except queue.Empty:
            break
    try:
        thread.join(timeout=5.0)
    finally:
        _release_fast_switch_interval()


def _worker_loop(gen, stats: PrefetchStats, q: queue.Queue, stop: threading.Event):
    """Threaded-mode staging loop (module-level: must not close over the
    prefetcher, only over its long-lived pieces)."""

    def put(item) -> bool:
        # blocking put that aborts promptly when the consumer closed
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    try:
        first = True
        while True:
            t0 = time.perf_counter()
            try:
                item = next(gen)
            except StopIteration:
                break
            dt = time.perf_counter() - t0
            stats.prep_s += dt
            if first:
                stats.first_prep_s += dt
                first = False
            if not put(item):
                return
        put(_DONE)
    except BaseException as exc:  # noqa: BLE001 — relayed to the consumer
        put(_Failure(exc))


def _staged_items(
    stats, schedule, rounds, chunk, next_batch, policy, pad_to_chunk, tracer, place
):
    """The staging stream both modes share (module-level: the generator's
    frame must not pin the prefetcher — see :func:`_worker_loop`).

    Telemetry: one ``stage`` span per chunk (batch draws + host stacking)
    and one ``h2d`` span per chunk (the device transfer), both on the
    logical ``prefetcher`` track — in threaded mode that is the worker
    thread's real timeline, in inline mode it is the staging work
    interleaved on the consumer, either way its own Perfetto row.  The
    policy's ``solve`` spans fire from inside ``relay_matrix``.

    ``place`` overrides the plain device transfer: the sharded engine
    passes a ``device_put``-with-``NamedSharding`` closure so each chunk
    lands directly in its mesh layout (every device receives exactly its
    clients' bytes) — still billed to the ``h2d`` span.
    """
    to_device = _to_device if place is None else place
    for seg in schedule.segments(rounds):
        A = policy.relay_matrix(seg.state) if policy is not None else None
        stats.segments += 1
        for start in range(0, seg.n_rounds, chunk):
            window = min(chunk, seg.n_rounds - start)
            pad = chunk - window if pad_to_chunk else 0
            if tracer.enabled:
                with tracer.span(
                    "prefetch.stage",
                    cat="stage",
                    track="prefetcher",
                    epoch=seg.epoch_id,
                    rounds=window,
                ):
                    host = _stack_host([next_batch() for _ in range(window)], pad)
                with tracer.span(
                    "prefetch.h2d", cat="h2d", track="prefetcher", epoch=seg.epoch_id
                ):
                    staged = to_device(host)
            else:
                host = _stack_host([next_batch() for _ in range(window)], pad)
                staged = to_device(host)
            stats.chunks_staged += 1
            yield StagedChunk(
                segment=seg,
                A=A,
                batches=staged,
                start=start,
                n_rounds=window,
                last_in_segment=start + window >= seg.n_rounds,
            )


class SegmentPrefetcher:
    """Double-buffered staging of per-chunk work items, in one of two modes.

    Both modes walk ``schedule.segments(rounds)`` in order and, per segment,
    (1) resolve the relay matrix once via ``policy.relay_matrix`` (the
    adaptive OPT-α re-solve — the dominant host cost under fast-varying
    channels), then (2) split the segment into ``chunk``-round windows,
    drawing ``next_batch()`` once per round in round order, stacking the
    window (optionally zero-padded to ``chunk``) and transferring it to the
    device.  The staged stream (segments, relay matrices, warm-start chain,
    batch stream) follows the serial driver's exact order in either mode, so
    the training trajectory is bit-identical to inline execution.

    **Inline mode** (``threaded=False``, the default) stages on demand from
    the consuming thread: because JAX dispatch is asynchronous, the consumer
    dispatches chunk k and immediately resumes this iterator, which stages
    chunk k+1 *while the device executes chunk k* — software double
    buffering with no second thread, no GIL contention, no handoff latency.
    Overlap is measured directly: staging time during which the previous
    dispatch was still in flight (``jax.Array.is_ready`` on the handle
    passed to :meth:`note_inflight`) was hidden; the rest is ``wait_s``.

    **Threaded mode** (``threaded=True``) runs staging on a worker thread
    feeding a bounded queue of ``depth`` items (the worker blocks when it is
    ``depth`` chunks ahead, bounding memory to ``depth + 1`` chunks).  This
    buys true host/host parallelism — worth it when staging is dominated by
    GIL-released native code and the backend is a real accelerator — at the
    price of GIL handoffs with the dispatch thread, which on few-core CPU
    hosts usually costs more than it hides.  The worker is the only thread
    touching schedule/policy/batches; compiled dispatches stay on the
    consumer thread.

    Iterate to consume; call :meth:`close` (or exhaust the iterator) to shut
    down.  Staging exceptions re-raise on the consumer side in both modes.
    """

    def __init__(
        self,
        schedule,
        rounds: int,
        *,
        chunk: int,
        next_batch: Callable[[], Any],
        policy=None,
        depth: int = 2,
        pad_to_chunk: bool = False,
        threaded: bool = False,
        tracer=None,
        place: Callable[[Any], Any] | None = None,
    ):
        """``place`` replaces the default H2D transfer (``jnp.asarray`` per
        leaf) with a caller-supplied placement — e.g. ``jax.device_put``
        under a ``NamedSharding`` so staged chunks arrive already laid out
        across a mesh.  It runs on the staging side (the worker thread in
        threaded mode) and must not block on in-flight device work."""
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.stats = PrefetchStats()
        self.threaded = bool(threaded)
        self._inflight = None
        self._tracer = NULL_TRACER if tracer is None else tracer
        self._counters_folded = False
        self._gen = _staged_items(
            self.stats,
            schedule,
            int(rounds),
            int(chunk),
            next_batch,
            policy,
            bool(pad_to_chunk),
            self._tracer,
            place,
        )
        self._thread = None
        self._finalizer = None
        if self.threaded:
            self._queue: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=_worker_loop,
                args=(self._gen, self.stats, self._queue, self._stop),
                daemon=True,
            )
            self._thread.start()
            # While the worker is alive, shorten the interpreter's GIL
            # switch interval: staging runs long GIL-holding numpy/python
            # stretches, and at the default 5 ms the consumer thread can
            # stall that long before it gets to enqueue the next device
            # chunk.  1 ms bounds that dispatch latency; released by
            # _shutdown_worker via a process-global refcount (acquired only
            # after start() succeeded, so a failed __init__ cannot leak the
            # shortened interval; the finalizer covers a consumer that
            # abandons the prefetcher without closing it).
            _acquire_fast_switch_interval()
            self._finalizer = weakref.finalize(
                self, _shutdown_worker, self._stop, self._queue, self._thread
            )

    def note_inflight(self, handle) -> None:
        """Inline-mode overlap probe: the consumer passes any output array
        of its latest dispatch; staging time that elapses while this handle
        is not yet ready was hidden behind device execution."""
        self._inflight = handle

    # -------------------------------------------------- consumer thread side
    def __iter__(self):
        if self.threaded:
            try:
                while True:
                    t0 = time.perf_counter()
                    item = self._queue.get()
                    dt = time.perf_counter() - t0
                    self.stats.wait_s += dt
                    if self.stats.chunks == 0:
                        self.stats.first_wait_s += dt
                    if item is _DONE:
                        break
                    if isinstance(item, _Failure):
                        raise item.exc
                    self.stats.chunks += 1
                    yield item
            finally:
                self.close()
            return
        while True:
            t0 = time.perf_counter()
            try:
                item = next(self._gen)
            except StopIteration:
                break
            dt = time.perf_counter() - t0
            self.stats.prep_s += dt
            hidden = self._inflight is not None and not self._inflight.is_ready()
            if not hidden:
                self.stats.wait_s += dt
            if self.stats.chunks == 0:
                # pipeline fill: the first chunk has nothing to hide behind,
                # so its prep/wait is excluded from steady_overlap_fraction
                self.stats.first_prep_s += dt
                if not hidden:
                    self.stats.first_wait_s += dt
            self.stats.chunks += 1
            yield item

    def close(self) -> None:
        """Stop the worker and release the queue (idempotent; no-op in
        inline mode).  Also runs via ``weakref.finalize`` if the prefetcher
        is garbage-collected without an explicit close.  When tracing, the
        final :class:`PrefetchStats` fold onto the tracer's counters here —
        once, whichever of close/exhaustion runs first."""
        if self._finalizer is not None:
            self._finalizer()  # runs _shutdown_worker at most once
            self._thread = None
        if self._tracer.enabled and not self._counters_folded:
            self._counters_folded = True
            self._tracer.count("prefetch.chunks", self.stats.chunks)
            self._tracer.count("prefetch.chunks_staged", self.stats.chunks_staged)
            self._tracer.count("prefetch.segments", self.stats.segments)
            self._tracer.count("prefetch.prep_s", self.stats.prep_s)
            self._tracer.count("prefetch.wait_s", self.stats.wait_s)


def _stack_host(batches: list, pad: int) -> Any:
    """Stack per-round batch pytrees along a new leading axis (zero-padding
    ``pad`` dead rounds when asked), entirely in numpy — the host half of
    staging, split from :func:`_to_device` so tracing can bill stacking as
    ``stage`` and the transfer as ``h2d`` without nesting the categories.
    Both halves run on the staging side (worker thread in threaded mode):
    the multi-MB memcpys happen in largely GIL-released numpy stretches."""
    import jax  # deferred: everything else in this package is jax-free

    def leaf(*xs):
        out = np.stack(xs)
        if pad:
            zeros = np.zeros((pad,) + out.shape[1:], out.dtype)
            out = np.concatenate([out, zeros])
        return out

    return jax.tree.map(leaf, *batches)


def _to_device(host: Any) -> Any:
    """Move a host-stacked pytree to the device.  ``jnp.asarray`` of a numpy
    array never blocks behind an in-flight compiled computation — decisive on
    the CPU backend, where *eager jnp ops* (a device-side pad/concatenate)
    would queue behind the previous chunk and stall staging for a full
    chunk's compute time."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(jnp.asarray, host)


class StaleOptAlpha:
    """Solve OPT-α on the first channel only; every later round reuses that A
    projected onto the live topology (the channel-oblivious baseline)."""

    def __init__(
        self, *, sweeps: int = 40, tol: float = 1e-10, method: str = "bisect"
    ):
        self.sweeps = sweeps
        self.tol = tol
        self.method = method
        self._A: np.ndarray | None = None

    def relay_matrix(self, state: ChannelState) -> np.ndarray:
        if self._A is None:
            if state.active is not None and not state.active.all():
                self._A = opt_alpha.optimize_masked(
                    state.p,
                    state.adj,
                    state.active,
                    sweeps=self.sweeps,
                    tol=self.tol,
                    method=self.method,
                ).A
            else:
                self._A = opt_alpha.optimize(
                    state.p,
                    state.adj,
                    sweeps=self.sweeps,
                    tol=self.tol,
                    method=self.method,
                ).A
        return project_to_support(self._A, state.adj, state.active)
