"""Client churn: membership processes over a padded client dimension.

The simulator's client axis is padded to a fixed ``n_max``; churn is the
(n_max,) boolean *membership mask* changing between rounds.  A membership
process steps that mask (host-side numpy, like every other channel process),
and :class:`ChurnSchedule` composes it with the existing link-fading and
p-drift processes into one stream of ``(adj, p, active, epoch_id)`` states —
so a client joining or leaving is just a new value of a traced input, never a
reshape or a recompile.

Processes
---------
  StaticMembership   fixed mask (degenerate composition / warm-up phases)
  MarkovChurn        per-client on/off 2-state Markov chain (independent
                     arrivals/departures with geometric session lengths),
                     with a ``min_active`` floor so the run never empties
  RotatingCohorts    deterministic shift rotation: the padded slots are split
                     into k cohorts and one cohort is offline per shift —
                     reproducible churn for tests and benchmarks
"""
from __future__ import annotations

import numpy as np

from repro.channels.schedule import TimeVaryingChannel


class StaticMembership:
    """Degenerate churn: the mask never changes."""

    def __init__(self, active):
        a = np.asarray(active, dtype=bool).copy()
        if a.ndim != 1:
            raise ValueError("active must be a vector")
        if not a.any():
            raise ValueError("at least one client must be active")
        self._a = a

    def value(self) -> np.ndarray:
        return self._a

    def step(self) -> np.ndarray:
        return self._a


class MarkovChurn:
    """Independent per-client membership chains: an active client departs
    with probability ``p_leave`` per step, an inactive one (re)joins with
    probability ``p_join`` — geometric session/absence lengths, the
    membership analogue of the Gilbert–Elliott link model.

    ``min_active`` guards the degenerate empty round: departures that would
    push the live count below the floor are resampled away (the kept clients
    are chosen uniformly among that step's survivors).
    """

    def __init__(
        self,
        n_max: int,
        *,
        p_leave: float,
        p_join: float,
        init_active=None,
        min_active: int = 1,
        seed: int = 0,
    ):
        if not (0.0 <= p_leave <= 1.0 and 0.0 <= p_join <= 1.0):
            raise ValueError("p_leave / p_join must be probabilities")
        if not (1 <= min_active <= n_max):
            raise ValueError("need 1 <= min_active <= n_max")
        self.n_max = int(n_max)
        self.p_leave = float(p_leave)
        self.p_join = float(p_join)
        self.min_active = int(min_active)
        self._rng = np.random.default_rng(seed)
        if init_active is None:
            self._a = np.ones((n_max,), dtype=bool)
        else:
            self._a = np.asarray(init_active, dtype=bool).copy()
            if self._a.shape != (n_max,):
                raise ValueError(f"init_active must have shape ({n_max},)")
        if self._a.sum() < min_active:
            raise ValueError("init_active starts below min_active")

    def value(self) -> np.ndarray:
        return self._a

    def step(self) -> np.ndarray:
        u = self._rng.random(self.n_max)
        nxt = np.where(self._a, u >= self.p_leave, u < self.p_join)
        deficit = self.min_active - int(nxt.sum())
        if deficit > 0:
            # revive `deficit` of this step's departures, uniformly
            departed = np.nonzero(self._a & ~nxt)[0]
            revive = self._rng.choice(departed, size=deficit, replace=False)
            nxt[revive] = True
        self._a = nxt
        return self._a


class RotatingCohorts:
    """Deterministic churn: n_max slots in ``n_cohorts`` contiguous cohorts;
    each shift of ``hold`` rounds takes exactly one cohort offline, rotating
    round-robin.  Every client periodically leaves and rejoins, with a
    perfectly reproducible trajectory."""

    def __init__(self, n_max: int, *, n_cohorts: int, hold: int = 1):
        if n_cohorts < 2 or n_cohorts > n_max:
            raise ValueError("need 2 <= n_cohorts <= n_max")
        if hold < 1:
            raise ValueError("hold must be >= 1")
        self.n_max = int(n_max)
        self.n_cohorts = int(n_cohorts)
        self.hold = int(hold)
        bounds = np.linspace(0, n_max, n_cohorts + 1).astype(int)
        self._cohorts = [np.arange(lo, hi) for lo, hi in zip(bounds[:-1], bounds[1:])]
        self._step = 0
        self._a = self._mask(0)

    def _mask(self, shift: int) -> np.ndarray:
        a = np.ones((self.n_max,), dtype=bool)
        a[self._cohorts[shift % self.n_cohorts]] = False
        return a

    def value(self) -> np.ndarray:
        return self._a

    def step(self) -> np.ndarray:
        self._step += 1
        self._a = self._mask(self._step // self.hold)
        return self._a


class ChurnSchedule(TimeVaryingChannel):
    """A :class:`TimeVaryingChannel` that additionally streams the churn
    mask: composes a membership process with a link-state process (or a fixed
    ``adj``) and a p-drift process (or a fixed ``p``), emitting one
    ``ChannelState(adj, p, active, epoch_id)`` per round.

    The emitted ``adj`` / ``p`` stay full-size (n_max); restriction to the
    active block is the consumer's job (``opt_alpha.optimize_masked`` host-
    side, ``relay.mask_relay_matrix`` in the compiled step).  A membership
    change alone changes ``ChannelState.key()``, so it opens a new epoch and
    a new adaptive-scheduler cache entry.

    ``active_every`` throttles the membership process exactly like
    ``adj_every`` / ``p_every`` throttle the channel processes.
    """

    def __init__(self, *, membership, active_every: int = 1, **channel_kwargs):
        super().__init__(**channel_kwargs)
        if active_every < 1:
            raise ValueError("active_every must be >= 1")
        self._member = membership
        self._active_every = int(active_every)

    def _membership(self) -> np.ndarray:
        return self._member.value()

    def next_round(self):
        if self._round > 0 and self._round % self._active_every == 0:
            self._member.step()
        return super().next_round()
