"""Drift processes for the uplink success probabilities p(r).

The paper estimates p from pilots once; these processes model the estimate
going stale: either the environment jumps between quasi-static states
(piecewise-constant, e.g. blockage appearing/clearing) or it wanders slowly
(reflected random walk, e.g. pathloss drift under mobility).  All emitted
vectors stay inside [low, high] ⊂ [0, 1].
"""
from __future__ import annotations

import numpy as np


def _check_bounds(p0, low, high):
    p0 = np.asarray(p0, dtype=np.float64).copy()
    if p0.ndim != 1:
        raise ValueError("p0 must be a vector")
    if not (0.0 <= low < high <= 1.0):
        raise ValueError("need 0 <= low < high <= 1")
    return np.clip(p0, low, high), float(low), float(high)


class StaticP:
    """Degenerate drift: p(r) = p0 forever (static-channel composition)."""

    def __init__(self, p0):
        self.p = np.asarray(p0, dtype=np.float64).copy()

    def value(self) -> np.ndarray:
        return self.p

    def step(self) -> np.ndarray:
        return self.p


class PiecewiseConstantDrift:
    """Hold p for ``hold`` rounds, then resample uniformly in [low, high]."""

    def __init__(
        self, p0, *, hold: int, low: float = 0.05, high: float = 0.95, seed: int = 0
    ):
        if hold < 1:
            raise ValueError("hold must be >= 1")
        self.p, self.low, self.high = _check_bounds(p0, low, high)
        self.hold = int(hold)
        self._rng = np.random.default_rng(seed)
        self._age = 0  # rounds the current block has been held

    def value(self) -> np.ndarray:
        return self.p

    def step(self) -> np.ndarray:
        self._age += 1
        if self._age >= self.hold:
            self.p = self._rng.uniform(self.low, self.high, size=self.p.shape)
            self._age = 0
        return self.p


def _reflect(x: np.ndarray, low: float, high: float) -> np.ndarray:
    """Fold x into [low, high] by mirror reflection at both walls."""
    width = high - low
    y = np.mod(x - low, 2.0 * width)
    y = np.where(y > width, 2.0 * width - y, y)
    return low + y


class RandomWalkDrift:
    """p(r+1) = reflect(p(r) + N(0, σ²)) — slow per-client drift."""

    def __init__(
        self, p0, *, sigma: float, low: float = 0.05, high: float = 0.95, seed: int = 0
    ):
        if sigma < 0:
            raise ValueError("sigma must be nonnegative")
        self.p, self.low, self.high = _check_bounds(p0, low, high)
        self.sigma = float(sigma)
        self._rng = np.random.default_rng(seed)

    def value(self) -> np.ndarray:
        return self.p

    def step(self) -> np.ndarray:
        self.p = _reflect(
            self.p + self._rng.normal(0.0, self.sigma, size=self.p.shape),
            self.low,
            self.high,
        )
        return self.p
