"""Correlated connectivity: spatial shadowing + coupled uplink/D2D fading.

The earlier channel processes are *independent*: every D2D edge carries its
own Markov chain (`link_state`) and the uplink vector drifts on its own
(`drift`).  Real D2D meshes fail in correlated bursts — edges sharing a
blocked node or a common obstacle drop together, and a client behind that
obstacle loses its uplink at the same time.  This module models exactly that
regime (the journal version of the source paper, arXiv:2202.11850, and
Connectivity-Aware Semi-Decentralized FL over Time-Varying D2D Networks,
arXiv:2303.08988, both study it): one latent per-node log-shadowing field
drives the whole channel, so ``(adj, p)`` are *jointly* sampled.

The latent field
----------------
z(r) ∈ R^n is a Gauss–Markov process in time and a Gaussian process in
space over the node positions x_i::

    z(0) ~ N(0, Σ),    z(r+1) = ρ z(r) + sqrt(1 − ρ²) ε_r,   ε_r ~ N(0, Σ),
    Σ_ij = σ² exp(−‖x_i − x_j‖² / (2 ℓ²)).

ρ is the temporal coherence (the AR(1) pole), ℓ the spatial correlation
length.  ℓ = 0 recovers independent per-node fading; ℓ → ∞ makes every node
share one fade — a common obstacle that blocks the whole mesh at once.  The
marginal of each z_i is N(0, σ²) at every round, independent of ρ and ℓ, so
sweeping the correlation structure never changes the per-node fade statistics
— only how fades *co-occur*.

From the field, per coherence interval:

* **blockage** — node i is blocked when z_i < −threshold (deep shadow).
  Every edge incident to a blocked node is down: edges sharing a node fail
  together by construction (:class:`ShadowedLinkProcess`).
* **coupled uplink** — p_i = clip(sigmoid(logit(p_base_i) + γ z_i)): the
  same latent fade that kills i's D2D links degrades its uplink marginal
  (:class:`CoupledUplinkDrift`).  γ = 0 decouples; larger γ makes the uplink
  co-move harder with the local D2D state.

Both are layer-1 processes sharing one :class:`ShadowingField`, so the
existing layer-2 schedules compose them unchanged —
``TimeVaryingChannel(link_process=..., p_process=...)`` for the pure
channel, ``ChurnSchedule(membership=..., ...)`` to add client churn on top.
:class:`CorrelatedChannel` is the one-call convenience wrapper.  The field
advances exactly once per link step (the link process owns it); the uplink
process only *reads* the field and caches its value, so the ``adj_every`` /
``p_every`` throttles keep their meaning (``p_every > adj_every`` models
pilot estimates lagging the fade).
"""
from __future__ import annotations

import numpy as np

from repro.channels.schedule import TimeVaryingChannel
from repro.core import topology


def circle_positions(n: int, *, radius: float = 0.5) -> np.ndarray:
    """n points evenly spaced on a circle centred in the unit square — the
    canonical embedding for ``topology.ring`` graphs, where graph neighbors
    are also spatial neighbors (adjacent nodes sit ~2πr/n apart)."""
    theta = 2.0 * np.pi * np.arange(n) / n
    return 0.5 + radius * np.stack([np.cos(theta), np.sin(theta)], axis=1)


def spatial_covariance(
    positions: np.ndarray, *, corr_length: float, sigma: float = 1.0
) -> np.ndarray:
    """Squared-exponential GP covariance over node positions:
    Σ_ij = σ² exp(−‖x_i − x_j‖² / (2ℓ²)).  ℓ = 0 degenerates to σ²·I
    (independent nodes), ℓ = ∞ to the rank-one σ²·𝟙𝟙ᵀ (one shared fade)."""
    pos = np.asarray(positions, dtype=np.float64)
    if pos.ndim != 2 or pos.shape[1] != 2:
        raise ValueError(f"positions must be (n, 2), got {pos.shape}")
    if corr_length < 0 or sigma <= 0:
        raise ValueError("need corr_length >= 0 and sigma > 0")
    n = pos.shape[0]
    if corr_length == 0.0:
        return sigma**2 * np.eye(n)
    if np.isinf(corr_length):
        return np.full((n, n), sigma**2)
    diff = pos[:, None, :] - pos[None, :, :]
    d2 = np.sum(diff * diff, axis=-1)
    return sigma**2 * np.exp(-d2 / (2.0 * corr_length**2))


class ShadowingField:
    """The latent per-node log-shadowing field (see module docstring).

    ``step()`` advances one coherence interval; ``value()`` returns the
    current (n,) field.  ``set_positions`` re-fits the spatial covariance
    (mobility: nodes that move apart decorrelate) without resetting the
    temporal state.
    """

    def __init__(
        self,
        positions: np.ndarray,
        *,
        corr_length: float,
        rho: float = 0.9,
        sigma: float = 1.0,
        seed: int = 0,
    ):
        if not 0.0 <= rho < 1.0:
            raise ValueError("need 0 <= rho < 1 (rho = 1 never mixes)")
        self.corr_length = float(corr_length)
        self.rho = float(rho)
        self.sigma = float(sigma)
        self._rng = np.random.default_rng(seed)
        self._chol = None
        self.set_positions(positions)
        self.z = self._draw()  # stationary init: z(0) ~ N(0, Σ)

    def set_positions(self, positions: np.ndarray) -> None:
        cov = spatial_covariance(
            positions, corr_length=self.corr_length, sigma=self.sigma
        )
        # jitter keeps the Cholesky factorizable in the degenerate limits
        # (ℓ = ∞ is rank one; near-coincident mobile nodes are rank deficient)
        jitter = 1e-9 * self.sigma**2 * np.eye(cov.shape[0])
        self._chol = np.linalg.cholesky(cov + jitter)

    def _draw(self) -> np.ndarray:
        """One N(0, Σ) sample."""
        return self._chol @ self._rng.standard_normal(self._chol.shape[0])

    def value(self) -> np.ndarray:
        return self.z

    def step(self) -> np.ndarray:
        """AR(1) update z ← ρz + √(1−ρ²)·ε keeps the N(0, Σ) marginal."""
        self.z = self.rho * self.z + np.sqrt(1.0 - self.rho**2) * self._draw()
        return self.z


class ShadowedLinkProcess:
    """D2D adjacency from per-node blockage on a shared shadowing field.

    Node i is *blocked* when z_i < −``threshold``; the realized graph is the
    base envelope minus every edge incident to a blocked node.  The base is
    either a fixed ``base_adj`` or, with ``mobility``, the geometric graph of
    the current positions (which also re-fits the field's spatial covariance
    as nodes move).

    This process **owns** the shared field: ``step()`` advances it exactly
    once.  Uplink processes coupled to the same field only read it.
    """

    def __init__(
        self,
        base_adj: np.ndarray | None,
        field: ShadowingField,
        *,
        threshold: float = 1.0,
        mobility=None,
    ):
        if (base_adj is None) == (mobility is None):
            raise ValueError("pass exactly one of base_adj / mobility")
        if threshold < 0:
            raise ValueError("threshold must be nonnegative")
        self.field = field
        self.threshold = float(threshold)
        self._mobility = mobility
        self.base = (
            None
            if base_adj is None
            else topology._validate(np.asarray(base_adj, dtype=bool).copy())
        )

    @property
    def blocked(self) -> np.ndarray:
        """(n,) bool: nodes currently in deep shadow."""
        return self.field.value() < -self.threshold

    def _base_adjacency(self) -> np.ndarray:
        if self._mobility is not None:
            return self._mobility.adjacency()
        return self.base

    def adjacency(self) -> np.ndarray:
        """Current realized graph: base minus blocked-node edges."""
        up = ~self.blocked
        adj = self._base_adjacency() & up[:, None] & up[None, :]
        return topology._validate(adj.copy())

    def step(self) -> np.ndarray:
        if self._mobility is not None:
            self._mobility.step()
            self.field.set_positions(self._mobility.positions)
        self.field.step()
        return self.adjacency()


class CoupledUplinkDrift:
    """Uplink marginals driven by the *same* shadowing field as the D2D
    links:  p_i = clip(sigmoid(logit(p_base_i) + gain·z_i), low, high).

    A deep fade (z_i ≪ 0) that blocks i's D2D edges simultaneously drags its
    uplink toward ``low``; a strong line-of-sight round lifts it toward
    ``high``.  ``step()`` re-reads the field and caches the result —
    ``value()`` is stable between steps, so schedule throttling
    (``p_every``) behaves exactly like the independent drift processes.
    """

    def __init__(
        self,
        p_base: np.ndarray,
        field: ShadowingField,
        *,
        gain: float = 2.0,
        low: float = 0.05,
        high: float = 0.95,
    ):
        if gain < 0:
            raise ValueError("gain must be nonnegative")
        if not 0.0 < low < high < 1.0:
            raise ValueError("need 0 < low < high < 1")
        p0 = np.clip(np.asarray(p_base, dtype=np.float64), low, high)
        if p0.ndim != 1:
            raise ValueError("p_base must be a vector")
        self.field = field
        self.gain = float(gain)
        self.low = float(low)
        self.high = float(high)
        self._logit0 = np.log(p0) - np.log1p(-p0)
        self.p = self._from_field()

    def _from_field(self) -> np.ndarray:
        logit = self._logit0 + self.gain * self.field.value()
        return np.clip(1.0 / (1.0 + np.exp(-logit)), self.low, self.high)

    def value(self) -> np.ndarray:
        return self.p

    def step(self) -> np.ndarray:
        self.p = self._from_field()
        return self.p


class CorrelatedChannel(TimeVaryingChannel):
    """One-call jointly-sampled channel: shadowing-driven D2D blockage and
    (optionally) the coupled uplink, all from one latent field.

    Equivalent to composing :class:`ShadowedLinkProcess` /
    :class:`CoupledUplinkDrift` through :class:`TimeVaryingChannel` by hand
    — the pieces stay accessible as ``.field`` / ``.link`` for diagnostics.
    ``hold`` is the channel coherence time in rounds (both the blockage
    pattern and the coupled p refresh together every ``hold`` rounds, so
    epochs are fusable by the scan engine).  With ``positions=None`` the
    nodes sit on a circle (:func:`circle_positions`), the natural embedding
    of the ring topologies; ``corr_length`` is then measured against a
    neighbor spacing of ~π/n in the unit square.
    """

    def __init__(
        self,
        base_adj: np.ndarray | None,
        p_base: np.ndarray,
        *,
        corr_length: float,
        positions: np.ndarray | None = None,
        mobility=None,
        rho: float = 0.9,
        sigma: float = 1.0,
        blockage_threshold: float = 1.0,
        couple_uplink: bool = True,
        uplink_gain: float = 2.0,
        p_low: float = 0.05,
        p_high: float = 0.95,
        hold: int = 1,
        seed: int = 0,
    ):
        if hold < 1:
            raise ValueError("hold must be >= 1")
        p_base = np.asarray(p_base, dtype=np.float64)
        if mobility is not None:
            positions = mobility.positions
        elif positions is None:
            positions = circle_positions(p_base.shape[0])
        self.field = ShadowingField(
            positions,
            corr_length=corr_length,
            rho=rho,
            sigma=sigma,
            seed=seed,
        )
        link = ShadowedLinkProcess(
            base_adj,
            self.field,
            threshold=blockage_threshold,
            mobility=mobility,
        )
        if couple_uplink:
            p_kw = {
                "p_process": CoupledUplinkDrift(
                    p_base, self.field, gain=uplink_gain, low=p_low, high=p_high
                )
            }
        else:
            p_kw = {"p": np.clip(p_base, p_low, p_high)}
        super().__init__(link_process=link, adj_every=hold, p_every=hold, **p_kw)

    @property
    def link(self) -> ShadowedLinkProcess:
        return self._link

    @property
    def blocked(self) -> np.ndarray:
        """(n,) bool: nodes currently blocked (diagnostic)."""
        return self._link.blocked
