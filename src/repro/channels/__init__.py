"""Time-varying channel subsystem: what the network does between rounds.

Everything in this package is **host-side numpy** — the compiled round step
only ever sees the resulting ``(A, p, τ, active)`` values as traced inputs,
so channel dynamics (and client churn) never retrace jitted code.  The
subsystem is three layers, consumed in order every round:

1. **Processes** — stateful generators advancing one aspect of the channel:

   * link state (`link_state`): Markov / Gilbert–Elliott per-edge fading on a
     base D2D graph; (`mobility`): random-waypoint trajectories with
     radio-range geometric adjacency.
   * uplink drift (`drift`): the p-vector going stale — piecewise-constant
     jumps (blockage) or a reflected random walk (pathloss drift).
   * correlated fading (`correlated`): one latent per-node log-shadowing
     field (:class:`ShadowingField`: AR(1) in time, Gaussian-process over
     node positions in space) drives node blockage on the D2D graph
     (:class:`ShadowedLinkProcess` — edges sharing a blocked node fail
     together) and, optionally, the uplink marginals
     (:class:`CoupledUplinkDrift` — p_i co-moves with i's local D2D state).
     Unlike every process above, the resulting ``(adj, p)`` stream is
     *jointly* sampled; :class:`CorrelatedChannel` is the one-call schedule.
   * membership (`churn`): clients joining/leaving over a *padded* client
     dimension ``n_max`` — per-client Markov on/off chains
     (:class:`MarkovChurn`), deterministic shift rotation
     (:class:`RotatingCohorts`), or a fixed mask
     (:class:`StaticMembership`).
   * cohort sampling (`sampling`): :class:`CohortSampler`, a membership
     process emitting ``membership ∧ sampled`` — per-round client cohorts
     (uniform / fixed-k / expander-stride draws), optionally wrapping any
     of the processes above as the eligibility base.  The n ≫ 10³ scale
     regime: per-round cost follows the cohort and its live edges.
   * arrival delays (`delay`): :class:`DelayProcess` streams — Poisson
     (:class:`PoissonDelays`), geometric (:class:`GeometricDelays`) or the
     synchronous :class:`ZeroDelays` — deciding *when* each client's
     computed update reaches the PS.  Consumed by the asynchronous engine
     (:class:`repro.fl.async_engine.AsyncRoundEngine`), not by the
     schedules: delays compose on top of churn/sampling rather than
     replacing them.

2. **Schedules** (`schedule`, `churn`) — compose processes into one stream of
   :class:`ChannelState` per federated round: the realized adjacency, the
   uplink marginals p, the churn mask ``active`` (``None`` for the fixed-
   membership schedules) and an ``epoch_id`` that increments exactly when the
   channel *value* ``(adj, p, active)`` changes.  :class:`StaticChannel` is
   the seed setting, :class:`TimeVaryingChannel` composes fading × drift,
   :class:`ChurnSchedule` additionally streams membership.
   ``ChannelSchedule.segments()`` regroups the stream into maximal
   constant-channel :class:`ChannelSegment` runs — the unit the
   epoch-segmented scan engine (:class:`repro.fl.engine.EpochScanEngine`)
   fuses into one ``lax.scan`` per epoch.

3. **Scheduler policies** (`scheduler`) — turn a state stream into per-round
   relay matrices.  :class:`AdaptiveOptAlpha` re-solves OPT-α only on epoch
   changes: an LRU cache keyed on the channel bytes — including the churn
   mask, since the optimum over a different active set is a different matrix
   — plus Gauss–Seidel warm starts from the previous optimum.  Under churn
   it solves the masked problem (`opt_alpha.optimize_masked`), so departed
   clients carry exactly zero weight.  :class:`SparseOptAlpha` is the same
   policy on the neighborhood-blocked solver: it emits sparse
   :class:`~repro.core.relay.EdgeRelay` operands for
   ``relay_backend="segment"`` and keeps solves, cache entries and relay
   cost O(edges).  :class:`StaleOptAlpha` is the
   channel-oblivious ablation (round-0 A forever, projected onto the live
   topology and membership).

4. **Prefetching** (`scheduler`) — the host half of the pipelined execution
   path.  :class:`SegmentPrefetcher` walks ``segments()``, resolves the
   relay matrix once per segment and stages per-chunk batch stacks
   (:class:`StagedChunk` items), so the OPT-α re-solve and data staging for
   epoch k+1 overlap the device's in-flight chunk of epoch k
   (:class:`repro.fl.engine.PipelinedScanEngine` is the consumer).  Two
   modes: by default staging runs *inline* right after the previous chunk's
   async dispatch (double buffering with no second thread); with
   ``threaded=True`` a worker thread fills a bounded queue instead.  Either
   way schedule/policy/batches are touched in the serial driver's exact
   order, so the staged stream — and therefore the training trajectory —
   is bit-identical to unpipelined execution; :class:`PrefetchStats`
   reports the measured host/device overlap.

Lifecycle per round::

    state = schedule.next_round()            # (adj, p, active, epoch_id)
    A     = policy.relay_matrix(state)       # cached within an epoch
    sim.run_round(key, ..., A=A, p=state.p, active=state.active)

The simulator's ``trace_count`` stays at 1 across epochs *and* membership
changes: A, p and the mask are values, never shapes.  The dataflow from
here to the compiled round engines (and the dispatch-timeline picture) is
narrated in ``docs/architecture.md``.
"""
from repro.channels.churn import (
    ChurnSchedule,
    MarkovChurn,
    RotatingCohorts,
    StaticMembership,
)
from repro.channels.correlated import (
    CorrelatedChannel,
    CoupledUplinkDrift,
    ShadowedLinkProcess,
    ShadowingField,
    circle_positions,
    spatial_covariance,
)
from repro.channels.delay import (
    DelayProcess,
    GeometricDelays,
    PoissonDelays,
    ZeroDelays,
    make_delays,
)
from repro.channels.drift import (
    PiecewiseConstantDrift,
    RandomWalkDrift,
    StaticP,
)
from repro.channels.link_state import MarkovLinkProcess, gilbert_elliott
from repro.channels.mobility import RandomWaypointMobility, geometric_adjacency
from repro.channels.sampling import CohortSampler
from repro.channels.schedule import (
    ChannelSchedule,
    ChannelSegment,
    ChannelState,
    StaticChannel,
    TimeVaryingChannel,
)
from repro.channels.scheduler import (
    AdaptiveOptAlpha,
    PrefetchStats,
    SchedulerStats,
    SegmentPrefetcher,
    SparseOptAlpha,
    StagedChunk,
    StaleOptAlpha,
    project_to_support,
)

__all__ = [
    "AdaptiveOptAlpha",
    "ChannelSchedule",
    "ChannelSegment",
    "ChannelState",
    "ChurnSchedule",
    "CohortSampler",
    "CorrelatedChannel",
    "CoupledUplinkDrift",
    "DelayProcess",
    "GeometricDelays",
    "MarkovChurn",
    "MarkovLinkProcess",
    "PiecewiseConstantDrift",
    "PoissonDelays",
    "PrefetchStats",
    "RandomWalkDrift",
    "RandomWaypointMobility",
    "RotatingCohorts",
    "SchedulerStats",
    "SegmentPrefetcher",
    "ShadowedLinkProcess",
    "ShadowingField",
    "SparseOptAlpha",
    "StagedChunk",
    "StaleOptAlpha",
    "StaticChannel",
    "StaticMembership",
    "StaticP",
    "TimeVaryingChannel",
    "ZeroDelays",
    "circle_positions",
    "geometric_adjacency",
    "gilbert_elliott",
    "make_delays",
    "project_to_support",
    "spatial_covariance",
]
