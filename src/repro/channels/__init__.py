"""Time-varying channel subsystem.

Link-state processes (Markov/Gilbert–Elliott fading, random-waypoint
mobility), uplink-probability drift, the per-round :class:`ChannelSchedule`
stream, and relay-matrix scheduling policies (adaptive OPT-α with LRU cache +
warm start, and the stale-A baseline).  Everything here is host-side numpy;
the compiled round step only ever sees the resulting (A, p, τ) values.
"""
from repro.channels.drift import (
    PiecewiseConstantDrift,
    RandomWalkDrift,
    StaticP,
)
from repro.channels.link_state import MarkovLinkProcess, gilbert_elliott
from repro.channels.mobility import RandomWaypointMobility, geometric_adjacency
from repro.channels.schedule import (
    ChannelSchedule,
    ChannelState,
    StaticChannel,
    TimeVaryingChannel,
)
from repro.channels.scheduler import (
    AdaptiveOptAlpha,
    SchedulerStats,
    StaleOptAlpha,
    project_to_support,
)

__all__ = [
    "AdaptiveOptAlpha",
    "ChannelSchedule",
    "ChannelState",
    "MarkovLinkProcess",
    "PiecewiseConstantDrift",
    "RandomWalkDrift",
    "RandomWaypointMobility",
    "SchedulerStats",
    "StaleOptAlpha",
    "StaticChannel",
    "StaticP",
    "TimeVaryingChannel",
    "geometric_adjacency",
    "gilbert_elliott",
    "project_to_support",
]
