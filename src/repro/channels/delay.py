"""Arrival-delay processes: when each client's round-r update reaches the PS.

The asynchronous engine (:class:`repro.fl.async_engine.AsyncRoundEngine`)
relaxes the lockstep round: client j's update from round r arrives at round
``r + d`` where ``d`` is drawn per (round, client) from one of the processes
below.  Like every other channel process, delays are **host-side numpy** —
deterministic streams from ``np.random.default_rng(seed)``, advanced exactly
once per round in round order, so a run (and its resume) replays the same
arrival pattern bit-for-bit.  The sampled delay only schedules *when* the
already-computed update is merged into the PS buffer; it never enters the
compiled round step, so asynchrony adds no retraces.

Delays compose freely with churn and cohort sampling
(:class:`~repro.channels.churn.ChurnSchedule` /
:class:`~repro.channels.sampling.CohortSampler`): the schedule decides who
*computes* and who is *eligible at aggregation time*; the delay process
decides when each computed update lands.  A client that departs before its
update arrives contributes exactly zero (the engine gates eligibility on the
aggregation round's active mask).

``max_delay`` clips every draw: it bounds the engine's pending-arrival
buffer (at most ``max_delay`` in-flight rounds are held) and guarantees every
update eventually lands or is superseded.
"""
from __future__ import annotations

import numpy as np


class DelayProcess:
    """Base class: a deterministic per-round stream of (n,) integer delays.

    Subclasses implement ``_draw(rng) -> (n,) ints``; ``sample()`` clips to
    ``[0, max_delay]`` and advances the stream.  ``reset()`` rewinds to the
    seed state — the bench harness replays cold/warm passes through the same
    engine, so the arrival pattern must be reproducible on demand.
    """

    def __init__(self, n: int, *, max_delay: int = 8, seed: int = 0):
        if n < 1:
            raise ValueError(f"need n >= 1 clients, got {n}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        self.n = n
        self.max_delay = max_delay
        self.seed = seed
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self.round = 0

    def sample(self) -> np.ndarray:
        """One round's (n,) delays, clipped to ``[0, max_delay]``."""
        d = np.clip(self._draw(self._rng), 0, self.max_delay)
        self.round += 1
        return d.astype(np.int64)

    def _draw(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


class ZeroDelays(DelayProcess):
    """Every update arrives in its own round — the synchronous reduction.

    This is the delay process under which the async engine is bitwise-
    identical to ``run_rounds_loop`` (tested), and the control arm of the
    time-to-accuracy comparison.
    """

    def __init__(self, n: int, *, seed: int = 0):
        super().__init__(n, max_delay=0, seed=seed)

    def _draw(self, rng):
        return np.zeros(self.n, np.int64)


class PoissonDelays(DelayProcess):
    """I.i.d. Poisson(rate) delays per (round, client) — the classic arrival
    model for stragglers: most updates land within a round or two, a thin
    tail arrives late.  ``rate`` is the mean delay in rounds."""

    def __init__(self, n: int, *, rate: float = 1.0, max_delay: int = 8,
                 seed: int = 0):
        if rate < 0:
            raise ValueError(f"rate must be >= 0, got {rate}")
        self.rate = rate
        super().__init__(n, max_delay=max_delay, seed=seed)

    def _draw(self, rng):
        return rng.poisson(self.rate, self.n)


class GeometricDelays(DelayProcess):
    """I.i.d. geometric delays on support {0, 1, 2, ...} with mean ``mean``
    rounds — a heavier tail than Poisson at the same mean (memoryless
    per-round "did it land yet" retries)."""

    def __init__(self, n: int, *, mean: float = 1.0, max_delay: int = 8,
                 seed: int = 0):
        if mean < 0:
            raise ValueError(f"mean must be >= 0, got {mean}")
        self.mean = mean
        super().__init__(n, max_delay=max_delay, seed=seed)

    def _draw(self, rng):
        if self.mean == 0:
            return np.zeros(self.n, np.int64)
        # numpy's geometric is on {1, 2, ...}: shift to include 0 so that
        # mean-0 limits to the synchronous setting
        p = 1.0 / (1.0 + self.mean)
        return rng.geometric(p, self.n) - 1


def make_delays(kind: str, n: int, *, rate: float = 1.0, max_delay: int = 8,
                seed: int = 0) -> DelayProcess:
    """Factory used by the bench registry: ``kind`` ∈ none|poisson|geometric
    (``rate`` is the mean delay in rounds for both distributions)."""
    if kind == "none":
        return ZeroDelays(n, seed=seed)
    if kind == "poisson":
        return PoissonDelays(n, rate=rate, max_delay=max_delay, seed=seed)
    if kind == "geometric":
        return GeometricDelays(n, mean=rate, max_delay=max_delay, seed=seed)
    raise ValueError(f"unknown delay process: {kind!r}")
