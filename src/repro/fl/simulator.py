"""Single-host FL simulator (paper-scale: n≈10 clients, small models).

Implements Algs. 1 + 2 literally: per round —
  broadcast x^(r) → T local SGD steps per client (vmap over clients) →
  D2D relay Δx̃ = A·Δx → Bernoulli τ mask → blind PS aggregation → server opt.

Used by the paper-figure benchmarks (Figs. 2-4), the convergence tests and
the examples.  The whole round is one jitted function.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core import relay as relay_lib
from repro.core.aggregation import ServerOpt
from repro.optim.sgd import ClientOpt
from repro.utils import stacked_ravel, tree_sub, tree_unravel


def _metrics(loss, tau, delta_norm):
    """Round metrics as a plain dict (jit-friendly)."""
    return {"loss": loss, "tau": tau, "delta_norm": delta_norm}


class FLSimulator:
    """strategy ∈ {colrel, colrel_fused, fedavg_blind, fedavg_nonblind,
    no_dropout}; A is required for the colrel strategies.

    The relay matrix A and the connectivity vector p are *round inputs*: a
    time-varying channel (``repro.channels``) may pass fresh values to
    ``run_round`` every round without retracing the jitted step — A enters the
    compiled function as a traced argument, never a closure constant.  The
    values given at construction are only defaults.  ``trace_count`` counts
    actual retraces (it should stay at 1 across channel epochs of fixed n).

    Client churn: ``n_clients`` is the *padded* client dimension ``n_max``.
    Passing ``run_round(..., active=mask)`` with a (n_max,) 0/1 mask runs the
    round over only the live clients — inactive slots still compute a local
    update (fixed shapes), but contribute exactly zero to the PS increment
    and are excluded from the metrics; the blind weight renormalizes to
    1/n_active.  The mask is traced, so clients may join/leave every round
    while ``trace_count`` stays at 1.  ``active=None`` (default) is the
    full-membership path, bit-identical to the fixed-n formulation.

    ``relay_backend`` ∈ {einsum, pallas, pallas_fused} picks the engine for
    the relay∘aggregate contraction over the raveled ``(n, D)`` delta buffer
    (``repro.kernels``); einsum is the pure-XLA reference.  ``block_d`` /
    ``interpret`` tune the Pallas kernel (None ⇒ defaults).

    ``run_round`` is the per-round reference path (one dispatch per round).
    For long horizons, :class:`repro.fl.engine.EpochScanEngine` fuses whole
    channel epochs into ``lax.scan`` calls over the same ``_round_math``
    (and :class:`repro.fl.engine.PipelinedScanEngine` additionally draws τ
    inside the chunk and prefetches the host work), bit-identical to calling
    ``run_round`` round by round.
    """

    def __init__(
        self,
        loss_fn: Callable[[Any, dict], jax.Array],
        *,
        n_clients: int,
        strategy: str = "colrel",
        A: np.ndarray | None = None,
        p: np.ndarray | None = None,
        local_steps: int = 8,
        client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
        server_opt: ServerOpt = ServerOpt(),
        relay_backend: str = "einsum",
        block_d: int | None = None,
        interpret=None,
    ):
        self.loss_fn = loss_fn
        self.n = n_clients
        self.T = local_steps
        self.client_opt = client_opt
        self.server_opt = server_opt
        self.strategy = strategy
        self.relay_backend = relay_backend
        self.p = (
            jnp.asarray(p, jnp.float32) if p is not None else jnp.ones((n_clients,))
        )
        self.A = relay_lib.as_relay_operand(A, n=n_clients, backend=relay_backend)
        self.aggregator = aggregation.make_aggregator(
            strategy,
            n=n_clients,
            relay_backend=relay_backend,
            block_d=block_d,
            interpret=interpret,
        )
        self.trace_count = 0
        self._round = jax.jit(self._round_impl)

    # -- one client: T local SGD steps from the broadcast global model -----
    def _client_update(self, params, client_batch, lr):
        opt_state = self.client_opt.init(params)

        def step(carry, minibatch):
            p, s = carry
            loss, g = jax.value_and_grad(self.loss_fn)(p, minibatch)
            p, s = self.client_opt.step(p, g, s, lr)
            return (p, s), loss

        (new_params, _), losses = jax.lax.scan(step, (params, opt_state), client_batch)
        return tree_sub(new_params, params), losses[0]

    def _round_impl(self, params, server_state, batch, tau, A, lr, active):
        self.trace_count += 1  # python-side: runs only when jit retraces
        return self._round_math(params, server_state, batch, tau, A, lr, active)

    def _round_math(self, params, server_state, batch, tau, A, lr, active):
        """The round as a pure function — traced both by the per-round jit
        (``run_round``) and by the epoch-segmented scan engines
        (``repro.fl.engine``), so all paths share one definition and
        stay bit-identical by construction."""
        deltas, losses = jax.vmap(self._client_update, in_axes=(None, 0, None))(
            params, batch, lr
        )
        # ravel the stacked deltas once: the aggregation hot spot (and the
        # kernel backends behind it) see one contiguous (n, D) buffer, while
        # the clients above ran on the structured view
        buf, spec = stacked_ravel(deltas)
        flat_inc = self.aggregator.flat_fn(tau, buf, A, active)
        increment = tree_unravel(spec, flat_inc, cast=False)
        new_params, new_state = self.server_opt.apply(params, server_state, increment)

        # per-client ‖Δ‖² falls out of the buffer for free (one row-sum)
        per_client_dn = jnp.sum(buf * buf, axis=1)
        if active is None:
            mean_loss, dn = jnp.mean(losses), jnp.mean(per_client_dn)
        else:
            # churn: metrics average over the live clients only (a padded
            # slot's local run is dead compute and must not skew them)
            a = jnp.asarray(active, jnp.float32)
            denom = jnp.maximum(a.sum(), 1.0)
            mean_loss = jnp.sum(losses * a) / denom
            dn = jnp.sum(per_client_dn * a) / denom
            tau = tau * a
        return new_params, new_state, _metrics(mean_loss, tau, jnp.sqrt(dn))

    def run_round(
        self, key, params, server_state, batch, lr, *, A=None, p=None, active=None
    ):
        """batch: pytree with leaves (n, T, b, ...).

        ``A`` / ``p`` override the construction-time channel for this round
        (time-varying channels); both enter the jitted step by value only.
        ``active`` is the churn mask over the padded client dimension (see
        class docstring) — also by value, so membership changes don't retrace.
        """
        tau = self.sample_tau(key, p)
        A_round = (
            self.A
            if A is None
            else relay_lib.as_relay_operand(A, n=self.n, backend=self.relay_backend)
        )
        active_round = None if active is None else jnp.asarray(active, jnp.float32)
        return self._round(params, server_state, batch, tau, A_round, lr, active_round)

    def sample_tau(self, key, p=None):
        """One round's uplink mask, exactly as ``run_round`` draws it.  The
        epoch-segmented scan engine calls this per round to materialize a
        segment's τ stream, so loop and scan consume identical randomness."""
        p_round = self.p if p is None else jnp.asarray(p, jnp.float32)
        tau = jax.random.bernoulli(key, p_round).astype(jnp.float32)
        if self.strategy == "no_dropout":
            tau = jnp.ones_like(tau)
        return tau

    def init_server_state(self, params):
        return self.server_opt.init(params)
