"""Ring-schedule D2D relay: the paper's physical exchange as manual
collectives (`shard_map` + `lax.ppermute`).

The relaying round of the paper (§II-C, eq. 2) is literally a network event:
every client transmits its local update to its D2D neighbors, each relay
forms the weighted consensus Δx̃_r = Σ_o α_{r,o} Δx_o, and the PS blindly
sums what arrives.  On a device mesh the same dataflow is a **ring
collective**: updates rotate around the client axis with `ppermute`, and
each rotation step contributes one α-weighted term to the local accumulator
— after n−1 rotations every relay holds its consensus with **O(1) live
buffers** instead of the O(n·|Δ|) gather of the einsum formulation.  The
blind PS reduction is then a τ-weighted `psum` over the same axis.

Step-by-step (4 devices; at rotation s, device r holds Δ_{(r−s) mod n} and
adds α_{r,(r−s)}·Δ_{(r−s)}):

    s=0   d0:Δ0   d1:Δ1   d2:Δ2   d3:Δ3      acc += α_{r,r}  Δ_r
    s=1   d0:Δ3   d1:Δ0   d2:Δ1   d3:Δ2      acc += α_{r,r−1}Δ_{r−1}
    s=2   d0:Δ2   d1:Δ3   d2:Δ0   d3:Δ1      acc += α_{r,r−2}Δ_{r−2}
    s=3   d0:Δ1   d1:Δ2   d2:Δ3   d3:Δ0      acc += α_{r,r−3}Δ_{r−3}
    psum( w·τ_r · acc_r )  →  the PS increment, replicated

Two granularities:

* **one client per device** (:func:`ring_relay_local`,
  :func:`ring_colrel_increment`, :func:`make_ring_round_mixer`): pytree
  deltas, the reference formulation; `tests/test_ring_relay.py` proves it
  equal to the einsum relay on a real (multi-axis) mesh.
* **a block of clients per device** (:func:`ring_relay_flat`,
  :func:`ring_colrel_increment_flat`): the production shape used inside
  `build_sharded_scan_round_step` — each of k devices owns m = n/k client
  rows of the raveled (n, D) buffer, rotations move (m, D) blocks, and each
  step contributes the (m, m) block-matmul A[rows_r, rows_{r−s}] @ block.
  k−1 ppermutes replace the all-gather regardless of how many clients share
  a device.

Reduction-order note: the ring accumulates α-terms in rotation order
(diagonal first), whereas the einsum contracts in XLA's order — the results
agree to f32 accumulation accuracy, *not* bitwise.  The sharded engine's
``exchange="gather"`` mode keeps the einsum order (bitwise vs the
single-device reference); ``exchange="ring"`` trades that for O(1) buffers
at a documented tolerance (see docs/distributed.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.utils import tree_axpy, tree_scale


def _combined_index(axis_names):
    idx = jax.lax.axis_index(axis_names[0])
    for a in axis_names[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def ring_relay_local(A, delta_local, axis_names: tuple):
    """Inside shard_map: delta_local = this client's Δx (no client dim).
    Returns Δx̃_r for the local relay r.  A: (n, n) host constant."""
    A = jnp.asarray(A, jnp.float32)
    n = A.shape[0]
    r = _combined_index(axis_names)
    perm = [(i, (i + 1) % n) for i in range(n)]

    acc = tree_scale(A[r, r], delta_local)

    def step(s, carry):
        buf, acc = carry
        buf = jax.tree.map(lambda x: jax.lax.ppermute(x, axis_names, perm), buf)
        origin = (r - s) % n
        acc = tree_axpy(A[r, origin], buf, acc)
        return buf, acc

    _, acc = jax.lax.fori_loop(1, n, step, (delta_local, acc))
    return acc


def ring_colrel_increment(A, tau, delta_local, *, w: float, axis_names: tuple):
    """Full blind round reduction inside shard_map:
    w · Σ_r τ_r Δx̃_r, replicated over the client axes."""
    relayed = ring_relay_local(A, delta_local, axis_names)
    r = _combined_index(axis_names)
    tau_r = jnp.asarray(tau, jnp.float32)[r]
    weighted = tree_scale(w * tau_r, relayed)
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), weighted)


def make_ring_round_mixer(A, *, w: float, mesh, client_axes: tuple):
    """shard_map wrapper: stacked deltas (n, ...) sharded over `client_axes`
    → PS increment pytree (replicated).  Other dims must be unsharded within
    the client shard (use the einsum/fused paths for model-sharded deltas)."""
    from jax.sharding import PartitionSpec as P

    def local(tau, deltas_stacked):
        delta_local = jax.tree.map(lambda x: x[0], deltas_stacked)
        return ring_colrel_increment(A, tau, delta_local, w=w, axis_names=client_axes)

    def in_specs(deltas):
        return (
            P(),
            jax.tree.map(lambda x: P(client_axes, *([None] * (x.ndim - 1))), deltas),
        )

    def mixer(tau, deltas_stacked):
        spec_tau, spec_d = in_specs(deltas_stacked)
        out_spec = jax.tree.map(lambda x: P(*([None] * (x.ndim - 1))), deltas_stacked)
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_tau, spec_d),
            out_specs=out_spec,
            check_rep=False,
        )(jnp.asarray(tau, jnp.float32), deltas_stacked)

    return mixer


# --------------------------------------------------------------------------
# Block-ring on the raveled (n, D) buffer: m = n/k clients per device
# --------------------------------------------------------------------------


def ring_relay_flat(A, buf_local, *, axis_name: str, n_shards: int):
    """Inside shard_map: ``buf_local`` is this device's (m, D) block of the
    raveled delta buffer (rows j·m … (j+1)·m−1 of the (n, D) stack for
    device j).  Returns the local relays' consensus block Δx̃ (m, D).

    ``A`` is the full (n, n) relay matrix, replicated: each rotation step s
    contributes the (m, m) block ``A[j·m:, origin·m:] @ block`` where
    ``origin = (j − s) mod k`` is the device whose rows are passing through.
    ``n_shards`` (= k) must be static — it sizes the permutation table.
    """
    A = jnp.asarray(A, jnp.float32)
    n = A.shape[0]
    if n % n_shards != 0:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    m = n // n_shards
    j = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def block(r, c):
        return jax.lax.dynamic_slice(A, (r * m, c * m), (m, m))

    acc = block(j, j) @ buf_local

    def step(s, carry):
        buf, acc = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        origin = (j - s) % n_shards
        acc = acc + block(j, origin) @ buf
        return buf, acc

    if n_shards > 1:
        _, acc = jax.lax.fori_loop(1, n_shards, step, (buf_local, acc))
    return acc


def ring_colrel_increment_flat(
    A, tau, buf_local, *, w, axis_name: str, n_shards: int
):
    """Full blind round reduction on the flat buffer inside shard_map:
    u = w · Σ_r τ_r Δx̃_r → (D,), replicated over ``axis_name``.

    ``tau`` is the full (n,) mask, replicated (the sharded engine draws it
    identically on every device from the same key chain); churn masking of
    A and τ is the *caller's* job, exactly as in
    ``aggregation.colrel_increment_flat`` — this function only phrases the
    contraction as k−1 ppermutes + a psum.
    """
    relayed = ring_relay_flat(
        A, buf_local, axis_name=axis_name, n_shards=n_shards
    )
    m = relayed.shape[0]
    j = jax.lax.axis_index(axis_name)
    tau = jnp.asarray(tau, jnp.float32)
    tau_local = jax.lax.dynamic_slice(tau, (j * m,), (m,))
    u_local = (w * tau_local) @ relayed
    return jax.lax.psum(u_local, axis_name)
