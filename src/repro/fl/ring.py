"""Ring-schedule D2D relay: the paper's physical exchange as a manual
collective (`shard_map` + `lax.ppermute`).

Each device owns one client's update shard.  The updates rotate around the
client axis; at step s device r holds Δx_{(r−s) mod n} and accumulates
α_{r,(r−s)} · Δx_{(r−s)} — after n−1 rotations every relay has its local
consensus Δx̃_r with **O(1) live buffers** instead of the O(n·|Δ|) gather of
the einsum formulation (the §Perf iteration-4/5 memory wall).  The blind PS
reduction is then a τ-weighted psum over the same axis.

This is the reference implementation of the *faithful* protocol at scales
where per-client Δ gathers exceed HBM; `tests/test_ring_relay.py` proves it
equal to the einsum relay on a real mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.utils import tree_axpy, tree_scale


def _combined_index(axis_names):
    idx = jax.lax.axis_index(axis_names[0])
    for a in axis_names[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def ring_relay_local(A, delta_local, axis_names: tuple):
    """Inside shard_map: delta_local = this client's Δx (no client dim).
    Returns Δx̃_r for the local relay r.  A: (n, n) host constant."""
    A = jnp.asarray(A, jnp.float32)
    n = A.shape[0]
    r = _combined_index(axis_names)
    perm = [(i, (i + 1) % n) for i in range(n)]

    acc = tree_scale(A[r, r], delta_local)

    def step(s, carry):
        buf, acc = carry
        buf = jax.tree.map(lambda x: jax.lax.ppermute(x, axis_names, perm), buf)
        origin = (r - s) % n
        acc = tree_axpy(A[r, origin], buf, acc)
        return buf, acc

    _, acc = jax.lax.fori_loop(1, n, step, (delta_local, acc))
    return acc


def ring_colrel_increment(A, tau, delta_local, *, w: float, axis_names: tuple):
    """Full blind round reduction inside shard_map:
    w · Σ_r τ_r Δx̃_r, replicated over the client axes."""
    relayed = ring_relay_local(A, delta_local, axis_names)
    r = _combined_index(axis_names)
    tau_r = jnp.asarray(tau, jnp.float32)[r]
    weighted = tree_scale(w * tau_r, relayed)
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), weighted)


def make_ring_round_mixer(A, *, w: float, mesh, client_axes: tuple):
    """shard_map wrapper: stacked deltas (n, ...) sharded over `client_axes`
    → PS increment pytree (replicated).  Other dims must be unsharded within
    the client shard (use the einsum/fused paths for model-sharded deltas)."""
    from jax.sharding import PartitionSpec as P

    def local(tau, deltas_stacked):
        delta_local = jax.tree.map(lambda x: x[0], deltas_stacked)
        return ring_colrel_increment(A, tau, delta_local, w=w, axis_names=client_axes)

    def in_specs(deltas):
        return (
            P(),
            jax.tree.map(lambda x: P(client_axes, *([None] * (x.ndim - 1))), deltas),
        )

    def mixer(tau, deltas_stacked):
        spec_tau, spec_d = in_specs(deltas_stacked)
        out_spec = jax.tree.map(lambda x: P(*([None] * (x.ndim - 1))), deltas_stacked)
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_tau, spec_d),
            out_specs=out_spec,
            check_rep=False,
        )(jnp.asarray(tau, jnp.float32), deltas_stacked)

    return mixer
