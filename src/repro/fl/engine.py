"""Epoch-segmented ``lax.scan`` round engine.

``FLSimulator.run_round`` dispatches one compiled step per round, so a
1000-round sweep pays ~1000 host→device round-trips even though the step
itself never retraces (A, p, τ, active are traced inputs).  Within a channel
epoch the tuple ``(A, p, active)`` is *constant* — only τ and the data
change — so whole epochs can be fused into a single ``jax.lax.scan`` over
stacked per-round streams:

    carry = (server params, server opt state)
    xs    = (batch_r, τ_r, valid_r)          # stacked over rounds
    A, lr, active                            # loop-invariant traced inputs

The scan body is the simulator's own ``_round_math``, so the fused path is
bit-identical to the per-round reference by construction (and by test:
``tests/test_scan_engine.py``).

Compile discipline
------------------
Epoch lengths vary, and a scan's length is static — scanning each epoch at
its exact length would recompile per distinct length.  The engine therefore
runs fixed-size chunks: an epoch of L rounds becomes ``L // chunk`` scans of
``chunk`` rounds plus a final padded scan whose dead rounds are masked out of
the carry (``jnp.where`` on a per-round valid flag selects the old carry
bit-exactly, so padding never perturbs real rounds).  One compile for the
chunk scan — ``trace_count`` stays at 1 across epochs of a fixed client dim
(2 when both the ``active=None`` and the masked variant are used).

Epoch orchestration lives on the host: ``run_schedule`` walks
``ChannelSchedule.segments()``, re-solves OPT-α once per segment boundary
(the adaptive policy), materializes the segment's τ/batch streams with
exactly the loop driver's RNG order, and issues one ``run_segment`` per
epoch.

Pipelined path
--------------
:class:`PipelinedScanEngine` is the next rung: the chunk body *also* draws
the τ stream (the key chain becomes part of the scan carry, so the separate
per-chunk τ dispatch disappears — exactly one device dispatch per chunk),
and all host work for the next segment (adaptive OPT-α re-solve, batch
stacking, segment sampling) runs on a background worker
(:class:`repro.channels.scheduler.SegmentPrefetcher`) while the device
executes the current chunk — JAX's async dispatch returns control to the
host immediately, so the consumer thread keeps feeding the device without
ever blocking on results.  Still bit-identical to the loop driver (same
gated key chain, same batch order, same policy call order — tested).

Sharded path
------------
:class:`ShardedScanEngine` is the top rung: the same schedule walk drives a
**multi-device** round step
(:func:`repro.fl.distributed.build_sharded_scan_round_step`) — each device
of a mesh owns a block of clients (or, in D mode, a slice of the parameter
axis), the relay exchange runs as a collective (all-gather or block-ring
``ppermute``), and staged batches are ``device_put`` straight into their
sharded layout (`repro.sharding.rules.round_batch_specs`) so no device ever
receives another device's client bytes.  One dispatch per channel epoch,
prefetched staging optional.  See docs/distributed.md for the dataflow.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import relay as relay_lib
from repro.fl.simulator import FLSimulator
from repro.obs import NULL_TRACER


def _stack_rounds(batches: list) -> Any:
    """Stack a list of per-round batch pytrees into one (R, ...) pytree:
    host-side ``np.stack`` per leaf, then a single device transfer each —
    one H2D per segment instead of one per round."""
    return jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)


def _pad_leading(tree: Any, pad: int) -> Any:
    """Append ``pad`` zero rounds along the leading axis of every leaf."""
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]),
        tree,
    )


def _concat_metrics(parts: list) -> Any:
    """Concatenate per-chunk metric pytrees along the round axis."""
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *ms: jnp.concatenate(ms), *parts)


def _trim_concat(parts: list, chunk: int) -> Any:
    """Concatenate (metrics, real_rounds) chunk pairs, trimming the padding
    off remainder chunks.  The pipelined engine defers this to segment/run
    boundaries: the slice and concatenate are *eager* device ops, and on the
    CPU backend an eager op queues behind the in-flight chunk computation —
    running them per chunk would stall the feeding thread for a full chunk's
    compute time and serialize the pipeline."""
    trimmed = []
    for metrics, real in parts:
        if real < chunk:
            metrics = jax.tree.map(lambda m, n=real: m[:n], metrics)
        trimmed.append(metrics)
    return _concat_metrics(trimmed)


class EpochScanEngine:
    """Fused multi-round execution for an :class:`FLSimulator`.

    The engine never re-implements round math: its scan body calls
    ``sim._round_math``, and a segment's remainder rounds run as one
    zero-padded, valid-masked chunk — same compiled function, no per-length
    retrace.

    ``trace_count`` counts the engine's compiles (chunk-scan traces plus any
    per-round traces of the wrapped simulator) — the scan-path analogue of
    ``FLSimulator.trace_count``.
    """

    def __init__(self, sim: FLSimulator, *, chunk: int = 32, tracer=None):
        """``chunk`` is the scan length per compiled call and should track
        the channel's coherence time: a padded chunk computes ``chunk``
        rounds regardless of how many are real, so ``chunk`` far above the
        typical epoch length trades dead compute for nothing (e.g. 2-round
        epochs under ``chunk=32`` cost 16× the math of the loop path).

        ``tracer`` (a :class:`repro.obs.Tracer`) records per-chunk dispatch
        spans plus explicit blocked-on-device fences; the fences change the
        async-dispatch overlap (observer effect), so they — like every other
        traced extra — run only when ``tracer.enabled``.  Also settable
        after construction via the ``tracer`` attribute.
        """
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.sim = sim
        self.chunk = int(chunk)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._scan_traces = 0
        self._chunk_fn = jax.jit(self._chunk_impl)
        self._taus_fn = jax.jit(self._taus_impl)

    @property
    def trace_count(self) -> int:
        return self._scan_traces + self.sim.trace_count

    # -- one compiled call: scan `chunk` rounds under a fixed channel -------
    def _chunk_impl(self, params, server_state, batches, taus, valid, A, lr, active):
        self._scan_traces += 1  # python-side: runs only when jit retraces

        def body(carry, xs):
            p0, s0 = carry
            batch, tau, v = xs
            p1, s1, metrics = self.sim._round_math(p0, s0, batch, tau, A, lr, active)
            # padded rounds: keep the old carry bit-exactly (v is a scalar
            # bool; where(True, new, old) passes `new` through unchanged)
            p1 = jax.tree.map(lambda a, b: jnp.where(v, a, b), p1, p0)
            s1 = jax.tree.map(lambda a, b: jnp.where(v, a, b), s1, s0)
            return (p1, s1), metrics

        (params, server_state), metrics = jax.lax.scan(
            body, (params, server_state), (batches, taus, valid)
        )
        return params, server_state, metrics

    # -- one compiled call: a chunk's τ stream from the sequential key chain
    def _taus_impl(self, key, p, valid):
        def body(k, v):
            k2, sub = jax.random.split(k)
            tau = jax.random.bernoulli(sub, p).astype(jnp.float32)
            if self.sim.strategy == "no_dropout":
                tau = jnp.ones_like(tau)
            # padded rounds must not advance the key chain — the final key
            # has to equal the loop driver's after exactly R splits
            k = jax.tree.map(lambda a, b: jnp.where(v, a, b), k2, k)
            return k, tau

        return jax.lax.scan(body, key, valid)

    def sample_taus(self, key, p, n_rounds: int):
        """A segment's τ stream, drawn in chunk-sized compiled calls but
        bit-identical to ``n_rounds`` sequential ``split`` + ``sample_tau``
        rounds (tested).  Returns ``(advanced_key, (n_rounds, n) taus)``."""
        p = jnp.asarray(p, jnp.float32)
        C = self.chunk
        parts = []
        for start in range(0, n_rounds, C):
            real = min(C, n_rounds - start)
            valid = jnp.arange(C) < real
            if self.tracer.enabled:
                with self.tracer.span("scan.taus", cat="dispatch", rounds=real):
                    key, taus = self._taus_fn(key, p, valid)
            else:
                key, taus = self._taus_fn(key, p, valid)
            parts.append(taus[:real] if real < C else taus)
        return key, (parts[0] if len(parts) == 1 else jnp.concatenate(parts))

    def run_segment(
        self, params, server_state, batches, taus, lr, *, A=None, active=None
    ):
        """Run one channel epoch: ``R`` rounds under a fixed (A, active).

        ``batches``: pytree with leaves (R, n, T, b, ...) — the epoch's data
        stream; ``taus``: (R, n) float32 — the epoch's uplink masks (drawn
        host-side, e.g. via ``sim.sample_tau``).  Dispatches
        ``ceil(R / chunk)`` compiled calls, the last one zero-padded and
        masked.  Returns ``(params, server_state, metrics)`` with every
        metric stacked over the R real rounds (padding trimmed).
        """
        A_seg = (
            self.sim.A
            if A is None
            else relay_lib.as_relay_operand(
                A, n=self.sim.n, backend=self.sim.relay_backend
            )
        )
        if A_seg is None and self.sim.strategy in ("colrel", "colrel_fused"):
            raise ValueError("colrel strategies need a relay matrix A")
        active_seg = None if active is None else jnp.asarray(active, jnp.float32)
        taus = jnp.asarray(taus, jnp.float32)
        R, C = int(taus.shape[0]), self.chunk
        if R == 0:
            raise ValueError("empty segment")
        parts = []
        for start in range(0, R, C):
            stop = min(start + C, R)
            pad = C - (stop - start)
            bs = _pad_leading(jax.tree.map(lambda x: x[start:stop], batches), pad)
            ts = _pad_leading(taus[start:stop], pad)
            valid = jnp.arange(C) < (stop - start)
            if self.tracer.enabled:
                with self.tracer.span(
                    "scan.chunk", cat="dispatch", rounds=stop - start
                ):
                    params, server_state, metrics = self._chunk_fn(
                        params, server_state, bs, ts, valid, A_seg, lr, active_seg
                    )
                # explicit fence: bills the in-flight chunk to the device
                # phase (untraced runs never block here — async dispatch)
                with self.tracer.span("scan.device", cat="device", track="device"):
                    jax.block_until_ready(metrics)
            else:
                params, server_state, metrics = self._chunk_fn(
                    params, server_state, bs, ts, valid, A_seg, lr, active_seg
                )
            if pad:
                metrics = jax.tree.map(lambda m: m[: stop - start], metrics)
            parts.append(metrics)
        return params, server_state, _concat_metrics(parts)

    def run_schedule(
        self,
        key,
        params,
        server_state,
        *,
        schedule,
        rounds,
        next_batch: Callable[[], Any],
        lr,
        policy=None,
        on_segment: Callable | None = None,
    ):
        """Drive a :class:`ChannelSchedule` for ``rounds`` rounds, one
        ``run_segment`` per channel epoch.

        Mirrors the per-round loop driver exactly: the key chain advances
        once per round in round order (``sample_taus``), ``next_batch()`` is
        called once per round in round order, and ``policy.relay_matrix``
        is evaluated once per segment — the same value the loop's per-round
        calls get from the policy's cache.  The trajectory is therefore
        bit-identical to calling ``run_round`` round by round.

        ``next_batch`` returns one round's stacked batch pytree
        (n, T, b, ...).  ``on_segment(segment, params, metrics)`` is an
        optional host callback per epoch (evaluation hooks).  Returns
        ``(params, server_state, metrics, key)`` with metrics stacked over
        all rounds.
        """
        all_metrics = []
        for seg in schedule.segments(rounds):
            A = policy.relay_matrix(seg.state) if policy is not None else None
            # materialize the segment chunk-by-chunk: the scan consumes at
            # most `chunk` rounds per compiled call, so never hold more than
            # one chunk of batches in memory (a single-epoch 500-round
            # schedule must not stack 500 rounds of data at once)
            seg_metrics = []
            for start in range(0, seg.n_rounds, self.chunk):
                window = min(self.chunk, seg.n_rounds - start)
                key, taus = self.sample_taus(key, seg.p, window)
                if self.tracer.enabled:
                    with self.tracer.span(
                        "scan.stage",
                        cat="stage",
                        epoch=seg.epoch_id,
                        rounds=window,
                    ):
                        stacked = _stack_rounds(
                            [next_batch() for _ in range(window)]
                        )
                else:
                    stacked = _stack_rounds([next_batch() for _ in range(window)])
                params, server_state, metrics = self.run_segment(
                    params,
                    server_state,
                    stacked,
                    taus,
                    lr,
                    A=A,
                    active=seg.active,
                )
                seg_metrics.append(metrics)
            metrics = _concat_metrics(seg_metrics)
            all_metrics.append(metrics)
            if on_segment is not None:
                on_segment(seg, params, metrics)
        return params, server_state, _concat_metrics(all_metrics), key


class PipelinedScanEngine:
    """Pipelined epoch execution: fused chunk body + async host/device
    overlap.

    Two changes over :class:`EpochScanEngine`, one on each side of the
    dispatch boundary:

    * **Device** — the τ stream is drawn *inside* the chunk scan: the RNG
      key chain joins the carry, each round splits it, samples
      ``Bernoulli(p)`` and gates the advance on the round's valid flag
      (padded rounds leave the chain untouched, exactly like the loop
      driver's ``split``-per-round order).  The separate per-chunk
      ``_taus_fn`` dispatch is gone — **one compiled dispatch per chunk**,
      counted by ``dispatches``.
    * **Host** — the schedule walk, the adaptive OPT-α re-solves and the
      per-chunk batch staging (stack + zero-pad + H2D, all numpy-side) run
      through a :class:`~repro.channels.scheduler.SegmentPrefetcher`.
      Because a chunk dispatch returns before the device finishes (async
      dispatch), staging epoch k+1 overlaps the device's in-flight chunk of
      epoch k — double-buffered inline by default, or ``prefetch_depth``
      chunks ahead on a worker thread (``prefetch="thread"``).  Epoch k+1's
      host work hides behind epoch k's device work; measured as
      ``prefetch_stats.overlap_fraction``.  The consumer loop itself runs
      no eager jnp ops — on the CPU backend those queue behind the
      in-flight computation and would re-serialize the pipeline (padding
      and valid masks are built host-side; metric trims/concats are
      deferred to segment/run boundaries).

    Everything that makes the scan engine trustworthy carries over
    unchanged: the body calls ``sim._round_math`` (bit-identity with the
    loop by construction and by test), fixed-size chunks with valid-masked
    zero padding keep ``trace_count ≤ 2``, and the key chain, batch order
    and policy call order are the serial driver's exactly.
    """

    def __init__(
        self,
        sim: FLSimulator,
        *,
        chunk: int = 32,
        prefetch: str = "inline",
        prefetch_depth: int = 2,
        tracer=None,
    ):
        """``prefetch`` picks the staging mode (see
        :class:`~repro.channels.scheduler.SegmentPrefetcher`): ``"inline"``
        (default) software-pipelines staging behind async dispatch on one
        thread — the right choice on CPU hosts, where a staging thread
        mostly fights the dispatch thread for the GIL; ``"thread"`` stages
        on a worker thread ``prefetch_depth`` chunks ahead — worth trying
        on real accelerators.

        ``tracer`` flows to the prefetcher (stage/h2d spans on the
        ``prefetcher`` track) and adds per-chunk dispatch + device-fence
        spans on the consumer side.  The fences serialize the pipeline
        (observer effect): traced runs show *where* time goes, untraced
        runs measure how fast it is.  Also settable after construction via
        the ``tracer`` attribute."""
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if prefetch not in ("inline", "thread"):
            raise ValueError(f"unknown prefetch mode: {prefetch!r}")
        self.sim = sim
        self.chunk = int(chunk)
        self.prefetch = prefetch
        self.prefetch_depth = int(prefetch_depth)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._scan_traces = 0
        # per-run counters (reset by run_schedule, like prefetch_stats):
        # compiled chunk calls — exactly one per chunk
        self.dispatches = 0
        self.prefetch_stats = None  # PrefetchStats of the latest run
        self._chunk_fn = jax.jit(self._chunk_impl)

    @property
    def trace_count(self) -> int:
        return self._scan_traces + self.sim.trace_count

    # -- the fully-fused chunk: τ draw + A apply + round math, one dispatch --
    def _chunk_impl(self, key, params, server_state, batches, valid, A, p, lr, active):
        self._scan_traces += 1  # python-side: runs only when jit retraces

        # the loop driver's per-round draw is split-then-Bernoulli(p) on the
        # subkey.  Only the *split chain* is inherently sequential, so run it
        # as a (cheap, key-only) scan and draw all rounds' τ in one batched
        # Bernoulli over the stacked subkeys — vmap of a PRNG draw over
        # distinct keys produces bit-identical samples to sequential calls,
        # and keeping the draws out of the round scan keeps them off its
        # serial critical path.  Still a single compiled dispatch.
        def key_step(k, v):
            k2, sub = jax.random.split(k)
            # padded rounds must not advance the chain — the final key has
            # to equal the loop driver's after exactly R splits
            k = jax.tree.map(lambda a, b: jnp.where(v, a, b), k2, k)
            return k, sub

        key, subs = jax.lax.scan(key_step, key, valid)
        taus = jax.vmap(lambda s: jax.random.bernoulli(s, p))(subs)
        taus = taus.astype(jnp.float32)
        if self.sim.strategy == "no_dropout":
            taus = jnp.ones_like(taus)

        def body(carry, xs):
            p0, s0 = carry
            batch, tau, v = xs
            p1, s1, metrics = self.sim._round_math(p0, s0, batch, tau, A, lr, active)
            # padded rounds: keep the old carry bit-exactly
            p1 = jax.tree.map(lambda a, b: jnp.where(v, a, b), p1, p0)
            s1 = jax.tree.map(lambda a, b: jnp.where(v, a, b), s1, s0)
            return (p1, s1), metrics

        (params, server_state), metrics = jax.lax.scan(
            body, (params, server_state), (batches, taus, valid)
        )
        return key, params, server_state, metrics

    def run_schedule(
        self,
        key,
        params,
        server_state,
        *,
        schedule,
        rounds,
        next_batch: Callable[[], Any],
        lr,
        policy=None,
        on_segment: Callable | None = None,
    ):
        """Drive a ``ChannelSchedule`` for ``rounds`` rounds — same contract
        and bit-identical trajectory as :meth:`EpochScanEngine.run_schedule`
        and the per-round loop, but with host staging prefetched and τ fused
        into the chunk dispatch.  ``on_segment(segment, params, metrics)``
        forces a device sync per epoch (it hands over concrete params), so
        leave it unset on pure-throughput runs.  Returns
        ``(params, server_state, metrics, key)``.
        """
        from repro.channels.scheduler import SegmentPrefetcher

        C = self.chunk
        self.dispatches = 0
        prefetcher = SegmentPrefetcher(
            schedule,
            rounds,
            chunk=C,
            next_batch=next_batch,
            policy=policy,
            depth=self.prefetch_depth,
            pad_to_chunk=True,  # remainder chunks arrive zero-padded (numpy)
            threaded=self.prefetch == "thread",
            tracer=self.tracer,
        )
        # The consumer loop must never run an *eager* jnp op: on the CPU
        # backend those queue behind the in-flight chunk and would stall the
        # pipeline for a full chunk's compute.  Everything here is either
        # jnp.asarray of host data (non-blocking) or the compiled dispatch
        # itself; metric trimming/concatenation is deferred (_trim_concat).
        all_parts: list = []  # (metrics, real_rounds) per chunk, in order
        seg_parts: list = []
        seg_id = A_seg = p_seg = active_seg = None
        valid_cache: dict = {}
        try:
            for item in prefetcher:
                seg = item.segment
                if seg.epoch_id != seg_id:
                    # channel values are loop-invariant within a segment:
                    # one device conversion per epoch, not per chunk
                    seg_id = seg.epoch_id
                    A_seg = (
                        self.sim.A
                        if item.A is None
                        else relay_lib.as_relay_operand(
                            item.A, n=self.sim.n, backend=self.sim.relay_backend
                        )
                    )
                    if A_seg is None and self.sim.strategy in (
                        "colrel",
                        "colrel_fused",
                    ):
                        raise ValueError("colrel strategies need a relay matrix A")
                    active_seg = (
                        None
                        if seg.active is None
                        else jnp.asarray(seg.active, jnp.float32)
                    )
                    p_seg = jnp.asarray(seg.p, jnp.float32)
                real = item.n_rounds
                valid = valid_cache.get(real)
                if valid is None:
                    valid = valid_cache[real] = jnp.asarray(np.arange(C) < real)
                if self.tracer.enabled:
                    with self.tracer.span(
                        "pipelined.chunk",
                        cat="dispatch",
                        epoch=seg.epoch_id,
                        rounds=real,
                    ):
                        key, params, server_state, metrics = self._chunk_fn(
                            key,
                            params,
                            server_state,
                            item.batches,
                            valid,
                            A_seg,
                            p_seg,
                            lr,
                            active_seg,
                        )
                else:
                    key, params, server_state, metrics = self._chunk_fn(
                        key,
                        params,
                        server_state,
                        item.batches,
                        valid,
                        A_seg,
                        p_seg,
                        lr,
                        active_seg,
                    )
                self.dispatches += 1
                prefetcher.note_inflight(metrics["loss"])
                if self.tracer.enabled:
                    # explicit fence: serializes the pipeline (observer
                    # effect — traced runs show *where* time goes, not how
                    # fast the untraced overlap is), but makes blocked-on-
                    # device time a first-class phase on its own track
                    with self.tracer.span(
                        "pipelined.device",
                        cat="device",
                        track="device",
                        epoch=seg.epoch_id,
                    ):
                        jax.block_until_ready(metrics["loss"])
                seg_parts.append((metrics, real))
                if item.last_in_segment:
                    if on_segment is not None:
                        seg_metrics = _trim_concat(seg_parts, C)
                        on_segment(seg, params, seg_metrics)
                        # already trimmed: the final _trim_concat must not
                        # re-slice it (its round count may exceed C)
                        all_parts.append((seg_metrics, C))
                    else:
                        all_parts.extend(seg_parts)
                    seg_parts = []
        finally:
            prefetcher.close()
            self.prefetch_stats = prefetcher.stats
        if self.tracer.enabled:
            self.tracer.count("pipelined.dispatches", self.dispatches)
        return params, server_state, _trim_concat(all_parts, C), key


class ShardedScanEngine:
    """Schedule driver for the multi-device sharded round step.

    Wraps a ``scan_rounds`` built by
    :func:`repro.fl.distributed.build_sharded_scan_round_step` and drives a
    ``ChannelSchedule`` one **whole epoch per compiled dispatch** — the
    channel tuple (A, p, active) is constant within an epoch, so the epoch
    is the natural scan unit and no valid-mask padding is needed (a scan's
    length is static, so schedules should keep epoch lengths uniform —
    coherence dividing the horizon — to hold ``trace_count`` at 1, or 2
    when both churned and churn-free epochs occur).

    The host side differs from the single-device engines in one way:
    staged batches are *placed*, not copied — each chunk is ``device_put``
    under the `NamedSharding` that
    :func:`repro.sharding.rules.round_batch_specs` resolves for the mesh,
    so the transfer scatters every device exactly its clients' bytes and
    the dispatch never reshards its input.  (In ``shard="d"`` mode batches
    stay replicated — GSPMD shards the delta buffer instead — so placement
    falls back to the plain transfer.)

    ``prefetch`` picks the staging mode: ``"serial"`` stages each epoch
    inline before its dispatch (the scan-engine analogue); ``"inline"`` /
    ``"thread"`` stage through a
    :class:`~repro.channels.scheduler.SegmentPrefetcher` (its ``place``
    hook carries the sharded placement), overlapping epoch k+1's OPT-α
    re-solve + stacking + scatter with epoch k's device execution —
    measured in ``prefetch_stats``.

    The trajectory matches the single-device fused engines to the exchange
    mode's guarantee: bitwise for ``exchange="gather"`` on the same local
    math, f32-accumulation tolerance for ``exchange="ring"`` (see
    `repro.fl.ring`).  Key chain, batch order and policy call order are the
    serial driver's exactly.
    """

    def __init__(
        self,
        step_fn: Callable,
        *,
        mesh,
        shard: str = "clients",
        prefetch: str = "inline",
        prefetch_depth: int = 2,
        tracer=None,
    ):
        """``step_fn`` is the ``scan_rounds(key, params, server_state,
        batches, p, lr, A=..., active=...)`` callable from
        ``build_sharded_scan_round_step`` (built on the same ``mesh`` and
        ``shard`` mode).  ``tracer`` adds per-epoch dispatch + device-fence
        spans and the prefetcher's stage/h2d spans."""
        if prefetch not in ("serial", "inline", "thread"):
            raise ValueError(f"unknown prefetch mode: {prefetch!r}")
        if shard not in ("clients", "d"):
            raise ValueError(f"unknown shard mode: {shard!r} (clients | d)")
        self.mesh = mesh
        self.shard = shard
        self.prefetch = prefetch
        self.prefetch_depth = int(prefetch_depth)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._step_fn = step_fn
        self._scan_traces = 0
        self.dispatches = 0
        self.prefetch_stats = None
        self._fn = jax.jit(self._epoch_impl)

    @property
    def trace_count(self) -> int:
        return self._scan_traces

    def _epoch_impl(self, key, params, server_state, batches, p, lr, A, active):
        self._scan_traces += 1  # python-side: runs only when jit retraces
        return self._step_fn(
            key, params, server_state, batches, p, lr, A=A, active=active
        )

    def _place(self, host):
        """Staging-side placement: host-stacked chunk → mesh layout.  In
        clients mode, ``device_put`` under ``round_batch_specs`` scatters
        dim 1 over the client axis; in D mode batches are replicated and
        the plain per-leaf transfer suffices."""
        from repro.sharding import rules

        if self.shard != "clients":
            return jax.tree.map(jnp.asarray, host)
        specs = rules.round_batch_specs(host, self.mesh)
        return jax.device_put(host, rules.to_shardings(specs, self.mesh))

    def _dispatch(self, key, params, server_state, batches, seg, lr, A):
        active = None if seg.active is None else jnp.asarray(seg.active, jnp.float32)
        p = jnp.asarray(seg.p, jnp.float32)
        if self.tracer.enabled:
            with self.tracer.span(
                "shard.epoch",
                cat="dispatch",
                epoch=seg.epoch_id,
                rounds=seg.n_rounds,
            ):
                out = self._fn(key, params, server_state, batches, p, lr, A, active)
        else:
            out = self._fn(key, params, server_state, batches, p, lr, A, active)
        self.dispatches += 1
        return out

    def run_schedule(
        self,
        key,
        params,
        server_state,
        *,
        schedule,
        rounds,
        next_batch: Callable[[], Any],
        lr,
        policy=None,
        on_segment: Callable | None = None,
    ):
        """Drive a ``ChannelSchedule`` for ``rounds`` rounds across the
        mesh — same contract as :meth:`EpochScanEngine.run_schedule`.  A
        relay policy is required (the sharded step is colrel-only).
        Returns ``(params, server_state, metrics, key)``; ``metrics`` is
        ``{"loss": (rounds,)}`` — the active-masked mean client loss per
        round, identical across devices by construction."""
        if policy is None:
            raise ValueError("the sharded engine needs a relay policy")
        self.dispatches = 0
        self.prefetch_stats = None
        losses: list = []
        if self.prefetch == "serial":
            for seg in schedule.segments(rounds):
                A = jnp.asarray(policy.relay_matrix(seg.state), jnp.float32)
                if self.tracer.enabled:
                    with self.tracer.span(
                        "shard.stage", cat="stage", epoch=seg.epoch_id
                    ):
                        host = [next_batch() for _ in range(seg.n_rounds)]
                        stacked = self._place(
                            jax.tree.map(lambda *xs: np.stack(xs), *host)
                        )
                else:
                    host = [next_batch() for _ in range(seg.n_rounds)]
                    stacked = self._place(
                        jax.tree.map(lambda *xs: np.stack(xs), *host)
                    )
                key, params, server_state, seg_losses = self._dispatch(
                    key, params, server_state, stacked, seg, lr, A
                )
                if self.tracer.enabled:
                    with self.tracer.span(
                        "shard.device", cat="device", track="device",
                        epoch=seg.epoch_id,
                    ):
                        jax.block_until_ready(seg_losses)
                losses.append(seg_losses)
                if on_segment is not None:
                    on_segment(seg, params, {"loss": seg_losses})
        else:
            from repro.channels.scheduler import SegmentPrefetcher

            # chunk = the full horizon ⇒ exactly one staged item per
            # segment (a segment never exceeds the horizon): the sharded
            # step scans whole epochs, so staging must hand it whole epochs
            prefetcher = SegmentPrefetcher(
                schedule,
                rounds,
                chunk=rounds,
                next_batch=next_batch,
                policy=policy,
                depth=self.prefetch_depth,
                threaded=self.prefetch == "thread",
                tracer=self.tracer,
                place=self._place,
            )
            try:
                for item in prefetcher:
                    seg = item.segment
                    A = jnp.asarray(item.A, jnp.float32)
                    key, params, server_state, seg_losses = self._dispatch(
                        key, params, server_state, item.batches, seg, lr, A
                    )
                    prefetcher.note_inflight(seg_losses)
                    if self.tracer.enabled:
                        with self.tracer.span(
                            "shard.device", cat="device", track="device",
                            epoch=seg.epoch_id,
                        ):
                            jax.block_until_ready(seg_losses)
                    losses.append(seg_losses)
                    if on_segment is not None:
                        on_segment(seg, params, {"loss": seg_losses})
            finally:
                prefetcher.close()
            self.prefetch_stats = prefetcher.stats
        if self.tracer.enabled:
            self.tracer.count("shard.dispatches", self.dispatches)
        metrics = {
            "loss": losses[0] if len(losses) == 1 else jnp.concatenate(losses)
        }
        return params, server_state, metrics, key


def run_rounds_loop(
    sim: FLSimulator,
    key,
    params,
    server_state,
    *,
    schedule,
    rounds,
    next_batch: Callable[[], Any],
    lr,
    policy=None,
    on_round: Callable | None = None,
    tracer=None,
):
    """The per-round reference driver: the exact loop the figure benchmarks
    run — one dispatch per round and, like every existing driver, a host
    read of the round's loss (``float(...)``, a device sync per round: the
    dispatch-bound regime the scan engine exists to remove).  Factored out
    so loop-vs-scan comparisons share one definition.  ``tracer`` records
    per-round stage/dispatch/sync spans (the loop already syncs per round,
    so tracing adds no extra fence here).
    Returns ``(params, server_state, per_round_metrics, key)``."""
    tracer = NULL_TRACER if tracer is None else tracer
    all_metrics = []
    for state in schedule.rounds(rounds):
        A = policy.relay_matrix(state) if policy is not None else None
        key, sub = jax.random.split(key)
        if tracer.enabled:
            with tracer.span("loop.stage", cat="stage", round=state.round):
                batch = jax.tree.map(jnp.asarray, next_batch())
            with tracer.span("loop.round", cat="dispatch", round=state.round):
                params, server_state, m = sim.run_round(
                    sub,
                    params,
                    server_state,
                    batch,
                    lr,
                    A=A,
                    p=state.p,
                    active=state.active,
                )
            with tracer.span(
                "loop.sync", cat="device", track="device", round=state.round
            ):
                float(m["loss"])  # the loop driver's per-round host sync
        else:
            batch = jax.tree.map(jnp.asarray, next_batch())
            params, server_state, m = sim.run_round(
                sub,
                params,
                server_state,
                batch,
                lr,
                A=A,
                p=state.p,
                active=state.active,
            )
            float(m["loss"])  # the per-round host sync the loop driver models
        all_metrics.append(m)
        if on_round is not None:
            on_round(state.round, params)
    metrics = jax.tree.map(lambda *ms: jnp.stack(ms), *all_metrics)
    return params, server_state, metrics, key
