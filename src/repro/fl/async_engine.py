"""Asynchronous round engine: staleness-weighted aggregation of delayed
client arrivals.

The synchronous drivers (``run_rounds_loop`` and the scan engines) assume
every client's round-r update is available at round r.  In production the
uplink is a queue: updates land after a sampled delay
(:mod:`repro.channels.delay`), and the PS aggregates whatever has *arrived*
— the buffered-async norm, with FedDec (arXiv 2306.06715) as the
semi-decentralized precedent.  :class:`AsyncRoundEngine` models exactly
that while keeping every contract the synchronous stack established:

* **Per-round protocol order is unchanged.**  Each round draws the channel
  state, the policy's relay matrix, one RNG split and one batch in the same
  order as ``run_rounds_loop``, and all n clients compute their local
  update from the *current* broadcast model.  Only the update's arrival at
  the PS is delayed.
* **Freshest-arrival buffer.**  The PS holds one slot per client: the most
  recent arrival's raveled delta row and its OPT-α coefficient (computed at
  the source round, against the source round's channel).  A newer arrival
  supersedes an older one; at aggregation time the K freshest eligible
  slots are selected (``buffer_k=0`` ⇒ all).
* **Staleness-discounted, renormalized weights.**  A slot whose update is
  s rounds old is discounted by ``decay**s`` and the weights renormalize to
  sum to one over the selected slots — so the aggregate stays a convex
  combination of per-source-round OPT-α unbiased increments, and at s=0 the
  weights are exactly the 1/n_active blind weight of the synchronous path.
* **delay=0 ⇒ bitwise-identical to ``run_rounds_loop``** (params, metrics,
  final key), under churn and correlated shadowing included — the discount
  is exactly 1.0 at s=0, the renormalizer reproduces
  ``aggregation.active_weight``'s float ops, and the buffered rows are the
  round's own delta rows unchanged.  Tested in
  ``tests/test_async_engine.py``; the bench harness re-asserts it as the
  mandatory ``async_check`` gate on every async scenario.

Strategy support: ``colrel_fused`` (the production path), ``fedavg_blind``
and ``no_dropout``.  ``colrel`` (unfused) is refused — its mix-then-reduce
association has no buffered form that stays bitwise at delay 0 — and
``fedavg_nonblind`` is refused because its per-round τ-count normalization
does not commute with the staleness renormalization.

Like the loop driver, the engine syncs the host once per round (it must:
arrival scheduling is host-side), so its rounds/sec sits near the loop's —
asynchrony is a *workload* axis, not a throughput one.  The
``async_ttac_500`` bench records the resulting time-to-accuracy against the
synchronous pipelined engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.delay import DelayProcess, ZeroDelays
from repro.core import relay as relay_lib
from repro.kernels import ops as kernel_ops
from repro.obs import NULL_TRACER
from repro.utils.trees import tree_spec, tree_unravel, stacked_ravel

SUPPORTED_STRATEGIES = ("colrel_fused", "fedavg_blind", "no_dropout")


# --------------------------------------------------------------------------
# Pure staleness-weight math (property-tested in tests/test_property.py)
# --------------------------------------------------------------------------


def staleness_discounts(staleness, *, decay: float) -> np.ndarray:
    """Per-slot discount ``decay**s`` as float32, with s=0 mapped to exactly
    1.0 (``where``, not ``power`` — pow(x, 0) is not guaranteed to return
    the literal 1.0 bit pattern on every backend, and the delay-0 bitwise
    contract needs the exact identity weight)."""
    s = np.asarray(staleness)
    d = np.float32(decay) ** s.astype(np.float32)
    return np.where(s == 0, np.float32(1.0), d).astype(np.float32)


def select_freshest(staleness, eligible, k: int) -> np.ndarray:
    """Boolean mask of the ≤k freshest eligible slots (smallest staleness,
    ties broken by client index — deterministic).  ``k=0`` selects every
    eligible slot."""
    stale = np.asarray(staleness)
    elig = np.asarray(eligible, bool)
    if k <= 0 or int(elig.sum()) <= k:
        return elig.copy()
    order = np.lexsort((np.arange(stale.shape[0]), stale))
    sel = np.zeros_like(elig)
    chosen = [j for j in order if elig[j]][:k]
    sel[chosen] = True
    return sel


def staleness_weights(m):
    """Renormalized weight vector from the discount-mask vector ``m``
    (discount × selected × active, zeros elsewhere): ``m / Σm`` computed
    reciprocal-then-multiply, with the all-zero vector mapping to zeros.
    The weights sum to one whenever any slot is selected.  At delay 0 the
    live entries of ``m`` are exactly 1.0, so Σm is the integer-valued
    active count and each live weight is bit-equal to the synchronous
    ``aggregation.active_weight`` 1/n_active (``where`` passes Σm through
    unchanged, exactly as ``maximum(Σ, 1)`` does for Σ ≥ 1)."""
    m = jnp.asarray(m, jnp.float32)
    s = m.sum()
    return m * (1.0 / jnp.where(s > 0, s, jnp.float32(1.0)))


def async_coefficients(A, tau, m, *, n: int, active=None,
                       backend: str = "einsum"):
    """The full async coefficient vector: staleness weights ⊙ the per-slot
    OPT-α base coefficients (``fused_coefficients`` under the same A/τ
    masking as :func:`repro.core.aggregation.colrel_increment_flat`).

    At ``m == active`` (all fresh, all selected) this equals the
    synchronous ``w · τᵀA`` coefficients bitwise; a zero entry of ``m``
    (departed or never-arrived client) forces an exactly-zero coefficient.
    """
    if backend == "segment" and not isinstance(A, relay_lib.EdgeRelay):
        raise ValueError("backend='segment' needs an EdgeRelay operand")
    if backend != "segment" and isinstance(A, relay_lib.EdgeRelay):
        A = A.todense(n)
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        a = jnp.asarray(active, jnp.float32)
        A = relay_lib.mask_relay_matrix(A, a)
        tau = tau * a
    base = relay_lib.fused_coefficients(A, tau)
    return staleness_weights(m) * base


def async_increment_flat(A, tau, m, buf, *, n: int, active=None,
                         backend: str = "einsum", block_d=None,
                         interpret=None):
    """Staleness-weighted ColRel increment over the (n, D) buffer → (D,),
    dispatched through the same backend mapping as the synchronous
    aggregation (einsum/segment → reference reduce, pallas* → fused
    kernel)."""
    coeffs = async_coefficients(A, tau, m, n=n, active=active, backend=backend)
    reduce_backend = (
        "einsum" if backend in ("einsum", "segment") else "pallas_fused"
    )
    return kernel_ops.reduce_flat(
        coeffs, buf, backend=reduce_backend, block_d=block_d,
        interpret=interpret,
    )


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------


class AsyncRoundEngine:
    """Asynchronous per-round driver over an :class:`FLSimulator`.

    ``delays`` is a :class:`repro.channels.delay.DelayProcess` (None ⇒
    :class:`ZeroDelays`, the synchronous reduction).  ``staleness_decay``
    is the per-round discount γ of a buffered update's weight;
    ``buffer_k`` caps aggregation to the K freshest eligible arrivals
    (0 ⇒ no cap).  ``block_d`` / ``interpret`` tune the kernel backends
    exactly as on the simulator.

    State (held buffer, pending arrivals, round index) persists across
    :meth:`run_schedule` calls when ``reset=False`` — the
    :class:`repro.launch.train.ContinuousTrainer` streams indefinitely in
    checkpoint-sized bursts through one engine.  Memory: the pending map
    holds at most ``delays.max_delay`` in-flight (n, D) buffers plus the
    (n, D) held buffer.
    """

    def __init__(self, sim, *, delays: DelayProcess | None = None,
                 staleness_decay: float = 0.8, buffer_k: int = 0,
                 block_d: int | None = None, interpret=None, tracer=None):
        if sim.strategy not in SUPPORTED_STRATEGIES:
            raise ValueError(
                f"AsyncRoundEngine supports strategies {SUPPORTED_STRATEGIES}"
                f", got {sim.strategy!r} ('colrel' reassociates the reduce "
                "and 'fedavg_nonblind' renormalizes per round — neither "
                "composes with staleness weighting)"
            )
        if not (0.0 < staleness_decay <= 1.0):
            raise ValueError(f"staleness_decay must be in (0, 1], got "
                             f"{staleness_decay}")
        if buffer_k < 0:
            raise ValueError(f"buffer_k must be >= 0, got {buffer_k}")
        self.sim = sim
        self.delays = delays if delays is not None else ZeroDelays(sim.n)
        if self.delays.n != sim.n:
            raise ValueError(
                f"delay process is over n={self.delays.n} clients, "
                f"simulator over n={sim.n}"
            )
        self.staleness_decay = staleness_decay
        self.buffer_k = buffer_k
        self.block_d = block_d
        self.interpret = interpret
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.trace_count = 0
        self._reduce_backend = (
            "einsum" if sim.relay_backend in ("einsum", "segment")
            else "pallas_fused"
        )
        self._spec = None
        self._compute = jax.jit(self._compute_impl)
        self._merge = jax.jit(self._merge_impl)
        self._agg = jax.jit(self._agg_impl)
        self._agg_full = jax.jit(self._agg_full_impl)
        self.reset()

    # ---------------------------------------------------------- host state

    def reset(self) -> None:
        """Clear the arrival buffers and rewind the delay stream (cold
        start; the bench harness replays cold/warm passes this way)."""
        n = self.sim.n
        self.delays.reset()
        self._round_index = 0
        self._pending: dict[int, list] = {}
        self._held_round = np.full(n, -1, np.int64)
        self._held_buf = None
        self._held_coeffs = None

    # ---------------------------------------------------------- jitted fns

    def _compute_impl(self, params, batch, tau, A, lr, active):
        """Round-r client compute: local updates (all n slots, fixed
        shapes), the raveled delta buffer, the per-slot base OPT-α
        coefficients against round r's channel, and the round metrics.

        The metrics are computed *here*, in the same compiled program that
        produces the delta buffer, replicating ``_round_math``'s op graph —
        splitting the ‖Δ‖² row-sum and the masked mean across two programs
        denies XLA the fusion the synchronous path gets and shifts the last
        bit of ``delta_norm``."""
        self.trace_count += 1  # python side: runs only on retrace
        sim = self.sim
        deltas, losses = jax.vmap(sim._client_update, in_axes=(None, 0, None))(
            params, batch, lr
        )
        buf, _ = stacked_ravel(deltas)
        tau_m = jnp.asarray(tau, jnp.float32)
        if sim.strategy == "colrel_fused":
            backend = sim.relay_backend
            if backend == "segment" and not isinstance(A, relay_lib.EdgeRelay):
                raise ValueError(
                    "relay_backend='segment' needs an EdgeRelay operand"
                )
            if backend != "segment" and isinstance(A, relay_lib.EdgeRelay):
                A = A.todense(buf.shape[0])
            if active is not None:
                a = jnp.asarray(active, jnp.float32)
                A = relay_lib.mask_relay_matrix(A, a)
                tau_m = tau_m * a
            coeffs = relay_lib.fused_coefficients(A, tau_m)
        elif sim.strategy == "fedavg_blind":
            if active is not None:
                tau_m = tau_m * jnp.asarray(active, jnp.float32)
            coeffs = tau_m
        else:  # no_dropout (τ forced to ones by sample_tau)
            coeffs = (
                jnp.ones_like(tau_m)
                if active is None
                else jnp.asarray(active, jnp.float32)
            )
        # round metrics, op-for-op as in FLSimulator._round_math: they
        # describe round r's local work, not the buffered arrivals
        per_client_dn = jnp.sum(buf * buf, axis=1)
        if active is None:
            mean_loss, dn = jnp.mean(losses), jnp.mean(per_client_dn)
            tau_out = tau
        else:
            a = jnp.asarray(active, jnp.float32)
            denom = jnp.maximum(a.sum(), 1.0)
            mean_loss = jnp.sum(losses * a) / denom
            dn = jnp.sum(per_client_dn * a) / denom
            tau_out = tau * a
        metrics = {
            "loss": mean_loss, "tau": tau_out, "delta_norm": jnp.sqrt(dn)
        }
        return buf, coeffs, metrics

    def _merge_impl(self, mask, src_buf, src_coeffs, held_buf, held_coeffs):
        """Accept the masked rows of an arriving source round into the held
        buffer (``where`` row-select: accepted rows pass through bit-exact)."""
        self.trace_count += 1
        keep = mask > 0
        return (
            jnp.where(keep[:, None], src_buf, held_buf),
            jnp.where(keep, src_coeffs, held_coeffs),
        )

    def _agg_impl(self, params, server_state, m, held_coeffs, held_buf):
        """Staleness-weighted aggregate + server step: the weights
        renormalize over the discount-mask vector m (see
        :func:`staleness_weights`, inlined here so the scalar stays in this
        program)."""
        self.trace_count += 1
        s = m.sum()
        w = 1.0 / jnp.where(s > 0, s, jnp.float32(1.0))
        coeffs = (m * w) * held_coeffs
        flat_inc = kernel_ops.reduce_flat(
            coeffs, held_buf, backend=self._reduce_backend,
            block_d=self.block_d, interpret=self.interpret,
        )
        increment = tree_unravel(self._spec, flat_inc, cast=False)
        return self.sim.server_opt.apply(params, server_state, increment)

    def _agg_full_impl(self, params, server_state, held_coeffs, held_buf):
        """The full-membership synchronous fast path (every slot arrived
        this round, no churn mask, nothing truncated): the weight is the
        *static python* 1/n — the same compiled constant the synchronous
        active=None path uses, keeping delay=0 bitwise there too."""
        self.trace_count += 1
        w = 1.0 / self.sim.n
        coeffs = w * held_coeffs
        flat_inc = kernel_ops.reduce_flat(
            coeffs, held_buf, backend=self._reduce_backend,
            block_d=self.block_d, interpret=self.interpret,
        )
        increment = tree_unravel(self._spec, flat_inc, cast=False)
        return self.sim.server_opt.apply(params, server_state, increment)

    # ------------------------------------------------------- host plumbing

    def _schedule_arrivals(self, t: int, d: np.ndarray, buf, coeffs) -> None:
        for delay in np.unique(d):
            idx = np.nonzero(d == delay)[0]
            self._pending.setdefault(t + int(delay), []).append(
                (idx, buf, coeffs, t)
            )

    def _deliver(self, t: int) -> tuple[int, int]:
        """Merge every arrival due at round t into the held buffer; newest
        source round wins.  Returns (accepted, superseded)."""
        entries = self._pending.pop(t, [])
        entries.sort(key=lambda e: e[3])  # oldest source first
        accepted = superseded = 0
        for idx, buf, coeffs, src in entries:
            take = idx[self._held_round[idx] < src]
            superseded += idx.size - take.size
            if take.size == 0:
                continue
            mask = np.zeros(self.sim.n, np.float32)
            mask[take] = 1.0
            self._held_buf, self._held_coeffs = self._merge(
                jnp.asarray(mask), buf, coeffs,
                self._held_buf, self._held_coeffs,
            )
            self._held_round[take] = src
            accepted += int(take.size)
        return accepted, superseded

    def _staleness_mask(self, t: int, active):
        """Host-side per-round weighting inputs: the discount-mask vector m
        (discount × selected × active, zero for never-arrived slots), the
        buffer depth, and whether the round is exactly synchronous (the
        static-weight fast path)."""
        n = self.sim.n
        arrived = self._held_round >= 0
        stale = t - self._held_round
        act = (
            np.ones(n, bool) if active is None
            else np.asarray(active).astype(bool)
        )
        elig = arrived & act
        sel = select_freshest(stale, elig, self.buffer_k)
        disc = staleness_discounts(stale, decay=self.staleness_decay)
        m = np.where(sel, disc, np.float32(0.0)).astype(np.float32)
        full_sync = bool(
            active is None and elig.all() and (stale == 0).all() and sel.all()
        )
        stats = {
            "depth": int(elig.sum()),
            "selected": int(sel.sum()),
            "max_staleness": int(stale[sel].max()) if sel.any() else 0,
        }
        return m, full_sync, stats

    # ------------------------------------------------------------- driving

    def run_schedule(self, key, params, server_state, *, schedule, rounds,
                     next_batch, lr, policy=None, on_round=None,
                     reset: bool = True):
        """Drive a :class:`ChannelSchedule` for ``rounds`` asynchronous
        rounds.  Same signature and return contract as ``run_rounds_loop``
        (``(params, server_state, metrics, key)``; ``on_round(round,
        params)`` per round); ``reset=False`` continues the arrival stream
        from the previous call (continuous-training bursts)."""
        if reset:
            self.reset()
        if self._spec is None:
            self._spec = tree_spec(params)
        if self._held_buf is None:
            n, D = self.sim.n, self._spec.total
            self._held_buf = jnp.zeros((n, D), jnp.float32)
            self._held_coeffs = jnp.zeros((n,), jnp.float32)
        all_metrics = []
        for state in schedule.rounds(rounds):
            t = self._round_index
            A = policy.relay_matrix(state) if policy is not None else None
            A_round = (
                self.sim.A if A is None
                else relay_lib.as_relay_operand(
                    A, n=self.sim.n, backend=self.sim.relay_backend
                )
            )
            key, sub = jax.random.split(key)
            batch = jax.tree.map(jnp.asarray, next_batch())
            tau = self.sim.sample_tau(sub, state.p)
            active = (
                None if state.active is None
                else jnp.asarray(state.active, jnp.float32)
            )
            if self.tracer.enabled:
                with self.tracer.span("async.round", cat="dispatch", round=t):
                    out, stats = self._round_step(
                        t, state, params, server_state, batch, tau,
                        A_round, lr, active,
                    )
                    params, server_state, metrics = out
                self.tracer.instant(
                    "async.buffer", cat="stage", round=t, **stats
                )
                self.tracer.count("async.rounds")
                self.tracer.count("async.arrivals", stats["arrivals"])
                self.tracer.count("async.selected", stats["selected"])
                if stats["superseded"]:
                    self.tracer.count("async.superseded", stats["superseded"])
            else:
                out, stats = self._round_step(
                    t, state, params, server_state, batch, tau,
                    A_round, lr, active,
                )
                params, server_state, metrics = out
            float(metrics["loss"])  # per-round host sync, like the loop
            all_metrics.append(metrics)
            if on_round is not None:
                on_round(state.round, params)
            self._round_index += 1
        metrics = jax.tree.map(lambda *ms: jnp.stack(ms), *all_metrics)
        return params, server_state, metrics, key

    def _round_step(self, t, state, params, server_state, batch, tau,
                    A_round, lr, active):
        buf, coeffs, metrics = self._compute(
            params, batch, tau, A_round, lr, active
        )
        d = self.delays.sample()
        self._schedule_arrivals(t, d, buf, coeffs)
        arrivals, superseded = self._deliver(t)
        m, full_sync, stats = self._staleness_mask(t, state.active)
        stats["arrivals"] = arrivals
        stats["superseded"] = superseded
        if full_sync:
            params, server_state = self._agg_full(
                params, server_state, self._held_coeffs, self._held_buf
            )
        else:
            params, server_state = self._agg(
                params, server_state, jnp.asarray(m), self._held_coeffs,
                self._held_buf,
            )
        return (params, server_state, metrics), stats
