"""Distributed ColRel round step for the production mesh (dry-run + launcher).

Clients map to the mesh's client axes (``data``, or ``pod × data`` multi-pod);
each client's model/compute shards over ``model`` (and ``data`` for FSDP
archs).  Batches arrive stacked (n_clients, T, local_batch, ...).

Two relay schedules compute the identical PS update (DESIGN.md §2):

  * ``faithful``: per-client Δx materialized, local consensus Δx̃ = A·Δx
    (GSPMD lowers the client-dim einsum to all-gathers — the D2D exchange),
    then the blind masked PS sum.  Mirrors the paper's physical protocol.
  * ``fused``: PS ∘ relay fused to one weighted reduce with c = τᵀA.  With
    T = 1 the weighted per-client gradient sum is formed directly, so no
    per-client full-parameter tensor ever exists.  Beyond-paper optimization.

τ is sampled on the host per round and passed in — the step itself is
deterministic and identity-blind (OAC-compatible).  The exception is
:func:`build_fused_scan_round_step`, the pipelined engine's mesh analogue:
it takes the RNG key instead and draws the epoch's τ stream inside the scan
body (key chain in the carry), so a whole epoch — τ draws included — is one
device dispatch.

:func:`build_sharded_scan_round_step` is the **multi-device** production
path (same τ-fused signature): under ``shard="clients"`` the step runs in
`shard_map` over the mesh's client axis — each device owns m = n/k client
slots, runs their local SGD, and the relay exchange is either an
``all_gather`` of the raveled delta blocks (bitwise-identical math to the
single-device step) or the block-ring collective from `repro.fl.ring`
(O(1) live buffers, f32-tolerance-identical).  Under ``shard="d"`` the step
stays GSPMD: a sharding constraint from `repro.sharding.rules` partitions
the (n, D) relay contraction over the model axis.  See docs/distributed.md.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import aggregation, relay as relay_lib
from repro.core.aggregation import ServerOpt, active_weight
from repro.optim.sgd import ClientOpt
from repro.utils import stacked_ravel, tree_scale, tree_sub, tree_unravel


def build_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
    constrain_buffer: Callable | None = None,
):
    """Returns round(params, server_state, batch, tau, lr, A=None, active=None)
    -> (params', state', loss).

    batch leaves: (n_clients, local_steps, per_client_batch, ...).

    The relay matrix may be bound at build time (static channel: it folds into
    the compiled step as a constant) or passed per call (time-varying channel:
    it is a traced input, so swapping A values between rounds does not retrace
    a jitted ``round``).  The call-time A wins when both are given.

    ``active`` is the churn mask over the padded client dimension
    (``n_clients = n_max``): a traced (n,) 0/1 vector restricting the relay
    matrix, τ and the blind weight (1/n_active) to the live clients, so
    membership changes between calls never retrace.  ``None`` keeps the
    static-weight fixed-membership path.

    ``relay_backend`` dispatches the relay∘aggregate contraction over the
    raveled (n, D) delta buffer to the Pallas kernels (see
    ``repro.core.aggregation.colrel_increment_flat``).  It applies wherever
    per-client deltas are materialized — every path except T = 1 fused, whose
    weighted-loss trick never forms an (n, D) tensor to stream (there is
    nothing for a kernel to read, so that path stays pure XLA).

    ``constrain_buffer`` (D-axis sharding) is applied to the raveled (n, D)
    buffer right after the ravel — `build_sharded_scan_round_step(shard="d")`
    passes a `with_sharding_constraint` over the mesh's model axis here, so
    GSPMD partitions the relay contraction over parameters.
    """
    T = local_steps
    A_static = A
    aggregation_kw = dict(
        backend=relay_backend, block_d=block_d, interpret=interpret
    )

    def round(params, server_state, batch, tau, lr, A=None, active=None):
        A = A_static if A is None else A
        if A is None:
            raise ValueError("no relay matrix: bind A at build time or pass it")

        def _mean_loss(losses):
            if active is None:
                return jnp.mean(losses)
            a_ = jnp.asarray(active, jnp.float32)
            return jnp.sum(losses * a_) / jnp.maximum(a_.sum(), 1.0)

        def _flat_increment(deltas):
            # ravel → kernel-dispatched increment → structured f32 view;
            # churn masking (A, τ, 1/n_active) happens inside the flat fn
            buf, spec = stacked_ravel(deltas)
            if constrain_buffer is not None:
                buf = constrain_buffer(buf)
            flat = aggregation.colrel_increment_flat(
                A, tau, buf, n=n_clients, fused=(relay_mode == "fused"),
                active=active, **aggregation_kw,
            )
            return tree_unravel(spec, flat, cast=False)

        if T == 1 and relay_mode == "fused":
            # never materialize per-client deltas: weighted loss trick —
            # Σ_o c_o Δ_o = -lr · ∇ Σ_o c_o L_o(x)  (+ wd term)
            w = active_weight(active, n=n_clients)
            A_f, tau_f = A, tau
            if active is not None:
                a = jnp.asarray(active, jnp.float32)
                A_f = relay_lib.mask_relay_matrix(A, a)
                tau_f = jnp.asarray(tau, jnp.float32) * a
            c = relay_lib.fused_coefficients(A_f, tau_f)  # (n,)

            def weighted_loss(p):
                sq = jax.tree.map(lambda x: x[:, 0], batch)  # (n, b, ...)
                losses = jax.vmap(lambda b_: loss_fn(p, b_))(sq)
                return jnp.sum(c * losses), losses

            (_, losses), gsum = jax.value_and_grad(weighted_loss, has_aux=True)(
                params
            )
            csum = jnp.sum(c)

            def _fused_inc(gs, pe):
                wd = csum * client_opt.weight_decay * pe.astype(jnp.float32)
                return -lr * w * (gs.astype(jnp.float32) + wd)

            inc = jax.tree.map(_fused_inc, gsum, params)
            mean_loss = _mean_loss(losses)
        elif T == 1:
            # deltas_g: stacked decayed grads (n, ...); Δ_i = -lr · g_i
            def one(client_batch):
                sq = jax.tree.map(lambda x: x[0], client_batch)
                loss, g = jax.value_and_grad(loss_fn)(params, sq)

                def _decayed(ge, pe):
                    wd = client_opt.weight_decay
                    return ge.astype(jnp.float32) + wd * pe.astype(jnp.float32)

                return jax.tree.map(_decayed, g, params), loss

            deltas_g, losses = jax.vmap(one)(batch)
            inc = _flat_increment(tree_scale(-lr, deltas_g))
            mean_loss = _mean_loss(losses)
        else:

            def client_update(client_batch):
                opt_state = client_opt.init(params)

                def step(carry, minibatch):
                    p, s = carry
                    loss, g = jax.value_and_grad(loss_fn)(p, minibatch)
                    p, s = client_opt.step(p, g, s, lr)
                    return (p, s), loss

                (new_p, _), losses = jax.lax.scan(
                    step, (params, opt_state), client_batch
                )
                return tree_sub(new_p, params), losses[0]

            deltas, losses = jax.vmap(client_update)(batch)
            mean_loss = _mean_loss(losses)
            inc = _flat_increment(deltas)

        new_params, new_state = server_opt.apply(params, server_state, inc)
        return new_params, new_state, mean_loss

    return round


def build_scan_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
):
    """Epoch-fused variant of :func:`build_round_step`: returns
    ``scan_rounds(params, server_state, batches, taus, lr, A=None,
    active=None) -> (params', state', losses)`` running R rounds in one
    ``lax.scan`` — one dispatch per channel epoch instead of per round.

    ``batches`` leaves are stacked (R, n_clients, local_steps, b, ...) and
    ``taus`` is (R, n_clients); A and the churn mask are loop-invariant
    traced inputs (constant within an epoch, by definition of an epoch).
    The scan body *is* the single-round step, so R sequential calls of the
    per-round function produce bit-identical results.
    """
    round = build_round_step(
        loss_fn,
        n_clients=n_clients,
        local_steps=local_steps,
        A=A,
        relay_mode=relay_mode,
        relay_backend=relay_backend,
        block_d=block_d,
        interpret=interpret,
        client_opt=client_opt,
        server_opt=server_opt,
    )

    def scan_rounds(params, server_state, batches, taus, lr, A=None, active=None):
        def body(carry, xs):
            p, s = carry
            batch, tau = xs
            p, s, loss = round(p, s, batch, tau, lr, A=A, active=active)
            return (p, s), loss

        (params, server_state), losses = jax.lax.scan(
            body, (params, server_state), (batches, taus)
        )
        return params, server_state, losses

    return scan_rounds


def build_fused_scan_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
    constrain_buffer: Callable | None = None,
):
    """τ-in-body variant of :func:`build_scan_round_step` (the pipelined
    engine's mesh analogue): returns ``scan_rounds(key, params,
    server_state, batches, p, lr, A=None, active=None) -> (key', params',
    state', losses)``.

    Instead of a host-sampled ``taus`` block, the step takes the RNG key and
    the uplink marginals ``p`` and draws each round's τ inside the scan body
    — per round: split the chain, ``Bernoulli(p)`` on the subkey — exactly
    the per-round driver's op order, so the realized τ stream (and the
    returned advanced key) are bit-identical to R sequential host draws.
    One device dispatch covers the whole epoch, τ included, and the key
    chain never leaves the device between epochs.
    """
    round = build_round_step(
        loss_fn,
        n_clients=n_clients,
        local_steps=local_steps,
        A=A,
        relay_mode=relay_mode,
        relay_backend=relay_backend,
        block_d=block_d,
        interpret=interpret,
        client_opt=client_opt,
        server_opt=server_opt,
        constrain_buffer=constrain_buffer,
    )

    def scan_rounds(key, params, server_state, batches, p, lr, A=None, active=None):
        def body(carry, batch):
            k, pr, s = carry
            k, sub = jax.random.split(k)
            tau = jax.random.bernoulli(sub, p).astype(jnp.float32)
            pr, s, loss = round(pr, s, batch, tau, lr, A=A, active=active)
            return (k, pr, s), loss

        (key, params, server_state), losses = jax.lax.scan(
            body, (key, params, server_state), batches
        )
        return key, params, server_state, losses

    return scan_rounds


def build_sharded_scan_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    mesh,
    shard: str = "clients",
    exchange: str = "gather",
    relay_mode: str = "fused",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
):
    """Multi-device variant of :func:`build_fused_scan_round_step`: same
    signature ``scan_rounds(key, params, server_state, batches, p, lr,
    A=None, active=None) -> (key', params', state', losses)``, executed
    across ``mesh``.

    ``shard="clients"`` runs the scan body in `shard_map` over the mesh's
    client axis: each of the k devices owns ``m = n_clients / k`` client
    slots (``batches`` leaves (R, n_clients, T, b, ...) sharded on dim 1),
    runs their local SGD steps, and exchanges raveled delta blocks —

    * ``exchange="gather"``: ``all_gather`` the (m, D) blocks to the full
      (n, D) buffer and reuse ``aggregation.colrel_increment_flat``
      verbatim.  Same contraction, same order ⇒ the trajectory is
      *bitwise-identical* to the single-device step.
    * ``exchange="ring"``: the block-ring collective
      (`repro.fl.ring.ring_colrel_increment_flat`): k−1 ``ppermute``
      rotations, each contributing an (m, m) block-matmul, then a τ-weighted
      ``psum``.  O(1) live buffers, but ring accumulation order ≠ einsum
      contraction order ⇒ identical only to f32 accumulation accuracy
      (documented tolerance; see docs/distributed.md).

    Model parameters, the RNG key, A, p and the churn mask stay replicated;
    every device draws the *same* τ from the same key chain, so the realized
    randomness — and the returned advanced key — match the single-device
    fused step exactly.  Churn masking composes unchanged: A and τ are
    masked before the exchange, so a departed client's block contributes
    exactly zero on either exchange.

    ``shard="d"`` keeps the single-program GSPMD formulation and shards the
    *parameter* axis instead: a `sharding.rules.flat_buffer_specs`
    constraint on the raveled (n, D) buffer partitions the relay
    contraction over the mesh's "model" axis (for models too large to
    replicate).  einsum backend only (`kernels.ops.validate_sharded_backend`).
    """
    from repro.fl import ring as ring_lib
    from repro.kernels import ops as kernel_ops
    from repro.sharding import rules as sharding_rules

    kernel_ops.validate_sharded_backend(
        relay_backend, shard=shard, exchange=exchange
    )
    if shard == "d":
        from jax.sharding import NamedSharding

        def constrain(buf):
            spec = sharding_rules.flat_buffer_specs(
                mesh, n=buf.shape[0], d=buf.shape[1]
            )
            return jax.lax.with_sharding_constraint(
                buf, NamedSharding(mesh, spec)
            )

        return build_fused_scan_round_step(
            loss_fn,
            n_clients=n_clients,
            local_steps=local_steps,
            relay_mode=relay_mode,
            relay_backend=relay_backend,
            block_d=block_d,
            interpret=interpret,
            client_opt=client_opt,
            server_opt=server_opt,
            constrain_buffer=constrain,
        )
    if shard != "clients":
        raise ValueError(f"unknown shard mode: {shard!r} (clients | d)")
    if exchange not in ("gather", "ring"):
        raise ValueError(f"unknown exchange: {exchange!r} (gather | ring)")

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = sharding_rules.shard_axis(mesh)
    k_shards = mesh.shape[axis]
    if n_clients % k_shards != 0:
        raise ValueError(
            f"n_clients={n_clients} not divisible by the {k_shards}-device "
            f"client axis {axis!r}"
        )
    T = local_steps

    def _mean_loss(losses, active):
        if active is None:
            return jnp.mean(losses)
        a_ = jnp.asarray(active, jnp.float32)
        return jnp.sum(losses * a_) / jnp.maximum(a_.sum(), 1.0)

    def _local_scan(key, params, server_state, batches, p, lr, A, active):
        # inside shard_map: batches leaves are this device's (R, m, T, b, ...)
        # client shard; everything else is replicated.
        def body(carry, batch):
            kcur, pr, s = carry
            kcur, sub = jax.random.split(kcur)
            tau = jax.random.bernoulli(sub, p).astype(jnp.float32)

            if T == 1:
                def one(client_batch):
                    sq = jax.tree.map(lambda x: x[0], client_batch)
                    loss, g = jax.value_and_grad(loss_fn)(pr, sq)

                    def _decayed(ge, pe):
                        wd = client_opt.weight_decay
                        return ge.astype(jnp.float32) + wd * pe.astype(
                            jnp.float32
                        )

                    return jax.tree.map(_decayed, g, pr), loss

                deltas_g, losses = jax.vmap(one)(batch)
                deltas = tree_scale(-lr, deltas_g)
            else:
                def client_update(client_batch):
                    opt_state = client_opt.init(pr)

                    def step(c, minibatch):
                        p_, s_ = c
                        loss, g = jax.value_and_grad(loss_fn)(p_, minibatch)
                        p_, s_ = client_opt.step(p_, g, s_, lr)
                        return (p_, s_), loss

                    (new_p, _), losses = jax.lax.scan(
                        step, (pr, opt_state), client_batch
                    )
                    return tree_sub(new_p, pr), losses[0]

                deltas, losses = jax.vmap(client_update)(batch)

            buf_local, spec = stacked_ravel(deltas)  # (m, D)
            if exchange == "gather":
                buf = jax.lax.all_gather(buf_local, axis, axis=0, tiled=True)
                flat = aggregation.colrel_increment_flat(
                    A, tau, buf, n=n_clients, fused=(relay_mode == "fused"),
                    active=active, backend=relay_backend, block_d=block_d,
                    interpret=interpret,
                )
            else:
                w = active_weight(active, n=n_clients)
                A_eff, tau_eff = A, tau
                if active is not None:
                    a = jnp.asarray(active, jnp.float32)
                    A_eff = relay_lib.mask_relay_matrix(A, a)
                    tau_eff = tau * a
                flat = ring_lib.ring_colrel_increment_flat(
                    A_eff, tau_eff, buf_local, w=w, axis_name=axis,
                    n_shards=k_shards,
                )
            inc = tree_unravel(spec, flat, cast=False)
            losses_all = jax.lax.all_gather(losses, axis, axis=0, tiled=True)
            mean_loss = _mean_loss(losses_all, active)
            pr, s = server_opt.apply(pr, s, inc)
            return (kcur, pr, s), mean_loss

        (key, params, server_state), losses = jax.lax.scan(
            body, (key, params, server_state), batches
        )
        return key, params, server_state, losses

    def scan_rounds(key, params, server_state, batches, p, lr, A=None, active=None):
        if A is None:
            raise ValueError("no relay matrix: pass A per call")
        batch_specs = jax.tree.map(
            lambda x: P(None, axis, *([None] * (x.ndim - 2))), batches
        )
        rep = lambda tree: jax.tree.map(lambda x: P(), tree)  # noqa: E731
        in_specs = (
            P(),            # key chain (replicated: every device draws the same τ)
            rep(params),
            rep(server_state),
            batch_specs,
            P(),            # p
            P(),            # lr
            P(),            # A
            P() if active is not None else rep(active),
        )
        out_specs = (P(), rep(params), rep(server_state), P())
        return shard_map(
            _local_scan,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )(key, params, server_state, batches, p, lr, A, active)

    return scan_rounds
