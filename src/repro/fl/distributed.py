"""Distributed ColRel round step for the production mesh (dry-run + launcher).

Clients map to the mesh's client axes (``data``, or ``pod × data`` multi-pod);
each client's model/compute shards over ``model`` (and ``data`` for FSDP
archs).  Batches arrive stacked (n_clients, T, local_batch, ...).

Two relay schedules compute the identical PS update (DESIGN.md §2):

  * ``faithful``: per-client Δx materialized, local consensus Δx̃ = A·Δx
    (GSPMD lowers the client-dim einsum to all-gathers — the D2D exchange),
    then the blind masked PS sum.  Mirrors the paper's physical protocol.
  * ``fused``: PS ∘ relay fused to one weighted reduce with c = τᵀA.  With
    T = 1 the weighted per-client gradient sum is formed directly, so no
    per-client full-parameter tensor ever exists.  Beyond-paper optimization.

τ is sampled on the host per round and passed in — the step itself is
deterministic and identity-blind (OAC-compatible).  The exception is
:func:`build_fused_scan_round_step`, the pipelined engine's mesh analogue:
it takes the RNG key instead and draws the epoch's τ stream inside the scan
body (key chain in the carry), so a whole epoch — τ draws included — is one
device dispatch.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import aggregation, relay as relay_lib
from repro.core.aggregation import ServerOpt, active_weight
from repro.optim.sgd import ClientOpt
from repro.utils import stacked_ravel, tree_scale, tree_sub, tree_unravel


def build_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
):
    """Returns round(params, server_state, batch, tau, lr, A=None, active=None)
    -> (params', state', loss).

    batch leaves: (n_clients, local_steps, per_client_batch, ...).

    The relay matrix may be bound at build time (static channel: it folds into
    the compiled step as a constant) or passed per call (time-varying channel:
    it is a traced input, so swapping A values between rounds does not retrace
    a jitted ``round``).  The call-time A wins when both are given.

    ``active`` is the churn mask over the padded client dimension
    (``n_clients = n_max``): a traced (n,) 0/1 vector restricting the relay
    matrix, τ and the blind weight (1/n_active) to the live clients, so
    membership changes between calls never retrace.  ``None`` keeps the
    static-weight fixed-membership path.

    ``relay_backend`` dispatches the relay∘aggregate contraction over the
    raveled (n, D) delta buffer to the Pallas kernels (see
    ``repro.core.aggregation.colrel_increment_flat``).  It applies wherever
    per-client deltas are materialized — every path except T = 1 fused, whose
    weighted-loss trick never forms an (n, D) tensor to stream (there is
    nothing for a kernel to read, so that path stays pure XLA).
    """
    T = local_steps
    A_static = A
    aggregation_kw = dict(
        backend=relay_backend, block_d=block_d, interpret=interpret
    )

    def round(params, server_state, batch, tau, lr, A=None, active=None):
        A = A_static if A is None else A
        if A is None:
            raise ValueError("no relay matrix: bind A at build time or pass it")

        def _mean_loss(losses):
            if active is None:
                return jnp.mean(losses)
            a_ = jnp.asarray(active, jnp.float32)
            return jnp.sum(losses * a_) / jnp.maximum(a_.sum(), 1.0)

        def _flat_increment(deltas):
            # ravel → kernel-dispatched increment → structured f32 view;
            # churn masking (A, τ, 1/n_active) happens inside the flat fn
            buf, spec = stacked_ravel(deltas)
            flat = aggregation.colrel_increment_flat(
                A, tau, buf, n=n_clients, fused=(relay_mode == "fused"),
                active=active, **aggregation_kw,
            )
            return tree_unravel(spec, flat, cast=False)

        if T == 1 and relay_mode == "fused":
            # never materialize per-client deltas: weighted loss trick —
            # Σ_o c_o Δ_o = -lr · ∇ Σ_o c_o L_o(x)  (+ wd term)
            w = active_weight(active, n=n_clients)
            A_f, tau_f = A, tau
            if active is not None:
                a = jnp.asarray(active, jnp.float32)
                A_f = relay_lib.mask_relay_matrix(A, a)
                tau_f = jnp.asarray(tau, jnp.float32) * a
            c = relay_lib.fused_coefficients(A_f, tau_f)  # (n,)

            def weighted_loss(p):
                sq = jax.tree.map(lambda x: x[:, 0], batch)  # (n, b, ...)
                losses = jax.vmap(lambda b_: loss_fn(p, b_))(sq)
                return jnp.sum(c * losses), losses

            (_, losses), gsum = jax.value_and_grad(weighted_loss, has_aux=True)(
                params
            )
            csum = jnp.sum(c)

            def _fused_inc(gs, pe):
                wd = csum * client_opt.weight_decay * pe.astype(jnp.float32)
                return -lr * w * (gs.astype(jnp.float32) + wd)

            inc = jax.tree.map(_fused_inc, gsum, params)
            mean_loss = _mean_loss(losses)
        elif T == 1:
            # deltas_g: stacked decayed grads (n, ...); Δ_i = -lr · g_i
            def one(client_batch):
                sq = jax.tree.map(lambda x: x[0], client_batch)
                loss, g = jax.value_and_grad(loss_fn)(params, sq)

                def _decayed(ge, pe):
                    wd = client_opt.weight_decay
                    return ge.astype(jnp.float32) + wd * pe.astype(jnp.float32)

                return jax.tree.map(_decayed, g, params), loss

            deltas_g, losses = jax.vmap(one)(batch)
            inc = _flat_increment(tree_scale(-lr, deltas_g))
            mean_loss = _mean_loss(losses)
        else:

            def client_update(client_batch):
                opt_state = client_opt.init(params)

                def step(carry, minibatch):
                    p, s = carry
                    loss, g = jax.value_and_grad(loss_fn)(p, minibatch)
                    p, s = client_opt.step(p, g, s, lr)
                    return (p, s), loss

                (new_p, _), losses = jax.lax.scan(
                    step, (params, opt_state), client_batch
                )
                return tree_sub(new_p, params), losses[0]

            deltas, losses = jax.vmap(client_update)(batch)
            mean_loss = _mean_loss(losses)
            inc = _flat_increment(deltas)

        new_params, new_state = server_opt.apply(params, server_state, inc)
        return new_params, new_state, mean_loss

    return round


def build_scan_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
):
    """Epoch-fused variant of :func:`build_round_step`: returns
    ``scan_rounds(params, server_state, batches, taus, lr, A=None,
    active=None) -> (params', state', losses)`` running R rounds in one
    ``lax.scan`` — one dispatch per channel epoch instead of per round.

    ``batches`` leaves are stacked (R, n_clients, local_steps, b, ...) and
    ``taus`` is (R, n_clients); A and the churn mask are loop-invariant
    traced inputs (constant within an epoch, by definition of an epoch).
    The scan body *is* the single-round step, so R sequential calls of the
    per-round function produce bit-identical results.
    """
    round = build_round_step(
        loss_fn,
        n_clients=n_clients,
        local_steps=local_steps,
        A=A,
        relay_mode=relay_mode,
        relay_backend=relay_backend,
        block_d=block_d,
        interpret=interpret,
        client_opt=client_opt,
        server_opt=server_opt,
    )

    def scan_rounds(params, server_state, batches, taus, lr, A=None, active=None):
        def body(carry, xs):
            p, s = carry
            batch, tau = xs
            p, s, loss = round(p, s, batch, tau, lr, A=A, active=active)
            return (p, s), loss

        (params, server_state), losses = jax.lax.scan(
            body, (params, server_state), (batches, taus)
        )
        return params, server_state, losses

    return scan_rounds


def build_fused_scan_round_step(
    loss_fn: Callable[[Any, dict], jax.Array],
    *,
    n_clients: int,
    local_steps: int,
    A=None,
    relay_mode: str = "faithful",
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
    client_opt: ClientOpt = ClientOpt(kind="sgd", weight_decay=1e-4),
    server_opt: ServerOpt = ServerOpt(),
):
    """τ-in-body variant of :func:`build_scan_round_step` (the pipelined
    engine's mesh analogue): returns ``scan_rounds(key, params,
    server_state, batches, p, lr, A=None, active=None) -> (key', params',
    state', losses)``.

    Instead of a host-sampled ``taus`` block, the step takes the RNG key and
    the uplink marginals ``p`` and draws each round's τ inside the scan body
    — per round: split the chain, ``Bernoulli(p)`` on the subkey — exactly
    the per-round driver's op order, so the realized τ stream (and the
    returned advanced key) are bit-identical to R sequential host draws.
    One device dispatch covers the whole epoch, τ included, and the key
    chain never leaves the device between epochs.
    """
    round = build_round_step(
        loss_fn,
        n_clients=n_clients,
        local_steps=local_steps,
        A=A,
        relay_mode=relay_mode,
        relay_backend=relay_backend,
        block_d=block_d,
        interpret=interpret,
        client_opt=client_opt,
        server_opt=server_opt,
    )

    def scan_rounds(key, params, server_state, batches, p, lr, A=None, active=None):
        def body(carry, batch):
            k, pr, s = carry
            k, sub = jax.random.split(k)
            tau = jax.random.bernoulli(sub, p).astype(jnp.float32)
            pr, s, loss = round(pr, s, batch, tau, lr, A=A, active=active)
            return (k, pr, s), loss

        (key, params, server_state), losses = jax.lax.scan(
            body, (key, params, server_state), batches
        )
        return key, params, server_state, losses

    return scan_rounds
