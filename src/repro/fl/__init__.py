from repro.fl.simulator import FLSimulator
