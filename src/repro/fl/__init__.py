from repro.fl.async_engine import AsyncRoundEngine
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator

__all__ = [
    "AsyncRoundEngine",
    "EpochScanEngine",
    "FLSimulator",
    "PipelinedScanEngine",
    "run_rounds_loop",
]
