from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator

__all__ = [
    "EpochScanEngine",
    "FLSimulator",
    "PipelinedScanEngine",
    "run_rounds_loop",
]
