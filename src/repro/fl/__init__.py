from repro.fl.engine import EpochScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator

__all__ = ["EpochScanEngine", "FLSimulator", "run_rounds_loop"]
