"""Pallas TPU kernel for the ColRel hot spot: Δ̃ = A · Δ (and the fused
τ-weighted PS reduction).

Shape regime: A is tiny ((n, n), n ≤ 128 clients) and Δ is enormous
((n, D), D = total model parameters, 10⁶–10¹¹).  The kernel keeps A resident
in VMEM for the whole launch and streams Δ through in (n, block_d) tiles —
one HBM read + one HBM write per element, with the (n×n)·(n×block_d) MXU
matmul per tile.  block_d is a multiple of 128 (lane granule) sized so the
three live buffers (A, Δ-tile, out-tile) stay ≪ 16 MB VMEM.

The fused variant computes  u = (w·τᵀA) · Δ  — the relay∘aggregate
composition (DESIGN.md §2) — reading Δ once and writing only (1, block_d)
per tile: an n× reduction in write traffic vs relay-then-reduce.

Validated in interpret mode against ``ref.py`` across shape/dtype sweeps
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 4096


def _mix_kernel(a_ref, d_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], d_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _fused_kernel(c_ref, d_ref, o_ref):
    o_ref[...] = jnp.dot(
        c_ref[...], d_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _relay_mix_core(A, delta, block_d: int, interpret: bool):
    n, D = delta.shape
    Dp = -(-D // block_d) * block_d
    if Dp != D:
        delta = jnp.pad(delta, ((0, 0), (0, Dp - D)))
    out = pl.pallas_call(
        _mix_kernel,
        grid=(Dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),  # A resident
            pl.BlockSpec((n, block_d), lambda j: (0, j)),  # Δ streamed
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, Dp), delta.dtype),
        interpret=interpret,
    )(A.astype(delta.dtype), delta)
    return out[:, :D]


def _relay_mix_fwd(A, delta, block_d, interpret):
    return _relay_mix_core(A, delta, block_d, interpret), (A, delta)


def _relay_mix_bwd(block_d, interpret, res, g):
    # the mix is linear: ∂/∂Δ = Aᵀ g (run the same kernel with Aᵀ);
    # ∂/∂A = g Δᵀ is a small (n, n) reduction.
    A, delta = res
    ddelta = _relay_mix_core(A.T, g, block_d, interpret)
    dA = jnp.einsum(
        "rd,od->ro", g.astype(jnp.float32), delta.astype(jnp.float32)
    ).astype(A.dtype)
    return dA, ddelta


_relay_mix_core.defvjp(_relay_mix_fwd, _relay_mix_bwd)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def relay_mix_2d(A, delta, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """Δ̃ = A @ Δ for Δ of shape (n, D); D padded to a block_d multiple."""
    return _relay_mix_core(A, delta, block_d, interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_aggregate_2d(
    coeffs, delta, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = True
):
    """u = coeffs @ Δ  (coeffs = w·τᵀA, shape (n,)) → (D,)."""
    n, D = delta.shape
    Dp = -(-D // block_d) * block_d
    if Dp != D:
        delta = jnp.pad(delta, ((0, 0), (0, Dp - D)))
    out = pl.pallas_call(
        _fused_kernel,
        grid=(Dp // block_d,),
        in_specs=[
            pl.BlockSpec((1, n), lambda j: (0, 0)),
            pl.BlockSpec((n, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), delta.dtype),
        interpret=interpret,
    )(coeffs.reshape(1, n).astype(delta.dtype), delta)
    return out[0, :D]
