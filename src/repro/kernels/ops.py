"""Jitted public wrappers: relay mixing over parameter *pytrees* backed by the
Pallas kernels.  Leaves are flattened to (n, leaf_size) tiles, streamed
through the kernel, and restored — so the single-host simulator can run the
whole D2D consensus as one fused kernel pass per leaf.

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips on TPU backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import relay as relay_lib
from repro.kernels import ref as _ref
from repro.kernels import relay_mix as _k

# the relay_backend knob (make_aggregator / build_round_step / scenarios):
#   einsum        pure-XLA reference path (ref.py oracles on the flat buffer)
#   pallas        kernel mix Δ̃ = A·Δ; the PS reduction stays an einsum
#   pallas_fused  kernel u = (w·τᵀA)·Δ — relay∘aggregate in one pass, the
#                 n×-less-write-traffic hot path
#   segment       sparse edge-list path (core.relay.EdgeRelay +
#                 jax.ops.segment_sum): relay∘aggregate cost scales with the
#                 edge count E, not n² — the n ≫ 10³ cohort-sampling regime
RELAY_BACKENDS = ("einsum", "pallas", "pallas_fused", "segment")


def validate_backend(backend: str) -> str:
    if backend not in RELAY_BACKENDS:
        raise ValueError(f"unknown relay_backend {backend!r} (known: {RELAY_BACKENDS})")
    return backend


def validate_sharded_backend(backend: str, *, shard: str, exchange: str = "gather") -> str:
    """Backend dispatch under sharding (``build_sharded_scan_round_step``):

    * ``shard="d"``: the contraction is partitioned over D by GSPMD, which
      has no partitioning rules for the Pallas kernels — einsum only.
    * ``exchange="ring"``: the ring collective *replaces* the relay
      contraction (k−1 ppermutes + psum), so a kernel backend would be
      silently ignored — einsum only, by refusal rather than surprise.
    * ``exchange="gather"``: the gathered (n, D) buffer is replicated
      per-device, so any dense backend runs unchanged inside shard_map.
    * ``segment`` is refused under every sharding mode: the sharded step
      builders take a dense (n, n) operand (replicated or GSPMD-partitioned),
      and an EdgeRelay's data-dependent gather/scatter has no sharding rule
      worth writing before the hierarchical-relaying follow-on.
    """
    validate_backend(backend)
    if backend == "segment":
        raise ValueError(
            "relay_backend='segment' is single-host only — the sharded "
            "round-step builders need a dense relay operand; use "
            "relay_backend='einsum' (or a pallas backend with "
            "exchange='gather')"
        )
    if shard == "d" and backend != "einsum":
        raise ValueError(
            "D-axis sharding partitions the relay contraction via GSPMD; "
            "the Pallas kernels have no partitioning rules — use "
            "relay_backend='einsum'"
        )
    if shard == "clients" and exchange == "ring" and backend != "einsum":
        raise ValueError(
            "exchange='ring' replaces the relay contraction with ppermute "
            "rotations; relay_backend must be 'einsum' (the kernel would "
            "never run)"
        )
    return backend


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mask_A(A, active):
    """Restrict A to the active block of a padded client dim (client churn);
    the mask folds into the operand (dense matrix or EdgeRelay edge values),
    the kernel itself is unchanged."""
    if active is None:
        return A if isinstance(A, relay_lib.EdgeRelay) else jnp.asarray(A)
    return relay_lib.mask_relay_matrix(A, active)


def relay_mix(
    A, stacked, *, active=None, block_d: int = _k.DEFAULT_BLOCK_D, interpret=None
):
    """Δ̃ = A·Δ over a stacked pytree (leaves (n, ...)).  ``active`` is the
    optional churn mask: inactive rows/cols of A are zeroed, so a departed
    client's slot neither relays nor is relayed."""
    interpret = _default_interpret() if interpret is None else interpret
    A = _mask_A(A, active)
    n = A.shape[0]

    def mix(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.relay_mix_2d(
            jnp.asarray(A),
            flat,
            block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape)

    return jax.tree.map(mix, stacked)


def fused_aggregate(
    A,
    tau,
    stacked,
    *,
    w,
    active=None,
    block_d: int = _k.DEFAULT_BLOCK_D,
    interpret=None,
):
    """w · Σ_r τ_r (A·Δ)_r without materializing the relayed updates.
    ``w`` may be a python float (fixed membership) or a traced scalar
    (1/n_active under churn); ``active`` masks A and τ to the live block."""
    interpret = _default_interpret() if interpret is None else interpret
    A = _mask_A(A, active)
    n = A.shape[0]
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    coeffs = w * (tau @ A.astype(jnp.float32))

    def reduce(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.fused_aggregate_2d(
            coeffs,
            flat,
            block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape[1:])

    return jax.tree.map(reduce, stacked)


# --------------------------------------------------------------------------
# Flat-buffer dispatch: the (n, D) raveled hot path (utils.stacked_ravel)
# --------------------------------------------------------------------------


def _block(block_d, width: int) -> int:
    """Clamp the tile width to the buffer (tiny-D scenarios must not pad a
    64-wide model to a 4096 tile); floor 128 = the TPU lane granule."""
    return min(
        _k.DEFAULT_BLOCK_D if block_d is None else block_d, max(128, width)
    )


def mix_flat(
    A,
    buf,
    *,
    active=None,
    backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
):
    """Δ̃ = A·Δ on the contiguous (n, D) buffer.  ``backend`` picks the
    einsum oracle, the Pallas kernel, or the sparse segment-sum path
    (``backend="segment"``, which needs an :class:`~repro.core.relay.EdgeRelay`
    operand); ``active`` is the churn mask (zeroes inactive rows/cols of A —
    or the touching edge values — before dispatch, on every backend)."""
    validate_backend(backend)
    A = _mask_A(A, active)
    if backend == "segment":
        if not isinstance(A, relay_lib.EdgeRelay):
            raise ValueError(
                "relay_backend='segment' needs an EdgeRelay operand "
                "(a sparse OPT-α policy); got a dense relay matrix — "
                "use relay_backend='einsum' or convert via "
                "relay.edge_relay_from_dense"
            )
        return relay_lib.segment_mix(A, buf)
    if isinstance(A, relay_lib.EdgeRelay):
        A = A.todense(buf.shape[0])
    if backend == "einsum":
        return _ref.relay_mix_2d(A, buf)
    interpret = _default_interpret() if interpret is None else interpret
    return _k.relay_mix_2d(
        jnp.asarray(A),
        buf,
        block_d=_block(block_d, buf.shape[1]),
        interpret=interpret,
    )


def reduce_flat(
    coeffs,
    buf,
    *,
    backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
):
    """u = coeffs·Δ on the (n, D) buffer → (D,).  ``coeffs`` already carries
    every weighting (w·τᵀA for the fused colrel path, w·τ for the blind
    sum, ...), so churn masking happens in the caller's coefficients.
    ``backend="segment"`` lands here with an already-dense (n,) coefficient
    vector — the sparsity was spent computing it — so it runs the einsum
    reduction."""
    validate_backend(backend)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if backend in ("einsum", "segment"):
        return _ref.fused_aggregate_2d(coeffs, buf)
    interpret = _default_interpret() if interpret is None else interpret
    return _k.fused_aggregate_2d(
        coeffs,
        buf,
        block_d=_block(block_d, buf.shape[1]),
        interpret=interpret,
    )
