"""Jitted public wrappers: relay mixing over parameter *pytrees* backed by the
Pallas kernels.  Leaves are flattened to (n, leaf_size) tiles, streamed
through the kernel, and restored — so the single-host simulator can run the
whole D2D consensus as one fused kernel pass per leaf.

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips on TPU backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import relay as relay_lib
from repro.kernels import relay_mix as _k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mask_A(A, active):
    """Restrict A to the active block of a padded client dim (client churn);
    the mask folds into the kernel operand, the kernel itself is unchanged."""
    if active is None:
        return jnp.asarray(A)
    return relay_lib.mask_relay_matrix(A, active)


def relay_mix(A, stacked, *, active=None, block_d: int = _k.DEFAULT_BLOCK_D,
              interpret=None):
    """Δ̃ = A·Δ over a stacked pytree (leaves (n, ...)).  ``active`` is the
    optional churn mask: inactive rows/cols of A are zeroed, so a departed
    client's slot neither relays nor is relayed."""
    interpret = _default_interpret() if interpret is None else interpret
    A = _mask_A(A, active)
    n = A.shape[0]

    def mix(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.relay_mix_2d(
            jnp.asarray(A), flat, block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape)

    return jax.tree.map(mix, stacked)


def fused_aggregate(A, tau, stacked, *, w, active=None,
                    block_d: int = _k.DEFAULT_BLOCK_D, interpret=None):
    """w · Σ_r τ_r (A·Δ)_r without materializing the relayed updates.
    ``w`` may be a python float (fixed membership) or a traced scalar
    (1/n_active under churn); ``active`` masks A and τ to the live block."""
    interpret = _default_interpret() if interpret is None else interpret
    A = _mask_A(A, active)
    n = A.shape[0]
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    coeffs = w * (tau @ A.astype(jnp.float32))

    def reduce(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.fused_aggregate_2d(
            coeffs, flat, block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape[1:])

    return jax.tree.map(reduce, stacked)
