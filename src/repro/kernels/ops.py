"""Jitted public wrappers: relay mixing over parameter *pytrees* backed by the
Pallas kernels.  Leaves are flattened to (n, leaf_size) tiles, streamed
through the kernel, and restored — so the single-host simulator can run the
whole D2D consensus as one fused kernel pass per leaf.

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips on TPU backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import relay_mix as _k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def relay_mix(A, stacked, *, block_d: int = _k.DEFAULT_BLOCK_D, interpret=None):
    """Δ̃ = A·Δ over a stacked pytree (leaves (n, ...))."""
    interpret = _default_interpret() if interpret is None else interpret
    n = jnp.asarray(A).shape[0]

    def mix(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.relay_mix_2d(
            jnp.asarray(A), flat, block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape)

    return jax.tree.map(mix, stacked)


def fused_aggregate(A, tau, stacked, *, w: float, block_d: int = _k.DEFAULT_BLOCK_D,
                    interpret=None):
    """w · Σ_r τ_r (A·Δ)_r without materializing the relayed updates."""
    interpret = _default_interpret() if interpret is None else interpret
    A = jnp.asarray(A)
    n = A.shape[0]
    coeffs = w * (jnp.asarray(tau, jnp.float32) @ A.astype(jnp.float32))

    def reduce(leaf):
        flat = leaf.reshape(n, -1)
        out = _k.fused_aggregate_2d(
            coeffs, flat, block_d=min(block_d, max(128, flat.shape[1])),
            interpret=interpret,
        )
        return out.reshape(leaf.shape[1:])

    return jax.tree.map(reduce, stacked)
