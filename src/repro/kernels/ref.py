"""Pure-jnp oracles for the relay kernels (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def relay_mix_2d(A, delta):
    return jnp.einsum(
        "ro,od->rd",
        A.astype(jnp.float32),
        delta.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    ).astype(delta.dtype)


def fused_aggregate_2d(coeffs, delta):
    return jnp.einsum(
        "o,od->d",
        coeffs.astype(jnp.float32),
        delta.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    ).astype(delta.dtype)


def relay_mix_pytree(A, stacked):
    return jax.tree.map(
        lambda leaf: jnp.einsum(
            "ro,o...->r...", A.astype(jnp.float32), leaf.astype(jnp.float32)
        ).astype(leaf.dtype),
        stacked,
    )
