from repro.checkpoint.io import (
    latest_checkpoint,
    load_metadata,
    publish,
    restore,
    restore_training_state,
    save,
    save_training_state,
)

__all__ = [
    "latest_checkpoint",
    "load_metadata",
    "publish",
    "restore",
    "restore_training_state",
    "save",
    "save_training_state",
]
