from repro.checkpoint.io import load_metadata, restore, save
