"""Checkpointing: pytree <-> npz with '/'-joined key paths + JSON metadata.

Saves the PS global model, server-optimizer state and round counter so FL
training is resumable; restore round-trips exact dtypes/shapes.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


_BF16_PREFIX = "__bf16__:"  # npz cannot store ml_dtypes.bfloat16 natively


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            key = _BF16_PREFIX + key
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(path: str, tree, *, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2)


def restore(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_keys
        )
        if _BF16_PREFIX + key in flat:
            import ml_dtypes

            arr = flat[_BF16_PREFIX + key].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
