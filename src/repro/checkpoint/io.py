"""Checkpointing: pytree <-> npz with '/'-joined key paths + JSON metadata.

Saves the PS global model, server-optimizer state and round counter so FL
training is resumable; restore round-trips exact dtypes/shapes.

Two layers:

* :func:`save` / :func:`restore` — one pytree ⇄ one atomic ``.npz``
  (tmp-file + ``os.replace``, so a crash mid-write leaves the previous
  snapshot intact) with an optional ``.meta.json`` sidecar.
* The **training-state layer** — :func:`save_training_state` /
  :func:`restore_training_state` bundle the full resumable state (params,
  optional server-optimizer state, the RNG key via
  ``jax.random.key_data``, and the round counter), and :func:`publish` /
  :func:`latest_checkpoint` add the continuous-training rotation: numbered
  ``ckpt_<round>.npz`` snapshots, an atomically-replaced ``LATEST``
  pointer file, and keep-last-k pruning.  The serving loop
  (``repro.launch.serve``) polls ``LATEST`` and reloads on change.

Resuming mid-run is bitwise (tested in ``tests/test_resume.py``): restore
the state, rebuild the schedule/policy/batch stream from their seeds and
advance them to the saved round, and the continued trajectory equals the
uninterrupted one — params, metrics, and final RNG key.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


_BF16_PREFIX = "__bf16__:"  # npz cannot store ml_dtypes.bfloat16 natively
_LATEST = "LATEST"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            key = _BF16_PREFIX + key
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(path: str, tree, *, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2)


def restore(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_keys
        )
        if _BF16_PREFIX + key in flat:
            import ml_dtypes

            arr = flat[_BF16_PREFIX + key].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)


# --------------------------------------------------------------------------
# Training-state layer: full resumable state + the latest-pointer rotation
# --------------------------------------------------------------------------


def _key_like():
    """The array shape/dtype a typed PRNG key serializes to (impl-dependent;
    (2,) uint32 for the default threefry)."""
    return np.zeros_like(np.asarray(jax.random.key_data(jax.random.key(0))))


def save_training_state(path: str, *, params, server_state, key, round: int,
                        metadata: dict | None = None) -> None:
    """Save the full resumable state as one atomic snapshot.

    ``server_state`` may be None (momentum-free server optimizer) — recorded
    in the metadata so restore knows the expected structure.  ``key`` is the
    live typed PRNG key; it round-trips bit-exactly via
    ``jax.random.key_data`` / ``wrap_key_data``.
    """
    tree = {"params": params, "rng_key": np.asarray(jax.random.key_data(key))}
    if server_state is not None:
        tree["server_state"] = server_state
    meta = dict(metadata or {})
    meta.update({
        "round": int(round),
        "has_server_state": server_state is not None,
    })
    save(path, tree, metadata=meta)


def restore_training_state(path: str, *, params_like, server_state_like=None):
    """Restore a :func:`save_training_state` snapshot.

    Returns ``(params, server_state, key, round)``.  ``server_state_like``
    is required exactly when the snapshot carries one (build it with
    ``server_opt.init(params_like)``); a momentum-free snapshot returns
    ``server_state=None``.
    """
    meta = load_metadata(path)
    like = {"params": params_like, "rng_key": _key_like()}
    if meta["has_server_state"]:
        if server_state_like is None:
            raise ValueError(
                f"{path} carries a server-optimizer state: pass "
                "server_state_like (e.g. server_opt.init(params_like))"
            )
        like["server_state"] = server_state_like
    tree = restore(path, like)
    key = jax.random.wrap_key_data(np.asarray(tree["rng_key"]))
    return (
        tree["params"],
        tree.get("server_state"),
        key,
        int(meta["round"]),
    )


def _ckpt_name(round: int) -> str:
    return f"ckpt_{int(round):08d}.npz"


def publish(directory: str, *, params, server_state, key, round: int,
            keep: int = 3, metadata: dict | None = None) -> str:
    """Publish one training-state snapshot into ``directory`` and rotate the
    ``LATEST`` pointer atomically (tmp + ``os.replace``): a reader polling
    :func:`latest_checkpoint` sees either the previous snapshot or the new
    one, never a torn state.  Keeps the newest ``keep`` snapshots (0 ⇒ keep
    everything).  Returns the snapshot path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, _ckpt_name(round))
    save_training_state(
        path, params=params, server_state=server_state, key=key,
        round=round, metadata=metadata,
    )
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(os.path.basename(path) + "\n")
        os.replace(tmp, os.path.join(directory, _LATEST))  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if keep > 0:
        _prune(directory, keep=keep, current=os.path.basename(path))
    return path


def latest_checkpoint(directory: str) -> str | None:
    """The snapshot the ``LATEST`` pointer names, or None when the directory
    holds no published snapshot (missing pointer, or pointer to a snapshot
    already pruned away)."""
    pointer = os.path.join(directory, _LATEST)
    try:
        with open(pointer) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    path = os.path.join(directory, name)
    return path if name and os.path.exists(path) else None


def _prune(directory: str, *, keep: int, current: str) -> None:
    """Drop all but the newest ``keep`` numbered snapshots (and their
    sidecars).  The pointed-at snapshot is never pruned."""
    snaps = sorted(
        f for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    )
    for name in snaps[:-keep]:
        if name == current:
            continue
        for victim in (name, name + ".meta.json"):
            full = os.path.join(directory, victim)
            if os.path.exists(full):
                os.unlink(full)
