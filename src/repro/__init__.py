"""repro — ColRel (semi-decentralized FL with collaborative relaying) in JAX."""
__version__ = "0.1.0"
