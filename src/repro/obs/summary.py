"""Per-phase time attribution from a recorded trace file.

    PYTHONPATH=src python -m repro.obs.summary TRACE_bench_smoke_pipelined.json

Reads either export format (Chrome trace-event JSON or the JSONL stream)
and prints (1) a per-phase attribution table — how the traced run's wall
time splits across ``solve`` (OPT-α re-solves), ``stage`` (batch draws +
host stacking), ``h2d`` (host→device transfer), ``dispatch`` (compiled-call
enqueue) and ``device`` (blocked-on-device fences) — and (2) the recorded
counter totals.  The attributed total is printed against the trace's wall
span: a large gap means untraced host work (Python glue, GC), not a broken
trace.

``make trace-smoke`` is the one-command demo: it records a traced
``bench_smoke`` run and feeds the pipelined engine's trace through this
CLI.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.export import load_trace_file, phase_attribution_loaded

# canonical phase order for the table; unknown categories append after
PHASE_ORDER = ("solve", "stage", "h2d", "dispatch", "device")

PHASE_LABEL = {
    "solve": "OPT-α solve",
    "stage": "host staging",
    "h2d": "host→device",
    "dispatch": "dispatch",
    "device": "blocked on device",
}


def wall_seconds_loaded(loaded: dict) -> float:
    """Timeline span of a loaded trace (max span end − min event start)."""
    t0 = t1 = None
    for s in loaded["spans"]:
        a, b = s["ts_us"], s["ts_us"] + s["dur_us"]
        t0 = a if t0 is None else min(t0, a)
        t1 = b if t1 is None else max(t1, b)
    for i in loaded["instants"]:
        a = i["ts_us"]
        t0 = a if t0 is None else min(t0, a)
        t1 = a if t1 is None else max(t1, a)
    if t0 is None:
        return 0.0
    return (t1 - t0) / 1e6


def format_attribution(phases: dict[str, float], wall_s: float) -> str:
    """The attribution table as text (shared with ``repro.bench.run``)."""
    order = [c for c in PHASE_ORDER if c in phases]
    order += sorted(c for c in phases if c not in PHASE_ORDER)
    lines = [f"  {'phase':<20} {'time_s':>9} {'share':>7}"]
    total = 0.0
    for cat in order:
        t = phases[cat]
        total += t
        share = t / wall_s if wall_s > 0 else 0.0
        lines.append(f"  {PHASE_LABEL.get(cat, cat):<20} {t:>9.4f} {share:>6.1%}")
    share = total / wall_s if wall_s > 0 else 0.0
    lines.append(f"  {'attributed total':<20} {total:>9.4f} {share:>6.1%}")
    return "\n".join(lines)


def format_summary(path: str, loaded: dict) -> str:
    phases = phase_attribution_loaded(loaded["spans"])
    wall = wall_seconds_loaded(loaded)
    tracks = loaded["tracks"]
    lines = [
        f"trace {path}: wall {wall:.4f}s, "
        f"{len(loaded['spans'])} spans + {len(loaded['instants'])} instants "
        f"on {len(tracks)} tracks ({', '.join(tracks)})"
    ]
    if loaded["dropped"]:
        lines.append(f"  WARNING: {loaded['dropped']} events dropped (buffer bound)")
    lines.append(format_attribution(phases, wall))
    if loaded["counters"]:
        lines.append("  counters:")
        for name in sorted(loaded["counters"]):
            value = loaded["counters"][name]
            shown = f"{value:.4f}" if isinstance(value, float) else str(value)
            lines.append(f"    {name:<28} {shown}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("trace", nargs="+", help="TRACE_*.json / *.jsonl files")
    args = ap.parse_args(argv)
    for path in args.trace:
        print(format_summary(path, load_trace_file(path)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
