"""Trace exporters: Chrome trace-event JSON, JSONL stream, phase attribution.

Two on-disk formats, one in-memory aggregation:

* :func:`chrome_trace` / :func:`write_chrome_trace` — the Chrome
  trace-event format (object form), loadable in Perfetto
  (https://ui.perfetto.dev) or ``chrome://tracing``.  Every *track* — the
  recording thread by default, or a span's logical override such as
  ``"prefetcher"`` / ``"device"`` — becomes its own named timeline row via
  ``thread_name`` metadata events, so host/device overlap is visible as
  parallel rows, not just a fraction.  Timestamps are microseconds relative
  to the tracer's start.  Counter totals and the dropped-event count ride
  in a top-level ``"repro"`` key (ignored by trace viewers, read back by
  :mod:`repro.obs.summary`).
* :func:`write_jsonl` — one JSON object per line (``kind``: ``span`` /
  ``instant`` / final ``counters``), the grep/pandas-friendly stream for
  ad-hoc analysis without a trace viewer.
* :func:`phase_attribution` — total seconds per span *category* (the
  solve / stage / h2d / dispatch / device axis).  Only top-level spans of
  each category count (a span nested under a same-category ancestor would
  double-bill its interval); instrumentation keeps categories disjoint, so
  in a serialized traced run the phase totals sum to ≈ wall time.
"""
from __future__ import annotations

import json
import pathlib

from repro.obs.trace import InstantEvent, SpanEvent, Tracer

__all__ = [
    "chrome_trace",
    "load_trace_file",
    "phase_attribution",
    "phase_attribution_loaded",
    "write_chrome_trace",
    "write_jsonl",
]

_PID = 1
# canonical track order: the main thread first, then logical tracks in
# first-seen order — keeps Perfetto rows stable across runs
_MAIN_TRACK = "main"


def _track_label(event, thread_names: dict[int, str]) -> str:
    if event.track is not None:
        return event.track
    name = thread_names.get(event.tid, f"thread-{event.tid}")
    return _MAIN_TRACK if name == "MainThread" else name


def chrome_trace(tracer: Tracer) -> dict:
    """The tracer's buffer as a Chrome trace-event dict (object form)."""
    tids: dict[str, int] = {}
    trace_events: list[dict] = []

    def tid_for(label: str) -> int:
        if label not in tids:
            tids[label] = len(tids)
            trace_events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": _PID,
                    "tid": tids[label],
                    "args": {"name": label},
                }
            )
        return tids[label]

    trace_events.append(
        {
            "ph": "M",
            "name": "process_name",
            "pid": _PID,
            "tid": 0,
            "args": {"name": "repro"},
        }
    )
    t0 = tracer.t_start_ns
    for e in tracer.events:
        tid = tid_for(_track_label(e, tracer.thread_names))
        if isinstance(e, SpanEvent):
            trace_events.append(
                {
                    "ph": "X",
                    "name": e.name,
                    "cat": e.cat,
                    "pid": _PID,
                    "tid": tid,
                    "ts": (e.t0_ns - t0) / 1e3,
                    "dur": e.dur_ns / 1e3,
                    "args": dict(e.attrs),
                }
            )
        else:
            trace_events.append(
                {
                    "ph": "i",
                    "s": "t",  # thread-scoped instant marker
                    "name": e.name,
                    "cat": e.cat,
                    "pid": _PID,
                    "tid": tid,
                    "ts": (e.t_ns - t0) / 1e3,
                    "args": dict(e.attrs),
                }
            )
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        # non-standard, viewer-ignored; summary + telemetry read it back
        "repro": {
            "counters": dict(tracer.counters),
            "dropped": tracer.dropped,
            "n_tracks": len(tids),
        },
    }


def write_chrome_trace(tracer: Tracer, path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(tracer)) + "\n")
    return path


def write_jsonl(tracer: Tracer, path) -> pathlib.Path:
    """One event per line, counters as the final line."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    t0 = tracer.t_start_ns
    lines = []
    for e in tracer.events:
        track = _track_label(e, tracer.thread_names)
        if isinstance(e, SpanEvent):
            lines.append(
                {
                    "kind": "span",
                    "name": e.name,
                    "cat": e.cat,
                    "ts_us": (e.t0_ns - t0) / 1e3,
                    "dur_us": e.dur_ns / 1e3,
                    "track": track,
                    "depth": e.depth,
                    "attrs": dict(e.attrs),
                }
            )
        else:
            lines.append(
                {
                    "kind": "instant",
                    "name": e.name,
                    "cat": e.cat,
                    "ts_us": (e.t_ns - t0) / 1e3,
                    "track": track,
                    "attrs": dict(e.attrs),
                }
            )
    lines.append(
        {"kind": "counters", "counters": dict(tracer.counters), "dropped": tracer.dropped}
    )
    path.write_text("".join(json.dumps(line) + "\n" for line in lines))
    return path


def phase_attribution(events) -> dict[str, float]:
    """Total seconds per span category, from live :class:`SpanEvent`s.

    Nested spans of the *same* category are skipped (their time is already
    inside the ancestor's interval); cross-category nesting bills both — so
    instrumentation keeps the conventional categories disjoint and the
    totals stay a partition of busy time.
    """
    # spans record at exit, so an ancestor appears *after* its children in
    # the buffer; collect per-thread intervals first, then prune.
    per_cat: dict[str, float] = {}
    spans = [e for e in events if isinstance(e, SpanEvent)]
    by_thread: dict[int, list[SpanEvent]] = {}
    for s in spans:
        by_thread.setdefault(s.tid, []).append(s)
    for thread_spans in by_thread.values():
        # end-time order ⇒ a child precedes its ancestor; an ancestor of s
        # is any later span with smaller depth whose interval contains s
        for i, s in enumerate(thread_spans):
            nested_same_cat = any(
                o.depth < s.depth
                and o.cat == s.cat
                and o.t0_ns <= s.t0_ns
                and s.t1_ns <= o.t1_ns
                for o in thread_spans[i + 1 :]
            )
            if not nested_same_cat:
                per_cat[s.cat] = per_cat.get(s.cat, 0.0) + s.dur_ns / 1e9
    return per_cat


def phase_attribution_loaded(spans: list[dict]) -> dict[str, float]:
    """:func:`phase_attribution` over spans loaded back from a trace file
    (:func:`load_trace_file` records): same same-category pruning, with
    nesting inferred from strict interval containment on one track."""
    per_cat: dict[str, float] = {}
    by_track: dict[str, list[dict]] = {}
    for s in spans:
        by_track.setdefault(s["track"], []).append(s)
    for track_spans in by_track.values():
        for s in track_spans:
            end = s["ts_us"] + s["dur_us"]
            nested_same_cat = any(
                o is not s
                and o["cat"] == s["cat"]
                and o["dur_us"] > s["dur_us"]
                and o["ts_us"] <= s["ts_us"]
                and end <= o["ts_us"] + o["dur_us"]
                for o in track_spans
            )
            if not nested_same_cat:
                per_cat[s["cat"]] = per_cat.get(s["cat"], 0.0) + s["dur_us"] / 1e6
    return per_cat


def load_trace_file(path) -> dict:
    """Load either export format back into one normalized dict::

        {"spans": [{name, cat, ts_us, dur_us, track, attrs}, ...],
         "instants": [{name, cat, ts_us, track, attrs}, ...],
         "counters": {...}, "dropped": int, "tracks": [label, ...]}

    Chrome files are detected by their ``traceEvents`` key; anything else is
    parsed as JSONL.
    """
    text = pathlib.Path(path).read_text()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    spans, instants, tracks = [], [], []
    counters: dict = {}
    dropped = 0
    if isinstance(doc, dict) and "traceEvents" in doc:
        tid_names: dict[int, str] = {}
        for e in doc["traceEvents"]:
            if e.get("ph") == "M" and e.get("name") == "thread_name":
                tid_names[e["tid"]] = e["args"]["name"]
        for e in doc["traceEvents"]:
            track = tid_names.get(e.get("tid"), str(e.get("tid")))
            if e.get("ph") == "X":
                spans.append(
                    {
                        "name": e["name"],
                        "cat": e.get("cat", "default"),
                        "ts_us": e["ts"],
                        "dur_us": e["dur"],
                        "track": track,
                        "attrs": e.get("args", {}),
                    }
                )
            elif e.get("ph") == "i":
                instants.append(
                    {
                        "name": e["name"],
                        "cat": e.get("cat", "default"),
                        "ts_us": e["ts"],
                        "track": track,
                        "attrs": e.get("args", {}),
                    }
                )
        meta = doc.get("repro", {})
        counters = meta.get("counters", {})
        dropped = meta.get("dropped", 0)
        tracks = [tid_names[k] for k in sorted(tid_names)]
    else:
        for line in text.splitlines():
            if not line.strip():
                continue
            rec = json.loads(line)
            kind = rec.get("kind")
            if kind == "span":
                spans.append(rec)
            elif kind == "instant":
                instants.append(rec)
            elif kind == "counters":
                counters = rec.get("counters", {})
                dropped = rec.get("dropped", 0)
        seen: list[str] = []
        for rec in spans + instants:
            if rec["track"] not in seen:
                seen.append(rec["track"])
        tracks = seen
    return {
        "spans": spans,
        "instants": instants,
        "counters": counters,
        "dropped": dropped,
        "tracks": tracks,
    }
