"""Runtime telemetry: structured spans, counters, and trace export.

The observability layer for the execution stack — one :class:`Tracer`
threads through the engines (`repro.fl.engine`), the scheduler/prefetcher
(`repro.channels.scheduler`), the schedule (`repro.channels.schedule`) and
the bench harness (`repro.bench`), recording nested spans, instants and
monotonic counters into a bounded in-memory buffer.  Exporters turn a run
into a Perfetto-loadable Chrome trace (host/device overlap visible as
parallel tracks) or a JSONL stream; ``python -m repro.obs.summary`` prints
the per-phase time attribution table.  Disabled tracing is the
:data:`NULL_TRACER` singleton — a single-attribute-check no-op, so
untraced runs stay bit- and perf-identical.

See ``docs/observability.md`` for the span model, the track/category
conventions, and how to read a traced pipelined-engine timeline.
"""
from repro.obs.export import (
    chrome_trace,
    load_trace_file,
    phase_attribution,
    phase_attribution_loaded,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.trace import (
    NULL_TRACER,
    InstantEvent,
    NullTracer,
    SpanEvent,
    Tracer,
)

__all__ = [
    "InstantEvent",
    "NULL_TRACER",
    "NullTracer",
    "SpanEvent",
    "Tracer",
    "chrome_trace",
    "load_trace_file",
    "phase_attribution",
    "phase_attribution_loaded",
    "write_chrome_trace",
    "write_jsonl",
]
