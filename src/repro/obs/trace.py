"""Structured runtime telemetry: nested spans, instants, and counters.

One :class:`Tracer` instance observes one run.  Instrumented code records
three kinds of facts into a bounded in-memory buffer:

* **spans** — ``with tracer.span("opt_alpha.solve", cat="solve", n_active=8)``
  wraps a stretch of host work.  A span records its name, category,
  ``time.perf_counter_ns`` start/end, the recording thread id, its nesting
  depth on that thread, an optional logical *track*, and arbitrary
  key=value attrs.  Categories are the attribution axis (the summary CLI
  and the bench ``telemetry`` block group by them); the conventional set is
  ``solve`` / ``stage`` / ``h2d`` / ``dispatch`` / ``device``
  (blocked-on-device).  Tracks are the *timeline* axis: by default a span
  lands on its recording thread's track, but a logical override (e.g.
  ``track="prefetcher"`` for staging work, ``track="device"`` for fence
  spans) groups related spans onto one named Perfetto row regardless of
  which thread ran them.
* **instants** — ``tracer.instant("segment", cat="schedule", epoch=3)``
  marks a point in time (rendered as a thin arrow in Perfetto); the channel
  schedule uses these for epoch boundaries.
* **counters** — ``tracer.count("opt_alpha.cache_hits")`` accumulates
  monotonic totals (ints or floats).  Counters are aggregates, not events:
  they cost a dict update, never buffer space.

Everything is thread-safe (spans record on exit under one lock; nesting
depth is tracked per-thread), and the buffer is bounded: past
``max_events`` new events are counted in ``dropped`` instead of appended,
so a runaway instrumentation site cannot eat the host's memory.

:class:`NullTracer` is the disabled path.  Its ``enabled`` attribute is
``False`` and every method is a constant-returning no-op, so instrumented
hot loops guard extra work (attribute computation, device fences) behind a
single ``if tracer.enabled:`` check and disabled runs stay bit- and
perf-identical to uninstrumented code.  The module-level :data:`NULL_TRACER`
singleton is the default everywhere a ``tracer`` parameter is accepted.

This module is stdlib-only (no jax, no numpy): the channels package stays
jax-free, and importing telemetry can never drag in an accelerator runtime.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

__all__ = [
    "CounterDict",
    "InstantEvent",
    "NULL_TRACER",
    "NullTracer",
    "SpanEvent",
    "Tracer",
]

CounterDict = dict  # name -> accumulated int | float


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One completed span (recorded at ``__exit__``)."""

    name: str
    cat: str
    t0_ns: int
    t1_ns: int
    tid: int  # recording thread id (threading.get_ident)
    depth: int  # nesting depth on the recording thread (0 = top level)
    track: str | None  # logical track override (None ⇒ the thread's track)
    attrs: dict

    @property
    def dur_ns(self) -> int:
        return self.t1_ns - self.t0_ns


@dataclasses.dataclass(frozen=True)
class InstantEvent:
    """A point-in-time marker."""

    name: str
    cat: str
    t_ns: int
    tid: int
    track: str | None
    attrs: dict


class _NullSpan:
    """The shared no-op context manager ``NullTracer.span`` returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every call is a no-op, ``enabled`` is False.

    Instrumentation sites hold a tracer unconditionally and branch on
    ``tracer.enabled`` only where tracing would add work that changes
    behavior or cost (device fences, attr computation); plain
    ``with tracer.span(...)`` on a NullTracer is itself only three cheap
    calls on shared constants.
    """

    __slots__ = ()
    enabled = False

    def span(self, name, *, cat="default", track=None, **attrs):
        return _NULL_SPAN

    def instant(self, name, *, cat="default", track=None, **attrs):
        return None

    def count(self, name, value=1):
        return None


NULL_TRACER = NullTracer()


class _Span:
    """Live span handle: measures on ``__enter__``/``__exit__``, records the
    completed :class:`SpanEvent` on exit (so buffer order is end-time order
    and a crashed span never leaves a half-open event behind)."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_attrs", "_t0", "_depth")

    def __init__(self, tracer, name, cat, track, attrs):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._attrs = attrs

    def __enter__(self):
        local = self._tracer._local
        self._depth = getattr(local, "depth", 0)
        local.depth = self._depth + 1
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._clock()
        self._tracer._local.depth = self._depth
        self._tracer._record(
            SpanEvent(
                name=self._name,
                cat=self._cat,
                t0_ns=self._t0,
                t1_ns=t1,
                tid=threading.get_ident(),
                depth=self._depth,
                track=self._track,
                attrs=self._attrs,
            )
        )
        return False


class Tracer:
    """Collects spans, instants and counters for one run.

    ``clock`` defaults to ``time.perf_counter_ns`` (monotonic, ns); tests
    inject a deterministic counter for golden-value assertions.  ``events``
    is the bounded buffer (read it directly or through the exporters in
    :mod:`repro.obs.export`); ``counters`` the accumulated totals;
    ``dropped`` how many events the bound rejected.
    """

    enabled = True

    def __init__(
        self,
        *,
        max_events: int = 1_000_000,
        clock: Callable[[], int] = time.perf_counter_ns,
    ):
        if max_events < 1:
            raise ValueError("max_events must be >= 1")
        self.max_events = int(max_events)
        self._clock = clock
        self._lock = threading.Lock()
        self._local = threading.local()
        self.events: list[SpanEvent | InstantEvent] = []
        self.counters: CounterDict[str, Any] = {}
        self.dropped = 0
        self.thread_names: dict[int, str] = {}
        self.t_start_ns = clock()

    # ------------------------------------------------------------- recording
    def span(self, name: str, *, cat: str = "default", track: str | None = None, **attrs):
        """A context manager timing one stretch of work.  ``cat`` is the
        attribution phase, ``track`` an optional logical timeline, ``attrs``
        free-form span metadata (must be JSON-serializable for export)."""
        return _Span(self, name, cat, track, attrs)

    def instant(self, name: str, *, cat: str = "default", track: str | None = None, **attrs):
        """Mark a point in time (e.g. a segment boundary)."""
        self._record(
            InstantEvent(
                name=name,
                cat=cat,
                t_ns=self._clock(),
                tid=threading.get_ident(),
                track=track,
                attrs=attrs,
            )
        )

    def count(self, name: str, value=1):
        """Accumulate a monotonic counter (int or float)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + value

    def _record(self, event) -> None:
        tid = event.tid
        with self._lock:
            if tid not in self.thread_names:
                # the recorder is always the current thread (spans record on
                # exit from the thread that entered them)
                self.thread_names[tid] = threading.current_thread().name
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append(event)

    # ------------------------------------------------------------- inspection
    @property
    def spans(self) -> list[SpanEvent]:
        return [e for e in self.events if isinstance(e, SpanEvent)]

    @property
    def instants(self) -> list[InstantEvent]:
        return [e for e in self.events if isinstance(e, InstantEvent)]

    def wall_seconds(self) -> float:
        """Span of the recorded timeline: last event end minus first event
        start, in seconds (0.0 for an empty buffer)."""
        t0 = t1 = None
        for e in self.events:
            a = e.t0_ns if isinstance(e, SpanEvent) else e.t_ns
            b = e.t1_ns if isinstance(e, SpanEvent) else e.t_ns
            t0 = a if t0 is None else min(t0, a)
            t1 = b if t1 is None else max(t1, b)
        if t0 is None:
            return 0.0
        return (t1 - t0) / 1e9
