"""Learning-rate schedules.  ``paper_lr`` is Theorem 1's
η_r = 4 μ⁻¹ / (r·T + 1); the constant/cosine schedules serve the DNN runs
(the paper itself uses constant 0.1 for ResNet-20)."""
from __future__ import annotations

import numpy as np


def paper_lr(mu: float, T: int):
    def lr(r: int) -> float:
        return 4.0 / (mu * (r * T + 1.0))
    return lr


def constant(value: float):
    return lambda r: value


def cosine(base: float, total_rounds: int, *, final_frac: float = 0.1):
    def lr(r: int) -> float:
        c = 0.5 * (1 + np.cos(np.pi * min(r, total_rounds) / total_rounds))
        return base * (final_frac + (1 - final_frac) * c)
    return lr
