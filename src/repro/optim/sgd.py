"""Client-side optimizers.  The paper trains clients with plain SGD
(lr 0.1, ℓ2 1e-4); momentum/Adam are provided for beyond-paper runs."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ClientOpt:
    kind: str = "sgd"            # sgd | momentum | adam
    momentum: float = 0.9
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4

    def init(self, params) -> Any:
        if self.kind == "sgd":
            return ()
        if self.kind == "momentum":
            return jax.tree.map(jnp.zeros_like, params)
        if self.kind == "adam":
            z = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
            return {"m": z, "v": jax.tree.map(jnp.copy, z), "t": jnp.int32(0)}
        raise ValueError(self.kind)

    def step(self, params, grads, state, lr):
        wd = self.weight_decay

        def decayed(g, p):
            return g.astype(jnp.float32) + wd * p.astype(jnp.float32)

        if self.kind == "sgd":
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr * decayed(g, p)).astype(p.dtype),
                params, grads)
            return new, state
        if self.kind == "momentum":
            vel = jax.tree.map(
                lambda v, g, p: self.momentum * v + decayed(g, p), state, grads, params)
            new = jax.tree.map(
                lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
                params, vel)
            return new, vel
        if self.kind == "adam":
            t = state["t"] + 1
            m = jax.tree.map(lambda m, g, p: self.b1 * m + (1 - self.b1) * decayed(g, p),
                             state["m"], grads, params)
            v = jax.tree.map(lambda v, g, p: self.b2 * v + (1 - self.b2) * decayed(g, p) ** 2,
                             state["v"], grads, params)
            bc1 = 1 - self.b1 ** t.astype(jnp.float32)
            bc2 = 1 - self.b2 ** t.astype(jnp.float32)
            new = jax.tree.map(
                lambda p, m_, v_: (
                    p.astype(jnp.float32) - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
                ).astype(p.dtype),
                params, m, v)
            return new, {"m": m, "v": v, "t": t}
        raise ValueError(self.kind)
