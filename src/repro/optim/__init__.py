from repro.optim import schedules
from repro.optim.sgd import ClientOpt
