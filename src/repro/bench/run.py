"""Benchmark CLI: run a registered scenario, emit ``BENCH_<name>.json``,
optionally gate against a checked-in baseline.

    PYTHONPATH=src python -m repro.bench.run --list
    PYTHONPATH=src python -m repro.bench.run --scenario bench_smoke
    PYTHONPATH=src python -m repro.bench.run --scenario bench_smoke \\
        --baseline benchmarks/baselines/BENCH_bench_smoke.json \\
        --max-regression 2.0

``--trace`` adds a third, instrumented pass per engine and writes
``TRACE_<scenario>_<engine>.json`` (Perfetto-loadable) + ``.jsonl`` next to
the report; the report gains a ``telemetry`` block and the summary prints
each engine's per-phase attribution (see ``docs/observability.md``).

Exit status is non-zero when the regression gate fails (CI wires this into
the ``bench-smoke`` job; see ``make bench-smoke``).
"""
from __future__ import annotations

import argparse
import sys

from repro.bench import harness, report as report_lib, scenarios
from repro.obs.summary import format_attribution


def format_scenario_line(spec) -> str:
    """One ``--list`` row per scenario (shared with ``benchmarks.run``)."""
    return (
        f"{spec.name:>12}  rounds={spec.rounds:<4} "
        f"n={spec.n_clients:<3} {spec.description}"
    )


def format_summary(rep: dict) -> str:
    lines = [f"scenario {rep['scenario']}: {rep['description']}"]
    for name, run in sorted(rep["engines"].items()):
        line = (
            f"  {name:>9}: {run['rounds_per_sec']:>8.1f} rounds/s  "
            f"wall {run['wall_s']:.3f}s  compile {run['compile_s']:.3f}s  "
            f"traces {run['trace_count']}  dispatches {run['dispatches']}"
        )
        if run.get("overlap_fraction") is not None:
            line += (
                f"  overlap {run['overlap_fraction']:.0%} "
                f"(prep {run['host_prep_s']:.3f}s, "
                f"wait {run['host_wait_s']:.3f}s)"
            )
        lines.append(line)
    for name, tele in sorted((rep.get("telemetry") or {}).items()):
        lines.append(
            f"  {name} telemetry (traced pass, {tele['events']} events, "
            f"attributed {tele['attributed_fraction']:.0%} of "
            f"{tele['wall_s']:.3f}s):"
        )
        lines.append(format_attribution(tele["phases"], tele["wall_s"]))
    check = rep.get("kernel_check")
    if check:
        lines.append(
            f"  kernel check [{check['backend']}]: allclose vs "
            f"{check['reference_backend']} (max |Δ| {check['max_abs_diff']:.2e} "
            f"≤ atol {check['atol']:g}/rtol {check['rtol']:g}), "
            f"{check['rounds_per_sec']:.1f} rounds/s on the kernel backend"
        )
    scheck = rep.get("shard_check")
    if scheck:
        lines.append(
            f"  shard check [{scheck['shard']}/{scheck['exchange']}, "
            f"{scheck['devices']} devices]: allclose vs the single-device "
            f"loop (max |Δ| {scheck['max_abs_diff']:.2e} ≤ atol "
            f"{scheck['atol']:g}/rtol {scheck['rtol']:g}), sharded engines "
            "bitwise-identical to each other"
        )
    acheck = rep.get("async_check")
    if acheck:
        lines.append(
            f"  async check: delay-0 re-run bitwise-identical to the loop "
            f"(recorded delay {acheck['recorded_delay']!r}, "
            f"{acheck['rounds_per_sec']:.1f} rounds/s at delay 0)"
        )
    ttac = rep.get("ttac")
    if ttac:
        lines.append(f"  time-to-accuracy (loss ≤ {ttac['target_loss']:g}):")
        for name, t in sorted(ttac["engines"].items()):
            if t["reached"]:
                lines.append(
                    f"    {name:>12}: round {t['rounds_to_target']} "
                    f"(~{t['seconds_to_target']:.3f}s)"
                )
            else:
                lines.append(f"    {name:>12}: target not reached")
    if rep.get("model_params"):
        lines.append(f"  model_params D = {rep['model_params']:,}")
    speedups = rep.get("speedups_vs_loop") or {}
    if speedups:
        pairs = "  ".join(
            f"{name}/loop {ratio:.2f}x" for name, ratio in sorted(speedups.items())
        )
        lines.append(f"  speedups: {pairs}  (bitwise_match={rep['bitwise_match']})")
    elif rep.get("speedup_rounds_per_sec"):
        lines.append(
            f"  scan/loop speedup: {rep['speedup_rounds_per_sec']:.2f}x  "
            f"(bitwise_match={rep['bitwise_match']})"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--list",
        action="store_true",
        help="print the registered scenarios and exit",
    )
    ap.add_argument(
        "--scenario",
        action="append",
        default=[],
        help="scenario name (repeatable); default: bench_smoke",
    )
    ap.add_argument(
        "--engines",
        default="",
        help="comma-separated engines to run (loop, scan, pipelined, "
        "async); default: the scenario's own engine list",
    )
    ap.add_argument(
        "--out-dir",
        default=".",
        help="directory for BENCH_<scenario>.json reports",
    )
    ap.add_argument(
        "--trace",
        action="store_true",
        help="record a traced pass per engine: TRACE_<scenario>_<engine>.json"
        " (+ .jsonl) in --out-dir and a telemetry block in the report",
    )
    ap.add_argument(
        "--baseline",
        default=None,
        help="baseline BENCH_*.json to gate against",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=2.0,
        help="fail when rounds/sec drops by more than this factor vs the "
        "baseline (default 2.0)",
    )
    args = ap.parse_args(argv)

    if args.list:
        for spec in scenarios.list_scenarios():
            print(format_scenario_line(spec))
        return 0

    names = args.scenario or ["bench_smoke"]
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip()) or None
    status = 0
    for name in names:
        spec = scenarios.get_scenario(name)
        result = harness.run_scenario(
            spec, engines=engines, trace_dir=args.out_dir if args.trace else None
        )
        rep = report_lib.make_report(spec, result)
        path = report_lib.write_report(rep, args.out_dir)
        print(format_summary(rep))
        print(f"  wrote {path}")
        if args.baseline:
            baseline = report_lib.load_report(args.baseline)
            failures = report_lib.check_regression(
                rep, baseline, factor=args.max_regression
            )
            if failures:
                status = 1
                for f in failures:
                    print(f"  GATE FAIL: {f}", file=sys.stderr)
            else:
                print(
                    f"  gate: OK (within {args.max_regression:g}x of "
                    f"{args.baseline})"
                )
    return status


if __name__ == "__main__":
    sys.exit(main())
