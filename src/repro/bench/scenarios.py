"""Declarative benchmark scenarios: topology × fading × drift × churn.

A :class:`ScenarioSpec` is pure data — every field is a plain value, so a
scenario can live in a registry, be printed by ``--list``, and be serialized
into its ``BENCH_*.json`` report.  ``build()`` turns a spec into the factory
bundle the harness consumes; each engine run gets *fresh* schedule / policy /
loader instances so cold and warm runs see identical streams.

The registered scenarios:

  bench_smoke     tiny CI gate scenario (seconds on one CPU core)
  fig5_500        the acceptance scenario: 500 rounds, n=10, ring(10, 2) with
                  bursty Markov fading + piecewise-constant p-drift at a
                  25-round coherence time (the Fig. 5 channel at paper-scale
                  horizon, bench-scale model so the engine — not the matmul —
                  is what's measured)
  fig5_chunk5 / fig5_chunk125
                  chunk-size-vs-coherence-time sweep around fig5_500's
                  matched chunk=25: under-chunked (5, dispatch-bound) and
                  over-chunked (125, padding-bound)
  fig6_500        fig5_500 plus rotating-cohort churn over the padded client
                  dimension (the Fig. 6 setting)
  static_500      single-epoch control: the seed paper's static channel,
                  where epoch fusion is maximal
  corr_shadow_500 correlated shadowing: one GP blockage field drives the D2D
                  graph (edges sharing a blocked node fail together), p
                  static — the first jointly-sampled adjacency stream
  corr_uplink_500 corr_shadow_500 with the uplink coupled to the same fade:
                  (adj, p) move together at every epoch boundary
  mesh_corr_500   the production mesh round step (``build_round_step`` vs
                  ``build_scan_round_step``) under the coupled correlated
                  channel — ``spec.step = "mesh"`` swaps the execution path
  resnet20_cifar  the paper's §V model (ResNet-20/GN) on CIFAR-shaped
                  synthetic batches through all three engines, with the
                  pallas mix-kernel parity check on the side
  relay_sweep_1e4 / _1e5 / _1e6 / _1e7 / _smoke
                  the relay/aggregate hot spot swept over model size
                  D = 10⁴ … 10⁷ (compute- vs memory-bound crossover);
                  reference engines + the mandatory pallas_fused kernel
                  check (see benchmarks/roofline.py)
  sample_sweep_n1e3 / _n1e4 / _smoke
                  the n ≫ 10³ client-scale regime: sparse geometric graph,
                  per-round fixed-k cohorts (CohortSampler), the
                  neighborhood-blocked OPT-α solver (policy="sparse") and
                  segment-sum aggregation over EdgeRelay operands — the
                  n=10³/10⁴ pair proves rounds/sec holds as n grows (the
                  smoke point is the CI gate, einsum parity check on)
  mesh8_smoke     the multi-device CI gate: client-sharded fused scan on an
                  8-device host mesh (gather exchange, pallas_fused parity
                  check on the side) — run under
                  XLA_FLAGS=--xla_force_host_platform_device_count=8
  mesh8_ring_churn
                  the sharded acceptance scenario: block-ring ppermute
                  exchange under rotating-cohort churn + correlated
                  shadowing, 8 devices
  mesh2_dshard    D-axis GSPMD mode: the (n, D) relay contraction
                  partitioned over a 2-device "model" axis
  async_ttac_500  time-to-accuracy under Poisson arrival delays: the
                  staleness-weighted async engine vs the synchronous
                  loop/pipelined engines on the fig5 channel, with the
                  mandatory delay-0 parity gate (async == loop bitwise)
  async_smoke     CI-sized async point: geometric delays, buffer_k
                  selection and the delay-0 parity gate in seconds
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import channels
from repro.configs.resnet20_cifar import CONFIG as _RESNET20_CONFIG
from repro.core import connectivity, topology
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import cifar_like, gaussian_classification
from repro.fl.async_engine import SUPPORTED_STRATEGIES as _ASYNC_STRATEGIES
from repro.fl.simulator import FLSimulator
from repro.kernels.ops import RELAY_BACKENDS, validate_sharded_backend
from repro.models.resnet import init_resnet20, resnet20_loss
from repro.optim.sgd import ClientOpt


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One benchmark setting.  All fields are plain data (JSON-serializable
    via ``dataclasses.asdict``)."""

    name: str
    description: str = ""
    # federated setting
    n_clients: int = 10
    rounds: int = 100
    local_steps: int = 2
    local_batch: int = 8
    strategy: str = "colrel_fused"
    policy: str = "adaptive"  # adaptive | sparse | stale | none
    opt_method: str = "exact"  # OPT-α column solver (exact | bisect)
    opt_sweeps: int = 40
    warm_sweeps: int = 12
    lr: float = 0.1
    seed: int = 0
    # model / data: "mlp" = spec-sized MLP over flat gaussian features
    # (dim/width apply); "resnet20" = the paper's §V ResNet-20 over
    # CIFAR-shaped synthetic images (dim/width ignored)
    model: str = "mlp"
    dim: int = 64
    width: int = 32
    n_classes: int = 10
    n_train: int = 1024
    # relay backend for the (n, D) aggregation hot spot (repro.kernels):
    # einsum = pure-XLA reference, pallas / pallas_fused = the kernel paths.
    # block_d sizes the kernel's Δ tile (None ⇒ kernel default).
    # check_backend != "none" makes the harness run one extra scan pass on
    # that backend and assert allclose against the reference engines' finals
    # (the mandatory kernel parity check; recorded as report.kernel_check).
    relay_backend: str = "einsum"
    block_d: int | None = None
    check_backend: str = "none"
    # channel composition
    topology: str = "ring"  # ring | full | geometric
    ring_k: int = 2
    # geometric: expected node degree of the random geometric graph on the
    # unit square (sets the radius: r = sqrt(deg / (π n)))
    geo_degree: float = 8.0
    fading: str = "markov"  # markov | corr_shadow | corr_uplink | static
    p_up_to_down: float = 0.3
    p_down_to_up: float = 0.5
    adj_every: int = 1
    drift: str = "piecewise"  # piecewise | static
    drift_hold: int = 1
    p_every: int = 1
    churn: str = "none"  # none | rotating
    n_cohorts: int = 5
    churn_hold: int = 4
    # per-round cohort sampling (the n ≫ 10³ regime): the active mask becomes
    # membership ∧ sampled, with the sampler wrapping the churn process as
    # its eligibility base.  fixed_k / expander use sample_k, uniform uses
    # sample_rate; sample_every throttles the redraw cadence.
    sampling: str = "none"  # none | uniform | fixed_k | expander
    sample_k: int = 0
    sample_rate: float = 0.5
    sample_every: int = 1
    # correlated shadowing (fading = corr_shadow | corr_uplink; the field
    # refreshes every adj_every rounds — the coherence time)
    corr_length: float = 0.4
    shadow_rho: float = 0.9
    shadow_sigma: float = 1.0
    blockage_threshold: float = 1.0
    uplink_gain: float = 2.0
    # execution path: FLSimulator/EpochScanEngine vs the production mesh
    # round step (build_round_step / build_scan_round_step) vs the
    # multi-device sharded step (build_sharded_scan_round_step).  The mesh
    # and shard scans dispatch one whole segment per call, so `chunk`
    # applies to the sim path only.
    step: str = "sim"  # sim | mesh | shard
    # sharded execution (step = "shard"): the scan/pipelined engines run the
    # shard_map round step across a host mesh of `devices` devices (CI forces
    # them with XLA_FLAGS=--xla_force_host_platform_device_count=N; the spec
    # itself never touches device state, so the registry imports anywhere).
    # `shard` picks the partitioned axis (clients | d), `exchange` the relay
    # collective in clients mode (gather = bitwise einsum order, ring =
    # O(1)-buffer block-ring at f32 tolerance) — see docs/distributed.md.
    devices: int = 1
    shard: str = "clients"  # clients | d
    exchange: str = "gather"  # gather | ring
    # scan engine (sim path)
    chunk: int = 32
    # which engines the scenario benches by default (run.py --engines
    # overrides).  "async" adds the staleness-weighted AsyncRoundEngine.
    engines: tuple = ("loop", "scan", "pipelined")
    # async arrival model (engines includes "async"): per-client upload
    # delays drawn by repro.channels.delay; the PS aggregates the freshest
    # buffer_k arrivals (0 = all) with staleness discount decay**s.  With
    # delay="none" the async engine is bitwise-identical to the loop — the
    # harness enforces exactly that as the async parity gate whenever the
    # recorded run itself uses a nonzero delay.
    delay: str = "none"  # none | poisson | geometric
    delay_rate: float = 1.0
    delay_max: int = 8
    staleness_decay: float = 0.8
    buffer_k: int = 0
    # time-to-accuracy: when > 0, the report records the first round (and
    # wall-clock second) at which each engine's training loss reaches the
    # target — the async-vs-synchronous TTA comparison
    ttac_target_loss: float = 0.0

    def __post_init__(self):
        # fail at construction, not mid-benchmark after batches are generated
        if self.step not in ("sim", "mesh", "shard"):
            raise ValueError(f"unknown step: {self.step!r}")
        if self.step == "mesh" and self.churn != "none":
            raise ValueError("mesh scenarios do not drive churn masks")
        if self.step == "mesh" and self.policy == "none":
            raise ValueError("the mesh round step needs a relay policy")
        if self.step == "mesh" and self.strategy != "colrel_fused":
            # _MeshStep benches build_round_step(relay_mode="fused") — the
            # mesh analogue of colrel_fused; any other strategy would be
            # recorded in the report but not what was measured
            raise ValueError("mesh scenarios bench the fused relay only")
        if self.step == "shard":
            if self.policy == "none":
                raise ValueError("the sharded round step needs a relay policy")
            if self.strategy != "colrel_fused":
                raise ValueError("shard scenarios bench the fused relay only")
            if self.devices < 2:
                raise ValueError("shard scenarios need devices >= 2")
            if self.shard not in ("clients", "d"):
                raise ValueError(f"unknown shard mode: {self.shard!r}")
            if self.exchange not in ("gather", "ring"):
                raise ValueError(f"unknown exchange: {self.exchange!r}")
            if self.shard == "clients" and self.n_clients % self.devices:
                raise ValueError(
                    f"n_clients={self.n_clients} must divide evenly over "
                    f"the {self.devices}-device client axis"
                )
            # backend dispatch under sharding: ring/d refuse kernel backends
            validate_sharded_backend(
                self.relay_backend, shard=self.shard, exchange=self.exchange
            )
            if self.check_backend != "none":
                validate_sharded_backend(
                    self.check_backend, shard=self.shard, exchange=self.exchange
                )
        if self.fading == "corr_uplink" and self.drift != "static":
            raise ValueError("corr_uplink couples p to the fade; set drift='static'")
        if self.topology == "geometric" and self.geo_degree <= 0:
            raise ValueError("geometric topology needs geo_degree > 0")
        if self.sampling not in ("none", "uniform", "fixed_k", "expander"):
            raise ValueError(f"unknown sampling: {self.sampling!r}")
        if self.sampling in ("fixed_k", "expander") and self.sample_k < 1:
            raise ValueError(f"sampling={self.sampling!r} needs sample_k >= 1")
        if self.sampling == "uniform" and not (0.0 < self.sample_rate <= 1.0):
            raise ValueError("uniform sampling needs sample_rate in (0, 1]")
        if self.sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        if self.sampling != "none" and self.step != "sim":
            raise ValueError("cohort sampling drives churn masks: sim path only")
        # the segment backend consumes EdgeRelay operands — single-host sim
        # path only (the mesh/shard steps are dense), and the colrel
        # strategies need a policy that actually emits EdgeRelays
        for be, what in (
            (self.relay_backend, "relay_backend"),
            (self.check_backend, "check_backend"),
        ):
            if be == "segment" and self.step != "sim":
                raise ValueError(
                    f"{what}='segment' runs on the single-host sim path only"
                )
        if (
            self.relay_backend == "segment"
            and self.strategy in ("colrel", "colrel_fused")
            and self.policy != "sparse"
        ):
            raise ValueError(
                "relay_backend='segment' needs policy='sparse' (the other "
                "policies emit dense relay matrices, not EdgeRelays)"
            )
        unknown_engines = set(self.engines) - {"loop", "scan", "pipelined", "async"}
        if unknown_engines:
            raise ValueError(f"unknown engines: {sorted(unknown_engines)}")
        if self.delay not in ("none", "poisson", "geometric"):
            raise ValueError(f"unknown delay: {self.delay!r}")
        if self.delay != "none" and "async" not in self.engines:
            raise ValueError("a delay process only drives the async engine")
        if "async" in self.engines:
            if self.step != "sim":
                raise ValueError("the async engine runs on the sim path only")
            if self.strategy not in _ASYNC_STRATEGIES:
                raise ValueError(
                    f"the async engine supports {_ASYNC_STRATEGIES}, "
                    f"not {self.strategy!r}"
                )
        if not 0.0 < self.staleness_decay <= 1.0:
            raise ValueError("staleness_decay must be in (0, 1]")
        if self.buffer_k < 0 or self.delay_max < 0:
            raise ValueError("buffer_k and delay_max must be >= 0")
        if self.ttac_target_loss < 0:
            raise ValueError("ttac_target_loss must be >= 0 (0 = off)")
        if self.model not in ("mlp", "resnet20"):
            raise ValueError(f"unknown model: {self.model!r}")
        if self.relay_backend not in RELAY_BACKENDS:
            raise ValueError(
                f"unknown relay_backend: {self.relay_backend!r} "
                f"(known: {RELAY_BACKENDS})"
            )
        if self.check_backend not in ("none",) + RELAY_BACKENDS:
            raise ValueError(f"unknown check_backend: {self.check_backend!r}")
        if self.check_backend == self.relay_backend:
            raise ValueError(
                "check_backend must differ from relay_backend (the parity "
                "check compares the two)"
            )


def _make_mlp(dim: int, width: int, n_classes: int):
    """Spec-sized analogue of ``benchmarks.common.make_mlp`` over flat
    features (leaves keyed ``inputs``/``labels``)."""

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (dim, width)) * dim**-0.5,
            "b1": jnp.zeros((width,)),
            "w2": jax.random.normal(k2, (width, n_classes)) * width**-0.5,
            "b2": jnp.zeros((n_classes,)),
        }

    def loss(params, batch):
        x = batch["inputs"]
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        lg = (h @ params["w2"] + params["b2"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    return init, loss


def _make_resnet20(n_classes: int):
    """The paper's §V model (repro.models.resnet, GN variant) bound to its
    checked-in config; batches carry ``images``/``labels`` leaves
    (CIFAR-shaped, see ``data.synthetic.cifar_like``)."""

    def init(key):
        return init_resnet20(key, _RESNET20_CONFIG, num_classes=n_classes)

    def loss(params, batch):
        return resnet20_loss(params, _RESNET20_CONFIG, batch)

    return init, loss


@dataclasses.dataclass
class ScenarioBundle:
    """Factories the harness calls per engine run."""

    spec: ScenarioSpec
    init_fn: object
    loss_fn: object
    # memoized base graph: every engine run builds a fresh schedule from the
    # same spec, and a 10⁴-node geometric graph is too expensive to resample
    # per run (the schedules copy it on construction, so sharing is safe)
    _adj: object = dataclasses.field(default=None, repr=False)

    def base_adjacency(self):
        if self._adj is None:
            spec = self.spec
            if spec.topology == "ring":
                self._adj = topology.ring(spec.n_clients, spec.ring_k)
            elif spec.topology == "full":
                self._adj = topology.fully_connected(spec.n_clients)
            elif spec.topology == "geometric":
                n = spec.n_clients
                radius = float(np.sqrt(spec.geo_degree / (np.pi * n)))
                self._adj = topology.random_geometric(n, radius, seed=spec.seed)
            else:
                raise ValueError(f"unknown topology: {spec.topology!r}")
        return self._adj

    def base_p(self):
        return connectivity.heterogeneous_profile(self.spec.n_clients).p

    def make_schedule(self) -> channels.ChannelSchedule:
        spec = self.spec
        adj = self.base_adjacency()
        p0 = self.base_p()
        seed = spec.seed + 7
        link = None
        p_process = None
        if spec.fading == "markov":
            link = channels.MarkovLinkProcess(
                adj,
                p_up_to_down=spec.p_up_to_down,
                p_down_to_up=spec.p_down_to_up,
                seed=seed,
            )
        elif spec.fading in ("corr_shadow", "corr_uplink"):
            # one latent field; the link process owns it, the coupled uplink
            # reads it — (adj, p) are jointly sampled per coherence interval
            field = channels.ShadowingField(
                channels.circle_positions(spec.n_clients),
                corr_length=spec.corr_length,
                rho=spec.shadow_rho,
                sigma=spec.shadow_sigma,
                seed=seed,
            )
            link = channels.ShadowedLinkProcess(
                adj, field, threshold=spec.blockage_threshold
            )
            if spec.fading == "corr_uplink":
                # drift='static' is enforced at spec construction
                p_process = channels.CoupledUplinkDrift(
                    p0, field, gain=spec.uplink_gain
                )
        elif spec.fading != "static":
            raise ValueError(f"unknown fading: {spec.fading!r}")
        if spec.drift == "piecewise":
            p_process = channels.PiecewiseConstantDrift(
                p0,
                hold=spec.drift_hold,
                low=0.1,
                high=0.9,
                seed=seed + 1,
            )
        elif spec.drift != "static":
            raise ValueError(f"unknown drift: {spec.drift!r}")
        kw = dict(adj_every=spec.adj_every, p_every=spec.p_every)
        if link is None:
            kw["adj"] = adj
        else:
            kw["link_process"] = link
        if p_process is None:
            kw["p"] = p0
        else:
            kw["p_process"] = p_process
        member = None
        if spec.churn == "rotating":
            member = channels.RotatingCohorts(
                spec.n_clients, n_cohorts=spec.n_cohorts, hold=spec.churn_hold
            )
        elif spec.churn != "none":
            raise ValueError(f"unknown churn: {spec.churn!r}")
        if spec.sampling != "none":
            # cohort sampling composes on top of churn: the sampler's base
            # is the membership process (active = membership ∧ sampled)
            member = channels.CohortSampler(
                spec.n_clients,
                strategy=spec.sampling,
                k=spec.sample_k if spec.sampling != "uniform" else None,
                rate=spec.sample_rate if spec.sampling == "uniform" else None,
                base=member,
                resample_every=spec.sample_every,
                seed=seed + 2,
            )
        if member is not None:
            return channels.ChurnSchedule(membership=member, **kw)
        if link is None and p_process is None:
            return channels.StaticChannel(adj, p0)
        return channels.TimeVaryingChannel(**kw)

    def make_policy(self, tracer=None):
        spec = self.spec
        if spec.policy == "adaptive":
            return channels.AdaptiveOptAlpha(
                sweeps=spec.opt_sweeps,
                warm_sweeps=spec.warm_sweeps,
                method=spec.opt_method,
                tracer=tracer,
            )
        if spec.policy == "sparse":
            return channels.SparseOptAlpha(
                sweeps=spec.opt_sweeps,
                warm_sweeps=spec.warm_sweeps,
                method=spec.opt_method,
                tracer=tracer,
            )
        if spec.policy == "stale":
            return channels.StaleOptAlpha(
                sweeps=spec.opt_sweeps, method=spec.opt_method
            )
        if spec.policy == "none":
            return None
        raise ValueError(f"unknown policy: {spec.policy!r}")

    def make_sim(self) -> FLSimulator:
        spec = self.spec
        return FLSimulator(
            self.loss_fn,
            n_clients=spec.n_clients,
            strategy=spec.strategy,
            p=self.base_p(),
            local_steps=spec.local_steps,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
            relay_backend=spec.relay_backend,
            block_d=spec.block_d,
        )

    def make_delays(self):
        """Fresh delay process for one async-engine run (deterministic:
        every run replays the same arrival stream)."""
        spec = self.spec
        return channels.make_delays(
            spec.delay,
            spec.n_clients,
            rate=spec.delay_rate,
            max_delay=spec.delay_max,
            seed=spec.seed + 11,
        )

    def make_loader(self) -> FederatedLoader:
        spec = self.spec
        if spec.model == "resnet20":
            ds = cifar_like(
                spec.n_train,
                n_classes=spec.n_classes,
                snr=0.5,
                seed=spec.seed,
            )
        else:
            ds = gaussian_classification(
                spec.n_train,
                dim=spec.dim,
                n_classes=spec.n_classes,
                snr=0.5,
                seed=spec.seed,
            )
        parts = iid_partition(ds, spec.n_clients, seed=spec.seed)
        return FederatedLoader(ds, parts, seed=spec.seed)


def build(spec: ScenarioSpec) -> ScenarioBundle:
    if spec.model == "resnet20":
        init_fn, loss_fn = _make_resnet20(spec.n_classes)
    else:
        init_fn, loss_fn = _make_mlp(spec.dim, spec.width, spec.n_classes)
    return ScenarioBundle(spec, init_fn, loss_fn)


# ---------------------------------------------------------------- registry

_REGISTRY: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"scenario already registered: {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r} (known: {known})") from None


def list_scenarios() -> list[ScenarioSpec]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


register(
    ScenarioSpec(
        name="bench_smoke",
        description="tiny CI gate: 64 rounds, n=6, 8-round channel coherence",
        n_clients=6,
        rounds=64,
        local_steps=2,
        local_batch=8,
        dim=32,
        width=16,
        n_train=256,
        adj_every=8,
        p_every=8,
        drift_hold=1,
        chunk=8,
    )
)

_FIG5_500 = register(
    ScenarioSpec(
        name="fig5_500",
        description=(
            "acceptance scenario: Fig. 5 channel (ring(10,2), Markov "
            "fading + p-drift) at a 500-round horizon, 25-round "
            "coherence time"
        ),
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        adj_every=25,
        p_every=25,
        drift_hold=1,
        chunk=25,
    )
)

# chunk-size vs coherence-time sweep: the fig5 channel holds (adj, p) for 25
# rounds, so chunk=25 is the matched point (== fig5_500).  chunk=5 splits
# every epoch into 5 dispatches (dispatch-bound again); chunk=125 pads every
# 25-round epoch to 125 scanned rounds — 5x dead compute per chunk.  The
# recorded trio quantifies the "chunk should track the coherence time" rule
# from the engine docstrings (see docs/benchmarks.md).
for _chunk in (5, 125):
    register(
        dataclasses.replace(
            _FIG5_500,
            name=f"fig5_chunk{_chunk}",
            description=(
                f"chunk sweep: the fig5_500 channel (25-round coherence) "
                f"run at chunk={_chunk} "
                f"({'dispatch-bound' if _chunk < 25 else 'padding-bound'})"
            ),
            chunk=_chunk,
        )
    )

register(
    ScenarioSpec(
        name="fig6_500",
        description="fig5_500 plus rotating-cohort churn (Fig. 6 setting)",
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        adj_every=25,
        p_every=25,
        drift_hold=1,
        chunk=25,
        churn="rotating",
        n_cohorts=5,
        churn_hold=25,
    )
)

register(
    ScenarioSpec(
        name="static_500",
        description="single-epoch control: static channel, maximal fusion",
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        fading="static",
        drift="static",
        chunk=50,
    )
)

register(
    ScenarioSpec(
        name="corr_shadow_500",
        description=(
            "correlated shadowing: GP blockage field over ring positions "
            "(edges sharing a blocked node fail together), static p, "
            "25-round coherence"
        ),
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        fading="corr_shadow",
        drift="static",
        adj_every=25,
        p_every=25,
        chunk=25,
    )
)

register(
    ScenarioSpec(
        name="corr_uplink_500",
        description=(
            "coupled uplink/D2D fading: (adj, p) jointly sampled from one "
            "shadowing field, 25-round coherence"
        ),
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        fading="corr_uplink",
        drift="static",
        adj_every=25,
        p_every=25,
        chunk=25,
    )
)

# ---------------------------------------------------------------- real model

register(
    ScenarioSpec(
        name="resnet20_cifar",
        description=(
            "the paper's §V model: ResNet-20 (GN) on CIFAR-shaped synthetic "
            "batches, paper-faithful relay, pallas mix-kernel parity check"
        ),
        n_clients=4,
        rounds=24,
        local_steps=1,
        local_batch=2,
        strategy="colrel",
        model="resnet20",
        n_train=256,
        adj_every=8,
        p_every=8,
        drift_hold=1,
        chunk=8,
        lr=0.05,
        check_backend="pallas",
    )
)

# ------------------------------------------------------------- relay D-sweep
# The compute-vs-memory-bound crossover of the relay/aggregate hot spot:
# identical channel/engine setting, model size D swept 10⁴ → 10⁷ (the MLP is
# sized so total params ≈ the target D).  Engines run the einsum reference
# (bitwise_match gate applies); the harness's mandatory kernel check re-runs
# the scan engine on the pallas_fused backend and asserts allclose — so every
# recorded BENCH_relay_sweep_* report carries both the reference numbers and
# the kernel parity/throughput (see benchmarks/roofline.py:relay_table).
# block_d grows with D to keep the interpret-mode grid small on CPU; on TPU
# the same specs run with interpret off.

_RELAY_SWEEP = {
    # name suffix -> (dim, width, rounds, block_d); D = dim·w + w + 10·w + 10
    "1e4": (96, 96, 64, None),  # D ≈ 1.03e4
    "1e5": (256, 384, 32, 16384),  # D ≈ 1.03e5
    "1e6": (1024, 960, 16, 131072),  # D ≈ 9.9e5
    "1e7": (3072, 3248, 8, 1048576),  # D ≈ 1.00e7
}

for _suffix, (_dim, _width, _rounds, _block) in _RELAY_SWEEP.items():
    register(
        ScenarioSpec(
            name=f"relay_sweep_{_suffix}",
            description=(
                f"relay hot-spot sweep @ D≈{_suffix}: fused aggregation "
                "over the raveled buffer, static channel, pallas_fused "
                "parity check"
            ),
            n_clients=8,
            rounds=_rounds,
            local_steps=1,
            local_batch=4,
            dim=_dim,
            width=_width,
            n_train=512,
            fading="static",
            drift="static",
            chunk=_rounds,
            block_d=_block,
            check_backend="pallas_fused",
        )
    )

# ------------------------------------------------------------ client n-sweep
# The cohort-sampling scale regime: the padded client dimension grows
# 10³ → 10⁴ while the per-round cohort stays fixed at k=128, the graph stays
# sparse (geometric, expected degree 8) and the relay operand stays O(edges)
# (EdgeRelay + segment backend, policy="sparse").  Every round redraws the
# cohort, so each round is its own channel epoch — the measured regime is
# warm-started sparse re-solves plus segment-sum aggregation.  The n1e3
# point carries the mandatory einsum parity check (the dense reference
# densifies the same EdgeRelays); at n1e4 the dense check matrix would be
# 10⁸ entries, so that point relies on the loop/scan/pipelined bitwise gate.

_SAMPLE_SWEEP = {
    # name suffix -> (n_clients, n_train, rounds, check_backend)
    "n1e3": (1_000, 4_000, 16, "einsum"),
    "n1e4": (10_000, 20_000, 16, "none"),
}

for _suffix, (_n, _train, _rounds, _check) in _SAMPLE_SWEEP.items():
    register(
        ScenarioSpec(
            name=f"sample_sweep_{_suffix}",
            description=(
                f"client n-sweep @ n={_n}: fixed-k cohorts (k=128) on a "
                "sparse geometric graph, sparse OPT-α + segment aggregation"
            ),
            n_clients=_n,
            rounds=_rounds,
            local_steps=1,
            local_batch=2,
            dim=32,
            width=16,
            n_train=_train,
            policy="sparse",
            opt_method="bisect",
            relay_backend="segment",
            check_backend=_check,
            topology="geometric",
            geo_degree=8.0,
            fading="static",
            drift="static",
            sampling="fixed_k",
            sample_k=128,
            chunk=1,
        )
    )

register(
    ScenarioSpec(
        name="sample_sweep_smoke",
        description=(
            "CI-sized cohort-sampling point (n=256, k=32): sparse OPT-α, "
            "segment aggregation and the einsum parity check in seconds"
        ),
        n_clients=256,
        rounds=10,
        local_steps=1,
        local_batch=2,
        dim=32,
        width=16,
        n_train=512,
        policy="sparse",
        opt_method="bisect",
        relay_backend="segment",
        check_backend="einsum",
        topology="geometric",
        geo_degree=8.0,
        fading="static",
        drift="static",
        sampling="fixed_k",
        sample_k=32,
        chunk=1,
    )
)

register(
    ScenarioSpec(
        name="relay_sweep_smoke",
        description=(
            "CI-sized D-sweep point (D≈1e4, 8 rounds): exercises the "
            "pallas_fused kernel check end-to-end in seconds"
        ),
        n_clients=8,
        rounds=8,
        local_steps=1,
        local_batch=4,
        dim=96,
        width=96,
        n_train=512,
        fading="static",
        drift="static",
        chunk=8,
        check_backend="pallas_fused",
    )
)

# --------------------------------------------------------- multi-device mesh
# CPU hosts present a single device unless XLA is told otherwise, so the
# mesh8_* / mesh2_* scenarios run under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (CI's bench-smoke mesh
# leg does; docs/distributed.md shows the invocation).  Registration is pure
# data — the device-count check happens at make_client_mesh time, never at
# import.  The shard gate replaces the bitwise gate: sharded engines must
# agree bitwise *among themselves* and match the single-device loop to the
# documented f32 tolerance (report.shard_check).

register(
    ScenarioSpec(
        name="mesh8_smoke",
        description=(
            "8-device CI gate: client-sharded fused scan over a host mesh, "
            "gather exchange, pallas_fused parity check"
        ),
        n_clients=8,
        rounds=32,
        local_steps=2,
        local_batch=4,
        dim=32,
        width=16,
        n_train=256,
        adj_every=8,
        p_every=8,
        drift_hold=1,
        step="shard",
        devices=8,
        check_backend="pallas_fused",
    )
)

register(
    ScenarioSpec(
        name="mesh8_ring_churn",
        description=(
            "sharded acceptance: block-ring ppermute exchange under "
            "rotating-cohort churn + correlated shadowing, 8 devices"
        ),
        n_clients=8,
        rounds=64,
        local_steps=2,
        local_batch=4,
        dim=32,
        width=16,
        n_train=256,
        fading="corr_shadow",
        drift="static",
        adj_every=8,
        p_every=8,
        churn="rotating",
        n_cohorts=4,
        churn_hold=8,
        step="shard",
        devices=8,
        exchange="ring",
    )
)

register(
    ScenarioSpec(
        name="mesh2_dshard",
        description=(
            "D-axis GSPMD mode: the (n, D) relay contraction partitioned "
            "over a 2-device model axis, static channel"
        ),
        n_clients=8,
        rounds=32,
        local_steps=2,
        local_batch=4,
        dim=32,
        width=16,
        n_train=256,
        fading="static",
        drift="static",
        step="shard",
        devices=2,
        shard="d",
    )
)

register(
    ScenarioSpec(
        name="mesh_corr_500",
        description=(
            "production mesh round step (fused relay) under the coupled "
            "correlated channel: per-round build_round_step vs one "
            "build_scan_round_step dispatch per epoch"
        ),
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        fading="corr_uplink",
        drift="static",
        adj_every=25,
        p_every=25,
        chunk=25,
        step="mesh",
    )
)

# ------------------------------------------------------------ async arrivals
# The staleness-weighted asynchronous engine (repro.fl.async_engine) under
# sampled per-client upload delays.  The recorded quantity is
# time-to-accuracy: rounds and wall-clock seconds to the target training
# loss, async vs the synchronous engines.  Because a delayed run is *meant*
# to diverge from the loop, the loop/scan/pipelined bitwise gate cannot
# cover the async engine; instead the harness re-runs it with the delay
# stripped (delay="none") and asserts bitwise equality with the loop — the
# OPT-α-unbiasedness regression gate for the staleness-weighting math
# (report.async_check; both gates are mandatory and raise on mismatch).

register(
    ScenarioSpec(
        name="async_ttac_500",
        description=(
            "time-to-accuracy under Poisson(1.0) arrival delays: the "
            "staleness-weighted async engine vs the synchronous loop / "
            "pipelined engines on the fig5 channel, delay-0 parity gate on"
        ),
        n_clients=10,
        rounds=500,
        local_steps=2,
        local_batch=8,
        dim=64,
        width=32,
        n_train=1024,
        adj_every=25,
        p_every=25,
        drift_hold=1,
        chunk=25,
        engines=("loop", "pipelined", "async"),
        delay="poisson",
        delay_rate=1.0,
        delay_max=8,
        staleness_decay=0.8,
        ttac_target_loss=0.05,
    )
)

register(
    ScenarioSpec(
        name="async_smoke",
        description=(
            "CI-sized async point: geometric delays, freshest-4 buffer, "
            "staleness weighting and the delay-0 parity gate in seconds"
        ),
        n_clients=6,
        rounds=24,
        local_steps=2,
        local_batch=8,
        dim=32,
        width=16,
        n_train=256,
        adj_every=8,
        p_every=8,
        drift_hold=1,
        chunk=8,
        engines=("loop", "async"),
        delay="geometric",
        delay_rate=1.0,
        delay_max=4,
        staleness_decay=0.8,
        buffer_k=4,
        ttac_target_loss=1.8,
    )
)
