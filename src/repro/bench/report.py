"""Schema-versioned ``BENCH_<scenario>.json`` reports + the CI perf gate.

Report schema (``schema_version`` 1)::

    {
      "schema_version": 1,
      "scenario": "<name>",
      "description": "...",
      "created_unix": 1234567890,
      "jax_version": "0.4.37",
      "backend": "cpu",
      "spec": { ...ScenarioSpec fields... },
      "engines": {
        "loop": {"wall_s": ..., "compile_s": ..., "rounds_per_sec": ...,
                 "trace_count": ..., "dispatches": ..., "final_loss": ...,
                 "overlap_fraction": null, "host_prep_s": null,
                 "host_wait_s": null},
        "scan": { ... },
        "pipelined": { ...incl. the measured overlap metrics... }
      },
      "speedup_rounds_per_sec": 6.2,
      "speedups_vs_loop": {"scan": 6.2, "pipelined": 7.4},
      "bitwise_match": true,
      "telemetry": {            // --trace runs only; null otherwise
        "pipelined": {"wall_s": ..., "phases": {"solve": ..., ...},
                      "attributed_fraction": ..., "counters": {...},
                      "events": ..., "dropped": ...},
        ...
      }
    }

The overlap metrics, ``speedups_vs_loop``, ``model_params``,
``kernel_check``, ``shard_check``, ``async_check``, ``ttac`` and the
``telemetry`` block are additive v1 fields (older readers ignore them;
older reports read back with them absent) — see ``docs/benchmarks.md`` for
the field-by-field reading guide and ``docs/observability.md`` for the
telemetry block.  ``model_params`` is the model's total parameter count D
(the x-axis of the relay D-sweep); ``kernel_check`` records the mandatory
pallas-vs-reference parity pass (backend, tolerances, measured max |Δ|,
kernel throughput) for scenarios with ``check_backend`` set.
``shard_check`` (shard scenarios only, whose ``spec.devices`` records the
mesh size) is the multi-device gate: sharded engines bitwise-identical to
each other, allclose to the single-device loop at the recorded tolerance
(``max_abs_diff`` is the measured divergence — see docs/distributed.md).
``async_check`` (delayed async scenarios) records the mandatory delay-0
parity gate — the async engine with the delay stripped is bitwise-identical
to the loop; ``ttac`` (scenarios with ``ttac_target_loss`` set) is the
per-engine time-to-accuracy block: first round / derived second at which
the training loss reached the target.

The gate (:func:`check_regression`) compares per-engine ``rounds_per_sec``
against a checked-in baseline report and fails when throughput regresses by
more than ``factor`` (default 2×: generous enough to absorb CI-runner noise,
tight enough to catch a lost fusion or an accidental per-round sync).  It
also re-asserts the qualitative invariants the baseline recorded:
``bitwise_match`` and the scan-beats-loop speedup staying within the same
``factor`` of the baseline's.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax

from repro.bench.harness import EngineRun
from repro.bench.scenarios import ScenarioSpec

SCHEMA_VERSION = 1


def make_report(spec: ScenarioSpec, result: dict) -> dict:
    """Assemble the JSON payload from a :func:`run_scenario` result."""
    runs: dict[str, EngineRun] = result["runs"]
    telemetry = {
        name: run.telemetry for name, run in runs.items() if run.telemetry is not None
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "scenario": spec.name,
        "description": spec.description,
        "created_unix": int(time.time()),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        # tuples (e.g. spec.engines) become lists so the payload is exactly
        # what a JSON round trip reads back
        "spec": {
            k: list(v) if isinstance(v, tuple) else v
            for k, v in dataclasses.asdict(spec).items()
        },
        "engines": {name: run.as_dict() for name, run in runs.items()},
        "speedup_rounds_per_sec": result["speedup"],
        "speedups_vs_loop": result.get("speedups", {}),
        "bitwise_match": result["bitwise_match"],
        "model_params": result.get("model_params"),
        "kernel_check": result.get("kernel_check"),
        "shard_check": result.get("shard_check"),
        "async_check": result.get("async_check"),
        "ttac": result.get("ttac"),
        "telemetry": telemetry or None,
    }


def report_path(out_dir, scenario: str) -> pathlib.Path:
    return pathlib.Path(out_dir) / f"BENCH_{scenario}.json"


def write_report(report: dict, out_dir=".") -> pathlib.Path:
    path = report_path(out_dir, report["scenario"])
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return path


def load_report(path) -> dict:
    with open(path) as f:
        report = json.load(f)
    version = report.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema_version {version!r} != {SCHEMA_VERSION}")
    return report


def check_regression(report: dict, baseline: dict, *, factor: float = 2.0) -> list[str]:
    """Compare a fresh report against a baseline; returns failure strings
    (empty ⇒ gate passes).  Only engines present in both are compared."""
    failures = []
    if report.get("scenario") != baseline.get("scenario"):
        failures.append(
            f"scenario mismatch: report {report.get('scenario')!r} vs "
            f"baseline {baseline.get('scenario')!r}"
        )
        return failures
    for name, base in baseline.get("engines", {}).items():
        cur = report.get("engines", {}).get(name)
        if cur is None:
            failures.append(f"engine {name!r} missing from report")
            continue
        base_rps, cur_rps = base["rounds_per_sec"], cur["rounds_per_sec"]
        if cur_rps * factor < base_rps:
            failures.append(
                f"{name}: rounds/sec regressed >{factor:g}x "
                f"({cur_rps:.1f} vs baseline {base_rps:.1f})"
            )
        if cur["trace_count"] > base["trace_count"]:
            failures.append(
                f"{name}: trace_count grew ({cur['trace_count']} vs "
                f"baseline {base['trace_count']}) — the engine retraces"
            )
    if baseline.get("bitwise_match") and report.get("bitwise_match") is False:
        failures.append("scan engine no longer bit-identical to the loop")
    base_speedup = baseline.get("speedup_rounds_per_sec")
    cur_speedup = report.get("speedup_rounds_per_sec")
    if base_speedup and cur_speedup and cur_speedup * factor < base_speedup:
        failures.append(
            f"scan-over-loop speedup collapsed: {cur_speedup:.2f}x vs "
            f"baseline {base_speedup:.2f}x"
        )
    return failures
