"""Timing harness: one scenario, every engine, cold + warm runs.

Per engine the harness runs the scenario twice on one simulator instance:
the **cold** run pays tracing + XLA compilation, the **warm** run is
steady-state throughput.  Reported quantities:

  wall_s          warm-run wall clock for all ``spec.rounds`` rounds
  compile_s       cold wall minus warm wall (the one-time tracing+compile
                  cost the scan engines amortize over the whole horizon)
  rounds_per_sec  spec.rounds / wall_s — the headline engine throughput
  trace_count     compiles observed across both runs (the no-retrace
                  invariant: 1 for the loop step, ≤ 2 for the scan engines)

The ``pipelined`` engine additionally reports its host/device overlap
(warm run): ``host_prep_s`` (worker-thread staging time), ``host_wait_s``
(how long the consumer actually blocked on staged work) and
``overlap_fraction = 1 - wait/prep`` — the share of host work hidden
behind device execution.

Fairness: the per-round batch stream is pre-generated once (host numpy) and
replayed identically to every run of every engine, and each run builds a
fresh schedule / policy / loader from the same seeds — so all engines
consume bit-identical data, τ randomness and relay matrices, and the harness
can (and does) assert their final parameters match bit-for-bit.

``spec.step = "mesh"`` swaps the execution path under measurement: instead
of ``FLSimulator`` / :class:`EpochScanEngine`, the engines are the
production mesh round steps — per-round :func:`build_round_step` ("loop"),
one :func:`build_scan_round_step` dispatch per channel epoch ("scan"), or
one τ-fused :func:`build_fused_scan_round_step` dispatch per epoch with the
host side prefetched ("pipelined").  Same fairness contract, same bitwise
assertion.

``spec.step = "shard"`` measures the **multi-device** path: "loop" stays
the single-device per-round reference, while "scan" / "pipelined" run the
`shard_map` step (:func:`build_sharded_scan_round_step`) through
:class:`~repro.fl.engine.ShardedScanEngine` across a forced host mesh of
``spec.devices`` devices — serial vs prefetched staging, with staged epochs
``device_put`` directly into their sharded layout.  The bitwise assertion
becomes the *shard gate*: sharded engines bitwise-identical to each other,
allclose (1e-5) to the loop — the measured max |Δ| lands in the report's
``shard_check`` block (see docs/distributed.md for why the loop comparison
is a tolerance, not bitwise).
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.scenarios import ScenarioBundle, ScenarioSpec, build
from repro.channels.scheduler import SegmentPrefetcher
from repro.core.aggregation import ServerOpt
from repro.fl.distributed import (
    build_fused_scan_round_step,
    build_round_step,
    build_scan_round_step,
    build_sharded_scan_round_step,
)
from repro.fl.async_engine import AsyncRoundEngine
from repro.fl.engine import (
    EpochScanEngine,
    PipelinedScanEngine,
    ShardedScanEngine,
    run_rounds_loop,
)
from repro.launch.mesh import make_client_mesh
from repro.obs import (
    NULL_TRACER,
    Tracer,
    phase_attribution,
    write_chrome_trace,
    write_jsonl,
)
from repro.optim.sgd import ClientOpt
from repro.utils import tree_size

# tolerance of the mandatory kernel parity check (run_scenario): the kernel
# backend re-runs the scan engine and its final params must match the einsum
# reference to f32 accumulation accuracy over the scenario horizon
KERNEL_CHECK_RTOL = 1e-5
KERNEL_CHECK_ATOL = 1e-5


@dataclasses.dataclass
class EngineRun:
    """One engine's measurements on one scenario.

    ``dispatches`` counts compiled round-engine calls only (loop: one step
    call per round; scan: one chunk scan per ⌈len/chunk⌉ per epoch;
    pipelined: identical chunk count, but each dispatch also covers the τ
    draws) — separate τ-sampling calls and H2D transfers are excluded on
    all sides.

    The ``host_*`` / ``overlap_fraction`` fields are the pipelined engine's
    prefetcher measurements (warm run); ``None`` for engines without a
    prefetcher.
    """

    engine: str
    wall_s: float
    compile_s: float
    rounds_per_sec: float
    trace_count: int
    dispatches: int
    final_loss: float
    overlap_fraction: float | None = None
    steady_overlap_fraction: float | None = None
    host_prep_s: float | None = None
    host_wait_s: float | None = None
    chunks_staged: int | None = None
    # traced-pass artifacts (``trace_dir`` runs only): the Chrome trace on
    # disk and the per-phase attribution summary.  The traced pass is a
    # *third* run — its fences serialize the pipeline (observer effect), so
    # the perf numbers above always come from the untraced warm run.
    trace_path: str | None = None
    telemetry: dict | None = None
    # the warm run's per-round loss trajectory (host floats) — consumed by
    # the time-to-accuracy block (run_scenario), not serialized per engine
    losses: list | None = None

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # the telemetry block is aggregated once at the report's top level
        # (make_report), not duplicated per engine entry; the loss
        # trajectory is distilled into the ttac block
        d.pop("telemetry")
        d.pop("losses")
        return d


def _pregenerate_batches(bundle: ScenarioBundle) -> list:
    """Materialize the full per-round batch stream once (numpy), replayed
    identically to every engine run."""
    spec = bundle.spec
    loader = bundle.make_loader()
    return [
        loader.round_batch(spec.local_steps, spec.local_batch)
        for _ in range(spec.rounds)
    ]


def _run_once(bundle: ScenarioBundle, engine, batches: list, tracer=None):
    """One full pass over the scenario; returns (wall_s, metrics, params).
    ``tracer`` threads telemetry through every layer of the pass (schedule
    instants, policy solve spans, engine dispatch/fence spans)."""
    spec = bundle.spec
    schedule = bundle.make_schedule()
    policy = bundle.make_policy(tracer=tracer)
    if tracer is not None:
        schedule.tracer = tracer
    params = bundle.init_fn(jax.random.key(spec.seed))
    fused = isinstance(
        engine, (EpochScanEngine, PipelinedScanEngine, AsyncRoundEngine)
    )
    sim = engine.sim if fused else engine
    server_state = sim.init_server_state(params)
    key = jax.random.key(spec.seed + 1)
    stream = iter(batches)
    t0 = time.perf_counter()
    if fused:
        params, server_state, metrics, _ = engine.run_schedule(
            key,
            params,
            server_state,
            schedule=schedule,
            rounds=spec.rounds,
            next_batch=lambda: next(stream),
            lr=spec.lr,
            policy=policy,
        )
    else:
        params, server_state, metrics, _ = run_rounds_loop(
            engine,
            key,
            params,
            server_state,
            schedule=schedule,
            rounds=spec.rounds,
            next_batch=lambda: next(stream),
            lr=spec.lr,
            policy=policy,
            tracer=tracer,
        )
    if tracer is not None:
        # the trailing drain belongs to the device phase too
        with tracer.span("run.finalize", cat="device", track="device"):
            jax.block_until_ready(params)
    else:
        jax.block_until_ready(params)
    return time.perf_counter() - t0, metrics, params


def _finish_trace(tracer: Tracer, trace_dir, scenario: str, engine: str):
    """Export a traced pass (Chrome trace + JSONL) and distill its telemetry
    block: per-phase attribution plus counters.  ``attributed_fraction`` is
    the share of the trace's wall span covered by phase spans — the rest is
    untraced host glue."""
    trace_dir = pathlib.Path(trace_dir)
    path = trace_dir / f"TRACE_{scenario}_{engine}.json"
    write_chrome_trace(tracer, path)
    write_jsonl(tracer, path.with_suffix(".jsonl"))
    phases = phase_attribution(tracer.events)
    wall = tracer.wall_seconds()
    telemetry = {
        "wall_s": wall,
        "phases": phases,
        "attributed_fraction": sum(phases.values()) / wall if wall > 0 else 0.0,
        "counters": dict(tracer.counters),
        "events": len(tracer.events),
        "dropped": tracer.dropped,
    }
    return str(path), telemetry


class _MeshStep:
    """The jitted mesh round steps with trace counting — the bench analogue
    of ``FLSimulator.trace_count`` for ``repro.fl.distributed``.  The
    counters increment at trace time only (python side of the jit)."""

    def __init__(self, bundle: ScenarioBundle):
        spec = bundle.spec
        self.trace_count = 0
        kw = dict(
            n_clients=spec.n_clients,
            local_steps=spec.local_steps,
            relay_mode="fused",
            relay_backend=spec.relay_backend,
            block_d=spec.block_d,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
        )
        round_fn = build_round_step(bundle.loss_fn, **kw)
        scan_fn = build_scan_round_step(bundle.loss_fn, **kw)
        fused_fn = build_fused_scan_round_step(bundle.loss_fn, **kw)

        def counted_round(params, ss, batch, tau, lr, A):
            self.trace_count += 1
            return round_fn(params, ss, batch, tau, lr, A)

        def counted_scan(params, ss, batches, taus, lr, A):
            self.trace_count += 1
            return scan_fn(params, ss, batches, taus, lr, A)

        def counted_fused(key, params, ss, batches, p, lr, A):
            self.trace_count += 1
            return fused_fn(key, params, ss, batches, p, lr, A)

        self.round = jax.jit(counted_round)
        self.scan = jax.jit(counted_scan)
        self.fused = jax.jit(counted_fused)


def _run_mesh_once(
    bundle: ScenarioBundle, step: _MeshStep, name: str, batches: list, tracer=None
):
    """One full mesh-path pass; returns (wall_s, losses, params, n_segments,
    prefetch_stats).  Walks ``schedule.segments()`` exactly like
    ``EpochScanEngine.run_schedule``: one OPT-α solve and one τ block per
    epoch, with the τ key chain advanced once per round so every engine
    consumes identical randomness.  The ``pipelined`` engine stages whole
    segments through a :class:`SegmentPrefetcher` and dispatches the τ-fused
    epoch scan — the key chain advances on device, identically.  ``tracer``
    adds the same span set as the sim path (stage/dispatch/device)."""
    spec = bundle.spec
    schedule = bundle.make_schedule()
    policy = bundle.make_policy(tracer=tracer)
    tr = NULL_TRACER if tracer is None else tracer
    if tracer is not None:
        schedule.tracer = tracer
    if policy is None:
        raise ValueError("the mesh round step needs a relay policy")
    params = bundle.init_fn(jax.random.key(spec.seed))
    server_state = None
    key = jax.random.key(spec.seed + 1)
    stream = iter(batches)
    losses = []
    n_segments = 0
    prefetch_stats = None
    t0 = time.perf_counter()
    if name == "pipelined":
        # chunk=spec.rounds ⇒ one staged item per segment: the mesh scan
        # path dispatches whole epochs, so the pipelined variant must too
        # for the dispatch counts to be comparable
        prefetcher = SegmentPrefetcher(
            schedule,
            spec.rounds,
            chunk=spec.rounds,
            next_batch=lambda: next(stream),
            policy=policy,
            tracer=tracer,
        )
        try:
            for item in prefetcher:
                seg = item.segment
                if seg.active is not None:
                    raise ValueError("mesh bench path does not drive churn masks")
                n_segments += 1
                A = jnp.asarray(item.A, jnp.float32)
                p = jnp.asarray(seg.p, jnp.float32)
                # item.batches is already device-resident (staged transfer)
                if tr.enabled:
                    with tr.span(
                        "mesh.fused", cat="dispatch", epoch=seg.epoch_id
                    ):
                        key, params, server_state, seg_losses = step.fused(
                            key, params, server_state, item.batches, p, spec.lr, A
                        )
                else:
                    key, params, server_state, seg_losses = step.fused(
                        key, params, server_state, item.batches, p, spec.lr, A
                    )
                prefetcher.note_inflight(seg_losses)
                if tr.enabled:
                    with tr.span(
                        "mesh.device",
                        cat="device",
                        track="device",
                        epoch=seg.epoch_id,
                    ):
                        jax.block_until_ready(seg_losses)
                losses.append(seg_losses)
        finally:
            prefetcher.close()
        prefetch_stats = prefetcher.stats
    else:
        for seg in schedule.segments(spec.rounds):
            if seg.active is not None:
                raise ValueError("mesh bench path does not drive churn masks")
            n_segments += 1
            A = jnp.asarray(policy.relay_matrix(seg.state), jnp.float32)
            p = jnp.asarray(seg.p, jnp.float32)
            taus = []
            for _ in range(seg.n_rounds):
                key, sub = jax.random.split(key)
                taus.append(jax.random.bernoulli(sub, p).astype(jnp.float32))
            seg_batches = [next(stream) for _ in range(seg.n_rounds)]
            if name == "loop":
                for r in range(seg.n_rounds):
                    if tr.enabled:
                        with tr.span("mesh.stage", cat="stage", epoch=seg.epoch_id):
                            batch = jax.tree.map(jnp.asarray, seg_batches[r])
                        with tr.span(
                            "mesh.round", cat="dispatch", epoch=seg.epoch_id
                        ):
                            params, server_state, loss = step.round(
                                params, server_state, batch, taus[r], spec.lr, A
                            )
                        with tr.span(
                            "mesh.sync", cat="device", track="device"
                        ):
                            losses.append(float(loss))
                        continue
                    batch = jax.tree.map(jnp.asarray, seg_batches[r])
                    params, server_state, loss = step.round(
                        params, server_state, batch, taus[r], spec.lr, A
                    )
                    # the per-round host sync every loop driver models (see
                    # run_rounds_loop) — without it async dispatch pipelines
                    # the round calls and the loop baseline measures the
                    # wrong thing
                    losses.append(float(loss))
            else:
                if tr.enabled:
                    with tr.span("mesh.stage", cat="stage", epoch=seg.epoch_id):
                        stacked = jax.tree.map(
                            lambda *xs: jnp.asarray(np.stack(xs)), *seg_batches
                        )
                    with tr.span("mesh.scan", cat="dispatch", epoch=seg.epoch_id):
                        params, server_state, seg_losses = step.scan(
                            params, server_state, stacked, jnp.stack(taus), spec.lr, A
                        )
                    with tr.span(
                        "mesh.device", cat="device", track="device"
                    ):
                        jax.block_until_ready(seg_losses)
                else:
                    stacked = jax.tree.map(
                        lambda *xs: jnp.asarray(np.stack(xs)), *seg_batches
                    )
                    params, server_state, seg_losses = step.scan(
                        params, server_state, stacked, jnp.stack(taus), spec.lr, A
                    )
                losses.append(seg_losses)
    if tr.enabled:
        with tr.span("run.finalize", cat="device", track="device"):
            jax.block_until_ready(params)
    else:
        jax.block_until_ready(params)
    wall = time.perf_counter() - t0
    losses = jnp.asarray(losses) if name == "loop" else jnp.concatenate(losses)
    return wall, losses, params, n_segments, prefetch_stats


def _run_mesh_engine(bundle: ScenarioBundle, name: str, batches: list, trace_dir=None):
    """Cold + warm mesh-path pass; mirrors :func:`run_engine`."""
    spec = bundle.spec
    if name not in ("loop", "scan", "pipelined"):
        raise ValueError(f"unknown engine: {name!r}")
    step = _MeshStep(bundle)
    cold_s, _, _, _, _ = _run_mesh_once(bundle, step, name, batches)
    warm = _run_mesh_once(bundle, step, name, batches)
    warm_s, losses, params, n_segments, overlap = warm
    trace_path = telemetry = None
    if trace_dir is not None:
        tracer = Tracer()
        _run_mesh_once(bundle, step, name, batches, tracer=tracer)
        trace_path, telemetry = _finish_trace(tracer, trace_dir, spec.name, name)
    dispatches = spec.rounds if name == "loop" else n_segments
    run = EngineRun(
        engine=name,
        wall_s=warm_s,
        compile_s=max(0.0, cold_s - warm_s),
        rounds_per_sec=spec.rounds / warm_s,
        trace_count=step.trace_count,
        dispatches=dispatches,
        final_loss=float(losses[-1]),
        losses=np.asarray(losses, np.float64).tolist(),
        overlap_fraction=None if overlap is None else overlap.overlap_fraction,
        steady_overlap_fraction=(
            None if overlap is None else overlap.steady_overlap_fraction
        ),
        host_prep_s=None if overlap is None else overlap.prep_s,
        host_wait_s=None if overlap is None else overlap.wait_s,
        chunks_staged=None if overlap is None else overlap.chunks_staged,
        trace_path=trace_path,
        telemetry=telemetry,
    )
    return run, params


class _ShardStep:
    """The single-device per-round reference for the shard path — the loop
    driver the sharded engines are gated against.  Unlike :class:`_MeshStep`
    it threads the churn mask (shard scenarios may rotate cohorts), so the
    trajectory is the reference for churned epochs too."""

    def __init__(self, bundle: ScenarioBundle):
        spec = bundle.spec
        self.trace_count = 0
        round_fn = build_round_step(
            bundle.loss_fn,
            n_clients=spec.n_clients,
            local_steps=spec.local_steps,
            relay_mode="fused",
            relay_backend=spec.relay_backend,
            block_d=spec.block_d,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
        )

        def counted_round(params, ss, batch, tau, lr, A, active):
            self.trace_count += 1
            return round_fn(params, ss, batch, tau, lr, A, active=active)

        self.round = jax.jit(counted_round)


def _shard_mesh(spec: ScenarioSpec):
    """The host mesh a shard scenario runs on: ``spec.devices`` devices on
    one axis — the client axis in clients mode, the model axis in D mode.
    Raises (with the XLA_FLAGS hint) when the host presents fewer devices."""
    axis = "clients" if spec.shard == "clients" else "model"
    return make_client_mesh(spec.devices, axis=axis)


def _run_shard_once(bundle: ScenarioBundle, ex, name: str, batches: list, tracer=None):
    """One full shard-path pass; returns (wall_s, losses, params, dispatches,
    prefetch_stats).  The loop reference draws τ host-side with exactly the
    sharded step's op order (split, then ``Bernoulli(p)`` on the subkey), so
    every engine consumes identical randomness; churn masks flow from the
    schedule segments on both sides."""
    spec = bundle.spec
    schedule = bundle.make_schedule()
    policy = bundle.make_policy(tracer=tracer)
    tr = NULL_TRACER if tracer is None else tracer
    if tracer is not None:
        schedule.tracer = tracer
    if policy is None:
        raise ValueError("the sharded round step needs a relay policy")
    params = bundle.init_fn(jax.random.key(spec.seed))
    server_state = None
    key = jax.random.key(spec.seed + 1)
    stream = iter(batches)
    t0 = time.perf_counter()
    if name == "loop":
        losses = []
        for seg in schedule.segments(spec.rounds):
            A = jnp.asarray(policy.relay_matrix(seg.state), jnp.float32)
            p = jnp.asarray(seg.p, jnp.float32)
            active = (
                None
                if seg.active is None
                else jnp.asarray(seg.active, jnp.float32)
            )
            for _ in range(seg.n_rounds):
                key, sub = jax.random.split(key)
                tau = jax.random.bernoulli(sub, p).astype(jnp.float32)
                if tr.enabled:
                    with tr.span("shard.stage", cat="stage", epoch=seg.epoch_id):
                        batch = jax.tree.map(jnp.asarray, next(stream))
                    with tr.span(
                        "shard.round", cat="dispatch", epoch=seg.epoch_id
                    ):
                        params, server_state, loss = ex.round(
                            params, server_state, batch, tau, spec.lr, A, active
                        )
                    with tr.span("shard.sync", cat="device", track="device"):
                        losses.append(float(loss))
                    continue
                batch = jax.tree.map(jnp.asarray, next(stream))
                params, server_state, loss = ex.round(
                    params, server_state, batch, tau, spec.lr, A, active
                )
                # the per-round host sync every loop driver models
                losses.append(float(loss))
        losses = jnp.asarray(losses)
        dispatches = spec.rounds
        prefetch_stats = None
    else:
        prev = ex.tracer
        if tracer is not None:
            ex.tracer = tracer
        try:
            params, server_state, metrics, key = ex.run_schedule(
                key,
                params,
                server_state,
                schedule=schedule,
                rounds=spec.rounds,
                next_batch=lambda: next(stream),
                lr=spec.lr,
                policy=policy,
            )
        finally:
            ex.tracer = prev
        losses = metrics["loss"]
        dispatches = ex.dispatches
        prefetch_stats = ex.prefetch_stats
    if tr.enabled:
        with tr.span("run.finalize", cat="device", track="device"):
            jax.block_until_ready(params)
    else:
        jax.block_until_ready(params)
    wall = time.perf_counter() - t0
    return wall, losses, params, dispatches, prefetch_stats


def _run_shard_engine(bundle: ScenarioBundle, name: str, batches: list, trace_dir=None):
    """Cold + warm shard-path pass; mirrors :func:`_run_mesh_engine`.  The
    ``loop`` engine is the single-device reference; ``scan`` and
    ``pipelined`` run the `shard_map` step through
    :class:`~repro.fl.engine.ShardedScanEngine` (serial vs prefetched
    staging — the prefetched variant ``device_put``s each staged epoch
    directly into its sharded layout)."""
    spec = bundle.spec
    if name not in ("loop", "scan", "pipelined"):
        raise ValueError(f"unknown engine: {name!r}")
    if name == "loop":
        ex = _ShardStep(bundle)
    else:
        mesh = _shard_mesh(spec)
        step_fn = build_sharded_scan_round_step(
            bundle.loss_fn,
            n_clients=spec.n_clients,
            local_steps=spec.local_steps,
            mesh=mesh,
            shard=spec.shard,
            exchange=spec.exchange,
            relay_mode="fused",
            relay_backend=spec.relay_backend,
            block_d=spec.block_d,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
        )
        ex = ShardedScanEngine(
            step_fn,
            mesh=mesh,
            shard=spec.shard,
            prefetch="serial" if name == "scan" else "inline",
        )
    cold_s, _, _, _, _ = _run_shard_once(bundle, ex, name, batches)
    warm_s, losses, params, dispatches, overlap = _run_shard_once(
        bundle, ex, name, batches
    )
    trace_path = telemetry = None
    if trace_dir is not None:
        tracer = Tracer()
        _run_shard_once(bundle, ex, name, batches, tracer=tracer)
        trace_path, telemetry = _finish_trace(tracer, trace_dir, spec.name, name)
    run = EngineRun(
        engine=name,
        wall_s=warm_s,
        compile_s=max(0.0, cold_s - warm_s),
        rounds_per_sec=spec.rounds / warm_s,
        trace_count=ex.trace_count,
        dispatches=dispatches,
        final_loss=float(losses[-1]),
        losses=np.asarray(losses, np.float64).tolist(),
        overlap_fraction=None if overlap is None else overlap.overlap_fraction,
        steady_overlap_fraction=(
            None if overlap is None else overlap.steady_overlap_fraction
        ),
        host_prep_s=None if overlap is None else overlap.prep_s,
        host_wait_s=None if overlap is None else overlap.wait_s,
        chunks_staged=None if overlap is None else overlap.chunks_staged,
        trace_path=trace_path,
        telemetry=telemetry,
    )
    return run, params


def run_engine(bundle: ScenarioBundle, name: str, batches: list, trace_dir=None):
    """Cold + warm pass of one engine; returns (EngineRun, final params).

    ``trace_dir`` adds a third, *traced* pass on the already-compiled engine
    and writes ``TRACE_<scenario>_<engine>.json`` (+ ``.jsonl``) there.  The
    traced pass fences the device per chunk, so its wall time is not the
    warm measurement — the ``wall_s``/``overlap_fraction`` numbers always
    come from the untraced warm run."""
    spec = bundle.spec
    if spec.step == "mesh":
        return _run_mesh_engine(bundle, name, batches, trace_dir)
    if spec.step == "shard":
        return _run_shard_engine(bundle, name, batches, trace_dir)
    if spec.step != "sim":
        raise ValueError(f"unknown step: {spec.step!r}")
    sim = bundle.make_sim()
    if name in ("scan", "pipelined"):
        cls = EpochScanEngine if name == "scan" else PipelinedScanEngine
        engine = cls(sim, chunk=spec.chunk)
        dispatches = sum(
            -(-seg.n_rounds // spec.chunk)
            for seg in bundle.make_schedule().segments(spec.rounds)
        )
    elif name == "async":
        # each engine run replays the same delay stream (fresh process,
        # same seed); reset=True inside run_schedule makes cold and warm
        # passes identical.  Like the loop, dispatch granularity is one
        # aggregation per round.
        engine = AsyncRoundEngine(
            sim,
            delays=bundle.make_delays(),
            staleness_decay=spec.staleness_decay,
            buffer_k=spec.buffer_k,
            block_d=spec.block_d,
        )
        dispatches = spec.rounds
    elif name == "loop":
        engine = sim
        dispatches = spec.rounds
    else:
        raise ValueError(f"unknown engine: {name!r}")
    cold_s, _, _ = _run_once(bundle, engine, batches)
    warm_s, metrics, params = _run_once(bundle, engine, batches)
    trace_count = engine.trace_count  # engine == sim on the loop path
    overlap = getattr(engine, "prefetch_stats", None)  # warm run's stats
    trace_path = telemetry = None
    if trace_dir is not None:
        tracer = Tracer()
        if name in ("scan", "pipelined", "async"):
            engine.tracer = tracer
        try:
            _run_once(bundle, engine, batches, tracer=tracer)
        finally:
            if name in ("scan", "pipelined", "async"):
                engine.tracer = NULL_TRACER
        trace_path, telemetry = _finish_trace(tracer, trace_dir, spec.name, name)
    run = EngineRun(
        engine=name,
        wall_s=warm_s,
        compile_s=max(0.0, cold_s - warm_s),
        rounds_per_sec=spec.rounds / warm_s,
        trace_count=trace_count,
        dispatches=dispatches,
        final_loss=float(metrics["loss"][-1]),
        losses=np.asarray(metrics["loss"], np.float64).tolist(),
        overlap_fraction=None if overlap is None else overlap.overlap_fraction,
        steady_overlap_fraction=(
            None if overlap is None else overlap.steady_overlap_fraction
        ),
        host_prep_s=None if overlap is None else overlap.prep_s,
        host_wait_s=None if overlap is None else overlap.wait_s,
        chunks_staged=None if overlap is None else overlap.chunks_staged,
        trace_path=trace_path,
        telemetry=telemetry,
    )
    return run, params


def run_scenario(
    spec: ScenarioSpec | str,
    *,
    engines=None,
    check_bitwise: bool = True,
    trace_dir=None,
) -> dict:
    """Run ``spec`` under every engine (default: ``spec.engines``); returns
    ``{"runs": {name: EngineRun}, "speedup": float | None,
    "speedups": {name: float}, "bitwise_match": bool | None,
    "model_params": int, "kernel_check": dict | None,
    "shard_check": dict | None, "async_check": dict | None,
    "ttac": dict | None}``.

    The ``async`` engine (``spec.engines`` includes it) joins the bitwise
    gate only at ``spec.delay == "none"`` — a delayed run diverges from the
    loop *by design*.  A delayed scenario instead gets the **async parity
    gate** (``async_check``): the async engine re-runs with the delay
    stripped and its final parameters must be bitwise-identical to the
    loop's (the staleness-weighting unbiasedness regression; the re-run is
    recorded in ``runs`` as ``async_delay0``).  A mismatch raises.

    ``spec.ttac_target_loss > 0`` adds the ``ttac`` block: per engine, the
    first round (and derived wall-clock second) at which the warm run's
    training loss reaches the target — the async-vs-synchronous
    time-to-accuracy comparison.

    On the shard path (``spec.step == "shard"``) the bitwise gate is
    replaced by the **shard gate** (``shard_check``): the sharded engines
    must be bitwise-identical to *each other*, and allclose to the
    single-device loop at the kernel-check tolerance (the measured
    ``max_abs_diff`` is recorded).  Either violation raises.

    ``speedups[name]`` is that engine's rounds/sec over the loop's (absent
    unless the loop ran); ``speedup`` remains the scan/loop headline for
    schema continuity.  ``bitwise_match`` asserts every fused engine's final
    parameters are bit-identical to the per-round reference — a benchmark
    whose fast path diverges from the reference is measuring the wrong
    thing, so a mismatch raises.

    ``spec.check_backend != "none"`` adds the **mandatory kernel parity
    check**: the scan engine re-runs on that relay backend (same batches,
    same randomness) and its final parameters must be allclose to the
    reference engines' — a mismatch raises, never degrades to a warning.
    The kernel pass is recorded in ``runs`` as ``scan_<backend>`` (so its
    throughput lands in the report and the speedup table) but stays out of
    the bitwise gate, which is reference-backend-only by design.
    """
    if isinstance(spec, str):
        from repro.bench.scenarios import get_scenario

        spec = get_scenario(spec)
    if engines is None:
        engines = spec.engines
    bundle = build(spec)
    model_params = tree_size(bundle.init_fn(jax.random.key(spec.seed)))
    batches = _pregenerate_batches(bundle)
    runs: dict[str, EngineRun] = {}
    finals = {}
    for name in engines:
        runs[name], finals[name] = run_engine(bundle, name, batches, trace_dir)
    kernel_check = None
    if spec.check_backend != "none" and finals:
        kspec = dataclasses.replace(
            spec, relay_backend=spec.check_backend, check_backend="none"
        )
        kname = f"scan_{spec.check_backend}"
        krun, kfinal = run_engine(build(kspec), "scan", batches)
        ref_name = "loop" if "loop" in finals else sorted(finals)[0]
        leaves_r = jax.tree.leaves(finals[ref_name])
        leaves_k = jax.tree.leaves(kfinal)
        max_abs_diff = max(
            (
                float(
                    np.max(
                        np.abs(
                            np.asarray(a, np.float64) - np.asarray(b, np.float64)
                        )
                    )
                )
                for a, b in zip(leaves_r, leaves_k)
            ),
            default=0.0,
        )
        ok = len(leaves_r) == len(leaves_k) and all(
            np.allclose(
                np.asarray(a, np.float64),
                np.asarray(b, np.float64),
                rtol=KERNEL_CHECK_RTOL,
                atol=KERNEL_CHECK_ATOL,
            )
            for a, b in zip(leaves_r, leaves_k)
        )
        if not ok:
            raise AssertionError(
                f"{spec.name}: {spec.check_backend} backend diverged from "
                f"the {spec.relay_backend} reference "
                f"(max |Δ| = {max_abs_diff:.3e} > "
                f"atol {KERNEL_CHECK_ATOL:g} / rtol {KERNEL_CHECK_RTOL:g})"
            )
        runs[kname] = dataclasses.replace(krun, engine=kname)
        kernel_check = {
            "backend": spec.check_backend,
            "reference_backend": spec.relay_backend,
            "engine": "scan",
            "allclose": True,
            "rtol": KERNEL_CHECK_RTOL,
            "atol": KERNEL_CHECK_ATOL,
            "max_abs_diff": max_abs_diff,
            "rounds_per_sec": krun.rounds_per_sec,
        }
    async_check = None
    if "async" in finals and spec.delay != "none" and "loop" in finals:
        # the mandatory async parity gate: strip the delay (and the buffer
        # cap — freshest-k at k < n drops clients even when all arrive
        # fresh) and the engine must reproduce the loop bit-for-bit (same
        # batches, same τ chain) — proof the staleness weighting degrades
        # to OPT-α exactly in the synchronous limit
        dspec = dataclasses.replace(spec, delay="none", buffer_k=0)
        arun, afinal = run_engine(build(dspec), "async", batches)
        leaves_l = jax.tree.leaves(finals["loop"])
        leaves_a = jax.tree.leaves(afinal)
        same = len(leaves_l) == len(leaves_a) and all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(leaves_l, leaves_a)
        )
        if not same:
            raise AssertionError(
                f"{spec.name}: the async engine at delay=0 diverged bitwise "
                "from the per-round loop — the staleness weighting broke "
                "the synchronous-limit contract"
            )
        runs["async_delay0"] = dataclasses.replace(arun, engine="async_delay0")
        async_check = {
            "reference": "loop",
            "bitwise": True,
            "recorded_delay": spec.delay,
            "rounds_per_sec": arun.rounds_per_sec,
        }
    ttac = None
    if spec.ttac_target_loss > 0:
        ttac = {"target_loss": spec.ttac_target_loss, "engines": {}}
        for name, run in runs.items():
            if run.losses is None:
                continue
            arr = np.asarray(run.losses)
            hit = np.nonzero(arr <= spec.ttac_target_loss)[0]
            reached = bool(hit.size)
            rounds_to = int(hit[0]) + 1 if reached else None
            ttac["engines"][name] = {
                "reached": reached,
                "rounds_to_target": rounds_to,
                "seconds_to_target": (
                    rounds_to / run.rounds_per_sec if reached else None
                ),
            }
    speedups = {}
    if "loop" in runs:
        speedups = {
            name: runs[name].rounds_per_sec / runs["loop"].rounds_per_sec
            for name in runs
            if name != "loop"
        }
    speedup = speedups.get("scan")
    bitwise = None
    shard_check = None
    if check_bitwise and "loop" in finals and len(finals) > 1:
        leaves_l = jax.tree.leaves(finals["loop"])
        if spec.step == "shard":
            # The shard gate: sharded engines must agree *bitwise among
            # themselves* (same program, same collectives); against the
            # single-device loop the bar is the documented f32 tolerance —
            # XLA compiles the m-client local scan differently than the
            # n-client program (gather mode), and the ring additionally
            # reassociates the relay accumulation (docs/distributed.md).
            sharded = sorted(k for k in finals if k != "loop")
            ref = jax.tree.leaves(finals[sharded[0]])
            for name in sharded[1:]:
                leaves_e = jax.tree.leaves(finals[name])
                same = len(ref) == len(leaves_e) and all(
                    np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(ref, leaves_e)
                )
                if not same:
                    raise AssertionError(
                        f"{spec.name}: sharded engines {sharded[0]} and "
                        f"{name} diverged bitwise from each other"
                    )
            max_abs_diff = max(
                (
                    float(
                        np.max(
                            np.abs(
                                np.asarray(a, np.float64)
                                - np.asarray(b, np.float64)
                            )
                        )
                    )
                    for a, b in zip(leaves_l, ref)
                ),
                default=0.0,
            )
            ok = len(leaves_l) == len(ref) and all(
                np.allclose(
                    np.asarray(a, np.float64),
                    np.asarray(b, np.float64),
                    rtol=KERNEL_CHECK_RTOL,
                    atol=KERNEL_CHECK_ATOL,
                )
                for a, b in zip(leaves_l, ref)
            )
            if not ok:
                raise AssertionError(
                    f"{spec.name}: sharded engines diverged from the "
                    f"single-device loop (max |Δ| = {max_abs_diff:.3e} > "
                    f"atol {KERNEL_CHECK_ATOL:g} / rtol {KERNEL_CHECK_RTOL:g})"
                )
            shard_check = {
                "shard": spec.shard,
                "exchange": spec.exchange,
                "devices": spec.devices,
                "reference": "loop",
                "allclose": True,
                "bitwise_among_sharded": len(sharded) > 1,
                "rtol": KERNEL_CHECK_RTOL,
                "atol": KERNEL_CHECK_ATOL,
                "max_abs_diff": max_abs_diff,
            }
        else:
            for name, final in finals.items():
                if name == "loop":
                    continue
                if name == "async" and spec.delay != "none":
                    # a delayed async run diverges from the loop by design;
                    # its gate is the delay-0 re-run above (async_check)
                    continue
                leaves_e = jax.tree.leaves(final)
                bitwise = len(leaves_l) == len(leaves_e) and all(
                    np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(leaves_l, leaves_e)
                )
                if not bitwise:
                    raise AssertionError(
                        f"{spec.name}: {name} engine diverged bitwise from "
                        "the per-round reference"
                    )
    return {
        "runs": runs,
        "speedup": speedup,
        "speedups": speedups,
        "bitwise_match": bitwise,
        "model_params": model_params,
        "kernel_check": kernel_check,
        "shard_check": shard_check,
        "async_check": async_check,
        "ttac": ttac,
    }
