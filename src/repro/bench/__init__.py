"""Benchmark subsystem: declarative scenarios, a timing harness, JSON reports.

Three layers, consumed in order:

1. **Scenarios** (`scenarios`) — :class:`ScenarioSpec` declaratively composes
   model size × topology × fading × drift × churn × engine chunking into one
   named, registered benchmark setting.  A spec is data: the same spec drives
   the per-round loop engine and the epoch-segmented scan engine over
   identical randomness, so their outputs are comparable (and bit-identical).

2. **Harness** (`harness`) — :func:`run_scenario` runs a spec under each
   engine twice (cold + warm), measuring wall clock, compile time,
   ``trace_count`` and rounds/sec, and verifies the two engines' final
   parameters match bit-for-bit.

3. **Reports** (`report`) — schema-versioned ``BENCH_<scenario>.json``
   emission, plus :func:`check_regression`, the CI perf gate comparing a
   fresh report against a checked-in baseline (fail when rounds/sec regresses
   by more than the configured factor).

CLI: ``PYTHONPATH=src python -m repro.bench.run --scenario bench_smoke``
(see ``make bench-smoke`` and the ``bench-smoke`` CI job).
"""
from repro.bench.harness import EngineRun, run_scenario
from repro.bench.report import (
    SCHEMA_VERSION,
    check_regression,
    load_report,
    make_report,
    write_report,
)
from repro.bench.scenarios import (
    ScenarioSpec,
    get_scenario,
    list_scenarios,
    register,
)

__all__ = [
    "EngineRun",
    "SCHEMA_VERSION",
    "ScenarioSpec",
    "check_regression",
    "get_scenario",
    "list_scenarios",
    "load_report",
    "make_report",
    "register",
    "run_scenario",
    "write_report",
]
