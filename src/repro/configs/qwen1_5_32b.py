"""qwen1.5-32b [dense]: 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392, vocab=152064,
    head_dim=128, qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=4, head_dim=64,
        d_ff=512, vocab=512)
