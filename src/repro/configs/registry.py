"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, ShapeConfig

ARCHS = {
    "qwen3-14b": "qwen3_14b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2.5-32b": "qwen2_5_32b",
    "whisper-tiny": "whisper_tiny",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "grok-1-314b": "grok_1_314b",
    "qwen1.5-32b": "qwen1_5_32b",
    "glm4-9b": "glm4_9b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "resnet20-cifar": "resnet20_cifar",
}

ASSIGNED = [a for a in ARCHS if a != "resnet20-cifar"]


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.reduced() if reduced else mod.CONFIG


def for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Resolve the shape-dependent attention variant.

    long_500k on full-attention archs uses the sliding-window variant
    (window = cfg.long_context_window) so the KV cache stays bounded —
    DESIGN.md §5.  Whisper (enc-dec) skips long_500k entirely.
    """
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        if not cfg.sliding_window:
            return dataclasses.replace(cfg, sliding_window=cfg.long_context_window)
    return cfg


def is_skipped(arch: str, shape_name: str) -> str | None:
    """Return a reason string if this (arch, shape) pair is skipped."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family == "audio":
        return "enc-dec full-attention decoder: 500k-token decode out of family (DESIGN.md §5)"
    return None
