"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=8, d_ff=27648, vocab=152064,
    head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512)
