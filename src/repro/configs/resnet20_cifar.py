"""ResNet-20 / CIFAR-10 — the paper's own §V model (GN instead of BN,
DESIGN.md §8)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet20-cifar", family="resnet",
    n_layers=20, d_model=64, vocab=10,
    source="paper §V (He et al. CIFAR ResNet-20)",
)

def reduced() -> ModelConfig:
    return CONFIG  # already laptop-scale
