"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba-1 architecture.  [arXiv:2410.05355]"""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, d_ff=0, vocab=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2410.05355",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, vocab=512,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2))
