"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent.  [arXiv:2402.19427]"""
import dataclasses
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
    head_dim=256, act="gelu",
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      block_pattern=("recurrent", "recurrent", "attention"),
                      local_window=2048),
    source="arXiv:2402.19427",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=256, n_heads=4, n_kv=1, head_dim=64,
        d_ff=512, vocab=512,
        rglru=RGLRUConfig(lru_width=256, local_window=64))
