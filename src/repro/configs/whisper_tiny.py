"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
encoder-decoder; mel+conv frontend is a stub (precomputed frame embeddings).
vocab padded 51865 -> 51968 for 16-way tensor parallelism (DESIGN.md §8).
[arXiv:2212.04356]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51968,
    head_dim=64, act="gelu", mlp_gated=False,
    enc_dec=True, n_enc_layers=4, enc_frames=1500,
    source="arXiv:2212.04356",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=128, n_heads=2, n_kv=2,
        head_dim=64, d_ff=256, vocab=512, enc_frames=64)
