"""Config dataclasses for models, FL protocol, sharding and input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0       # 0 → d_model
    conv_width: int = 4
    block_pattern: Sequence[str] = ("recurrent", "recurrent", "attention")
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm | resnet
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0             # 0 → d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0       # glm4 uses partial rotary (0.5)
    sliding_window: int = 0       # 0 → full attention
    long_context_window: int = 8192   # SWA window used for the long_500k variant
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"             # mlp activation; "gelu" for whisper
    mlp_gated: bool = True        # SwiGLU vs plain 2-layer MLP
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500        # stub audio frontend output length for serve shapes
    # vlm
    cross_attn_every: int = 0     # >0 → cross-attn block every k-th layer
    n_image_tokens: int = 1600    # stub vision frontend output length
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # citation for the assigned-architecture pool
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads if self.n_heads else 0)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic total parameter count N (embeddings included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        if self.family == "resnet":
            return 272_474  # resnet-20 CIFAR (analytic, GN variant)
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm.expand * d
            dtr = self.ssm.dt_rank or -(-d // 16)
            per = (d * 2 * di + di * self.ssm.d_conv
                   + di * (dtr + 2 * self.ssm.d_state) + dtr * di
                   + di * self.ssm.d_state + di + di * d + d)
            return L * per + emb + d
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        mlp = d * f * (3 if self.mlp_gated else 2)
        if self.family == "moe":
            mlp = self.moe.n_experts * mlp + d * self.moe.n_experts
        per = attn + mlp + 2 * d
        total = L * per + emb + d
        if self.family == "hybrid":
            # recurrent blocks replace attention in 2/3 of layers; roughly
            # linear-proj dominated — attn estimate is close enough for
            # roofline MODEL_FLOPS (exact count comes from the pytree).
            pass
        if self.enc_dec:
            total += self.n_enc_layers * per
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (attn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        mlp_active = self.moe.top_k * d * f * (3 if self.mlp_gated else 2)
        per = attn + mlp_active + 2 * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * per + emb + d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """ColRel protocol configuration."""
    n_clients: int = 16
    local_steps: int = 1          # T
    topology: str = "ring"        # ring | fct | disconnected | er | clusters
    topology_k: int = 1
    p_profile: str = "heterogeneous"  # homogeneous | heterogeneous | paper
    p_homogeneous: float = 0.2
    relay_mode: str = "faithful"  # faithful | fused
    aggregation: str = "colrel"   # colrel | colrel_fused | fedavg_* | no_dropout
    server_momentum: float = 0.0
    client_lr: float = 0.1
    weight_decay: float = 1e-4
    opt_alpha_sweeps: int = 50


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    mode: str = "tp"   # "tp" (weights over model axis) | "fsdp_tp" (2-D)
    remat: bool = True


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    fl: FLConfig
    sharding: ShardingConfig
