"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers (every 5th layer); vision encoder
is a stub (precomputed projected patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=128256,
    head_dim=128, cross_attn_every=5, n_image_tokens=1600,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=10, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512, cross_attn_every=5, n_image_tokens=16)
