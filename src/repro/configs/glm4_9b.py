"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 —
RoPE (partial rotary 0.5), GQA.  [hf:THUDM/glm-4-9b]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=2, d_ff=13696, vocab=151552,
    head_dim=128, rotary_pct=0.5,
    source="hf:THUDM/glm-4-9b",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512)
