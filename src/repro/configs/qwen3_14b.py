"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
— qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, d_ff=17408, vocab=151936,
    head_dim=128, qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512)
