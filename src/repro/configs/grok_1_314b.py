"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768, vocab=131072,
    head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2),
    source="hf:xai-org/grok-1",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512, moe=MoEConfig(n_experts=4, top_k=2))
