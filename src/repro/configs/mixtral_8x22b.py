"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    head_dim=128, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv=2, head_dim=64,
        d_ff=512, vocab=512, sliding_window=64,
        moe=MoEConfig(n_experts=4, top_k=2))
