"""Client connectivity graphs G = (V, E) for the D2D relay network.

The graph is undirected and need not be connected (paper §II-B).  We represent
it by a dense boolean adjacency matrix with a zero diagonal; the neighborhood
closure ``N_i ∪ {i}`` used throughout the ColRel algebra is ``adj | I``.
"""
from __future__ import annotations

import numpy as np


def _validate(adj: np.ndarray) -> np.ndarray:
    adj = np.asarray(adj, dtype=bool)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric (graph is undirected)")
    np.fill_diagonal(adj, False)
    return adj


def fully_connected(n: int) -> np.ndarray:
    """FCT of paper Fig. 2: every client sees every other client."""
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def ring(n: int, k: int = 1) -> np.ndarray:
    """Ring topology of paper Fig. 3 (k=1) / Fig. 4 (k=2: 4 nearest neighbors).

    Client i is connected to clients (i ± d) mod n for d in 1..k.
    """
    if k < 0:
        raise ValueError("k must be >= 0")
    adj = np.zeros((n, n), dtype=bool)
    for d in range(1, k + 1):
        for i in range(n):
            adj[i, (i + d) % n] = True
            adj[i, (i - d) % n] = True
    np.fill_diagonal(adj, False)
    return adj


def disconnected(n: int) -> np.ndarray:
    """No D2D links: ColRel degenerates to plain FedAvg-with-dropout."""
    return np.zeros((n, n), dtype=bool)


def erdos_renyi(n: int, p: float, seed: int = 0) -> np.ndarray:
    """Random G(n, p) graph (symmetrized upper triangle)."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, 1)
    adj = adj | adj.T
    return _validate(adj)


def clusters(n: int, n_clusters: int) -> np.ndarray:
    """Disjoint fully-connected clusters (the paper allows disconnected G)."""
    if n_clusters < 1:
        raise ValueError("need at least one cluster")
    adj = np.zeros((n, n), dtype=bool)
    bounds = np.linspace(0, n, n_clusters + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        adj[lo:hi, lo:hi] = True
    np.fill_diagonal(adj, False)
    return adj


def from_edges(n: int, edges) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    for i, j in edges:
        if i == j:
            continue
        adj[i, j] = adj[j, i] = True
    return adj


def neighborhoods(adj: np.ndarray) -> list[np.ndarray]:
    """N_i for each client (indices, excluding self)."""
    adj = _validate(adj.copy())
    return [np.nonzero(adj[i])[0] for i in range(adj.shape[0])]


def closed_mask(adj: np.ndarray) -> np.ndarray:
    """Boolean mask of N_i ∪ {i}: entry [j, i] = can j's update reach relay i."""
    adj = _validate(adj.copy())
    return adj | np.eye(adj.shape[0], dtype=bool)


def common_neighborhood_sets(adj: np.ndarray) -> np.ndarray:
    """mask[j, i, l] = j ∈ N_il = (N_i ∪ {i}) ∩ (N_l ∪ {l}) (paper eq. 4)."""
    m = closed_mask(adj)  # [j, i]
    return m[:, :, None] & m[:, None, :]


class ClosedGraph:
    """CSC view of the closed neighborhoods N_i ∪ {i}.

    Column i of the closed mask is stored as the sorted row indices
    ``rows[indptr[i]:indptr[i+1]]`` — the only slots j with adj[j, i] or
    j == i.  Everything that is O(n²) on the dense mask (column supports,
    row masses, the relay contraction itself) becomes O(E) on this view,
    which is what lets OPT-α and the segment relay backend scale to
    n ≫ 10³ sparse graphs.
    """

    __slots__ = ("n", "indptr", "rows", "cols")

    def __init__(self, indptr: np.ndarray, rows: np.ndarray):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.rows = np.asarray(rows, dtype=np.int64)
        self.n = self.indptr.size - 1
        # flat column index per stored entry: entry k lives in column cols[k]
        self.cols = np.repeat(
            np.arange(self.n, dtype=np.int64), np.diff(self.indptr)
        )

    @property
    def nnz(self) -> int:
        return int(self.rows.size)

    def column(self, i: int) -> np.ndarray:
        """Sorted row indices of closed column i (N_i ∪ {i})."""
        return self.rows[self.indptr[i] : self.indptr[i + 1]]

    def column_counts(self) -> np.ndarray:
        """|N_i| + 1 per column — the deg+1 normalizer of initial_weights."""
        return np.diff(self.indptr)

    def todense_mask(self) -> np.ndarray:
        m = np.zeros((self.n, self.n), dtype=bool)
        m[self.rows, self.cols] = True
        return m


def closed_csc(adj: np.ndarray) -> ClosedGraph:
    """Build the CSC closed-neighborhood structure from a dense adjacency.

    Row indices within each column come out sorted ascending (including the
    diagonal i itself), so per-column slices line up with the dense
    ``np.nonzero(closed_mask(adj)[:, i])`` ordering bit-for-bit.
    """
    m = closed_mask(adj)
    # nonzero on the transpose walks column-major: entries grouped by column
    cols, rows = np.nonzero(m.T)
    counts = np.bincount(cols, minlength=m.shape[0])
    indptr = np.concatenate(([0], np.cumsum(counts)))
    return ClosedGraph(indptr, rows)


def random_geometric(
    n: int, radius: float, *, seed: int = 0
) -> np.ndarray:
    """Random geometric graph on the unit square: clients at uniform
    positions, linked iff within ``radius``.

    Grid-binned neighbor search (cell size = radius) so construction is
    O(n · expected-degree), not O(n²) — the only graph family here that
    stays buildable at n = 10⁴⁺.  Expected degree ≈ n·π·radius², so pick
    ``radius = sqrt(deg / (π·n))`` for a target average degree.
    """
    if radius <= 0:
        raise ValueError("radius must be positive")
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 2))
    ncell = max(1, int(np.floor(1.0 / radius)))
    cell = np.minimum((pos * ncell).astype(np.int64), ncell - 1)
    cell_id = cell[:, 0] * ncell + cell[:, 1]
    order = np.argsort(cell_id, kind="stable")
    sorted_ids = cell_id[order]
    # bucket boundaries in the sorted order, keyed by flat cell id
    starts = np.searchsorted(sorted_ids, np.arange(ncell * ncell))
    ends = np.searchsorted(sorted_ids, np.arange(ncell * ncell), side="right")
    r2 = radius * radius
    src: list[np.ndarray] = []
    dst: list[np.ndarray] = []
    for cx in range(ncell):
        for cy in range(ncell):
            mine = order[starts[cx * ncell + cy] : ends[cx * ncell + cy]]
            if mine.size == 0:
                continue
            for dx in (0, 1):
                for dy in (-1, 0, 1):
                    if dx == 0 and dy < 0:
                        continue  # each unordered cell pair visited once
                    nx, ny = cx + dx, cy + dy
                    if not (0 <= nx < ncell and 0 <= ny < ncell):
                        continue
                    theirs = order[starts[nx * ncell + ny] : ends[nx * ncell + ny]]
                    if theirs.size == 0:
                        continue
                    d = pos[mine, None, :] - pos[None, theirs, :]
                    hit = (d * d).sum(axis=-1) <= r2
                    if dx == 0 and dy == 0:
                        hit = np.triu(hit, 1)  # dedupe within-cell pairs
                    ii, jj = np.nonzero(hit)
                    if ii.size:
                        src.append(mine[ii])
                        dst.append(theirs[jj])
    adj = np.zeros((n, n), dtype=bool)
    if src:
        i = np.concatenate(src)
        j = np.concatenate(dst)
        adj[i, j] = True
        adj[j, i] = True
    np.fill_diagonal(adj, False)
    return adj
