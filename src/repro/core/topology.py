"""Client connectivity graphs G = (V, E) for the D2D relay network.

The graph is undirected and need not be connected (paper §II-B).  We represent
it by a dense boolean adjacency matrix with a zero diagonal; the neighborhood
closure ``N_i ∪ {i}`` used throughout the ColRel algebra is ``adj | I``.
"""
from __future__ import annotations

import numpy as np


def _validate(adj: np.ndarray) -> np.ndarray:
    adj = np.asarray(adj, dtype=bool)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric (graph is undirected)")
    np.fill_diagonal(adj, False)
    return adj


def fully_connected(n: int) -> np.ndarray:
    """FCT of paper Fig. 2: every client sees every other client."""
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def ring(n: int, k: int = 1) -> np.ndarray:
    """Ring topology of paper Fig. 3 (k=1) / Fig. 4 (k=2: 4 nearest neighbors).

    Client i is connected to clients (i ± d) mod n for d in 1..k.
    """
    if k < 0:
        raise ValueError("k must be >= 0")
    adj = np.zeros((n, n), dtype=bool)
    for d in range(1, k + 1):
        for i in range(n):
            adj[i, (i + d) % n] = True
            adj[i, (i - d) % n] = True
    np.fill_diagonal(adj, False)
    return adj


def disconnected(n: int) -> np.ndarray:
    """No D2D links: ColRel degenerates to plain FedAvg-with-dropout."""
    return np.zeros((n, n), dtype=bool)


def erdos_renyi(n: int, p: float, seed: int = 0) -> np.ndarray:
    """Random G(n, p) graph (symmetrized upper triangle)."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, 1)
    adj = adj | adj.T
    return _validate(adj)


def clusters(n: int, n_clusters: int) -> np.ndarray:
    """Disjoint fully-connected clusters (the paper allows disconnected G)."""
    if n_clusters < 1:
        raise ValueError("need at least one cluster")
    adj = np.zeros((n, n), dtype=bool)
    bounds = np.linspace(0, n, n_clusters + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        adj[lo:hi, lo:hi] = True
    np.fill_diagonal(adj, False)
    return adj


def from_edges(n: int, edges) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    for i, j in edges:
        if i == j:
            continue
        adj[i, j] = adj[j, i] = True
    return adj


def neighborhoods(adj: np.ndarray) -> list[np.ndarray]:
    """N_i for each client (indices, excluding self)."""
    adj = _validate(adj.copy())
    return [np.nonzero(adj[i])[0] for i in range(adj.shape[0])]


def closed_mask(adj: np.ndarray) -> np.ndarray:
    """Boolean mask of N_i ∪ {i}: entry [j, i] = can j's update reach relay i."""
    adj = _validate(adj.copy())
    return adj | np.eye(adj.shape[0], dtype=bool)


def common_neighborhood_sets(adj: np.ndarray) -> np.ndarray:
    """mask[j, i, l] = j ∈ N_il = (N_i ∪ {i}) ∩ (N_l ∪ {l}) (paper eq. 4)."""
    m = closed_mask(adj)  # [j, i]
    return m[:, :, None] & m[:, None, :]
