"""Intermittent client→PS connectivity model (paper §II-B).

Connectivity of client i at round r is τ_i(r) ~ Bern(p_i), i.i.d. across
rounds.  The downlink (PS → clients) is assumed reliable, and no client or
the PS observes the realized τ before transmitting — only the marginals p_i
are known (estimated from pilots in the paper's setting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class ConnectivityModel:
    """Bernoulli uplink model with per-client success probabilities."""

    def __init__(self, p):
        p = np.asarray(p, dtype=np.float32)
        if p.ndim != 1:
            raise ValueError("p must be a vector of per-client probabilities")
        if np.any(p < 0) or np.any(p > 1):
            raise ValueError("probabilities must lie in [0, 1]")
        self.p = p
        self.n = int(p.shape[0])

    def sample(self, key: jax.Array) -> jax.Array:
        """One round of τ ∈ {0,1}^n."""
        return jax.random.bernoulli(key, jnp.asarray(self.p)).astype(jnp.float32)

    def sample_rounds(self, key: jax.Array, rounds: int) -> jax.Array:
        """(rounds, n) matrix of τ realizations."""
        return jax.random.bernoulli(
            key, jnp.asarray(self.p), shape=(rounds, self.n)
        ).astype(jnp.float32)


def homogeneous(n: int, p: float) -> ConnectivityModel:
    """Paper Fig. 2: p_i = p for all clients."""
    return ConnectivityModel(np.full((n,), p, dtype=np.float32))


def paper_heterogeneous() -> ConnectivityModel:
    """The exact p-vector of paper Figs. 3-4 (n = 10)."""
    return ConnectivityModel(
        np.array([0.1, 0.2, 0.3, 0.1, 0.1, 0.5, 0.8, 0.1, 0.2, 0.9], dtype=np.float32)
    )


def heterogeneous_profile(
    n: int, low: float = 0.1, high: float = 0.9, seed: int = 0
) -> ConnectivityModel:
    """A deliberately skewed profile in the paper's spirit: some clients with
    very low, some moderate, some very high connectivity."""
    rng = np.random.default_rng(seed)
    base = np.array([low, 0.2, 0.3, low, low, 0.5, 0.8, low, 0.2, high])
    if n <= base.size:
        p = base[:n]
    else:
        p = np.concatenate([base, rng.uniform(low, high, size=n - base.size)])
    return ConnectivityModel(p.astype(np.float32))
