"""ColRel core: the paper's contribution as composable JAX modules.

  topology      D2D client graphs (ring / FCT / ER / clusters / ...)
  connectivity  Bernoulli intermittent uplink model τ_i ~ Bern(p_i)
  opt_alpha     OPT-α relay-weight optimization (paper Alg. 3)
  relay         local consensus Δx̃ = A·Δx + fused relay∘aggregate path
  aggregation   PS strategies (colrel / fedavg variants) + server momentum
"""
from repro.core import aggregation, connectivity, opt_alpha, relay, topology

__all__ = ["aggregation", "connectivity", "opt_alpha", "relay", "topology"]
