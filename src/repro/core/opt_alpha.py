"""OPT-α (paper Alg. 3): optimize the relay weight matrix A.

Conventions
-----------
``A[j, i] = α_ji`` is the weight **relay** client ``j`` assigns to **origin**
client ``i``'s update while forming its local consensus
``Δx̃_j = Σ_i α_ji Δx_i``.  The unbiasedness condition (Lemma 1) is then the
per-origin (column) constraint

    Σ_{j ∈ N_i ∪ {i}} p_j · α_ji = 1,      α_ji ≥ 0,
    α_ji = 0 whenever j ∉ N_i ∪ {i}.

The variance proxy being minimized (paper eq. 4) is

    S(p, A) = Σ_{i,l} Σ_{j ∈ N_il} p_j (1 − p_j) α_ji α_jl.

Because α is supported on the closed neighborhoods, the double sum collapses
to row sums:  S(p, A) = Σ_j p_j (1 − p_j) · (Σ_i α_ji)²  — the total mass a
relay forwards is what multiplies its own Bernoulli uplink noise.  We use the
collapsed form for O(n²) evaluation and keep the O(n³) literal form as a
cross-check in the tests.

The Gauss–Seidel sweep (paper eq. 7-9) updates one column at a time; each
column subproblem is solved in closed form through its Lagrange multiplier
λ_i, located by bisection (paper-faithful) or by an exact piecewise-linear
solve (equivalent, used as a fast path / cross-check).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology


@dataclasses.dataclass(frozen=True)
class OptAlphaResult:
    A: np.ndarray                 # (n, n) relay weight matrix, A[j, i] = α_ji
    S_history: np.ndarray         # S(p, A) after each Gauss-Seidel sweep
    feasible_columns: np.ndarray  # bool (n,): column constraint satisfiable
    sweeps: int
    bisection_iters_total: int


def variance_proxy(p: np.ndarray, A: np.ndarray) -> float:
    """S(p, A) via the collapsed row-sum form (see module docstring)."""
    p = np.asarray(p, dtype=np.float64)
    row_mass = A.sum(axis=1)
    return float(np.sum(p * (1.0 - p) * row_mass**2))


def variance_proxy_literal(p: np.ndarray, A: np.ndarray, adj: np.ndarray) -> float:
    """S(p, A) exactly as written in paper eq. (4) — O(n³), test oracle."""
    p = np.asarray(p, dtype=np.float64)
    m = topology.closed_mask(adj)  # m[j, i] = j ∈ N_i ∪ {i}
    n = p.shape[0]
    w = p * (1.0 - p)
    s = 0.0
    for i in range(n):
        for l in range(n):
            for j in range(n):
                if m[j, i] and m[j, l]:
                    s += w[j] * A[j, i] * A[j, l]
    return float(s)


def unbiasedness_residual(p: np.ndarray, A: np.ndarray) -> np.ndarray:
    """Per-column residual of Lemma 1: (p @ A) − 1.  Zero ⇒ unbiased."""
    return np.asarray(p, dtype=np.float64) @ A - 1.0


def initial_weights(p: np.ndarray, adj: np.ndarray) -> np.ndarray:
    """Paper Alg. 3 line 1:  α_ji^(0) = 1 / ((|N_i|+1) · p_j)  on the support.

    When some closed-neighborhood members have p_j = 0 the literal formula
    leaves the column constraint violated (those terms are dropped); we then
    renormalize the column so the unbiasedness constraint holds at init —
    a documented deviation that only triggers with hard-disconnected clients.
    """
    p = np.asarray(p, dtype=np.float64)
    m = topology.closed_mask(adj)  # [j, i]
    sup = m & (p > 0)[:, None]  # empty column ⇒ infeasible, left all-zero
    denom = m.sum(axis=0)[None, :] * np.where(p > 0, p, 1.0)[:, None]
    A = np.where(sup, 1.0 / denom, 0.0)
    col = np.einsum("j,ji->i", p, A)
    fix = (col > 0) & ~np.isclose(col, 1.0)
    A *= np.where(fix, 1.0 / np.where(fix, col, 1.0), 1.0)[None, :]
    return A


# Fallback threshold for warm starts: a carried column is reused only when
# its mass p @ col clears this *relative* fraction of the column's largest
# carried entry (plus the absolute 1e-12 floor).  An absolute-only cutoff let
# columns with tiny-but-positive mass — e.g. every surviving relay of origin
# i is a near-departed client with p_j ≈ ε — be rescaled by ~1/mass into
# enormous α entries, poisoning the Gauss–Seidel seed.
WARM_START_RTOL = 1e-6


def warm_start_weights(
    p: np.ndarray, adj: np.ndarray, A_prev: np.ndarray
) -> np.ndarray:
    """Project a previous epoch's relay matrix onto a new channel ``(p, adj)``.

    Used by the adaptive OPT-α scheduler (``repro.channels.scheduler``): after
    a small channel perturbation the old optimum is a near-feasible point, so
    seeding Gauss–Seidel from it converges in a few sweeps instead of from
    scratch.  Per column i: keep only entries on the new closed neighborhood
    with p_j > 0, rescale so Lemma 1 (Σ_j p_j α_ji = 1) holds under the new p,
    and fall back to the Alg. 3 initial weights for any column whose carried
    mass (nearly) vanished — every old relay of i dropped out of N_i ∪ {i},
    or the survivors' uplinks are so weak that rescaling by 1/mass would blow
    the column up (see :data:`WARM_START_RTOL`).
    """
    p = np.asarray(p, dtype=np.float64)
    adj = np.asarray(adj, dtype=bool)
    m = topology.closed_mask(adj)
    A = np.where(m, np.asarray(A_prev, dtype=np.float64), 0.0)
    A_init = None
    for i in range(p.shape[0]):
        sup = m[:, i] & (p > 0)
        col = np.where(sup, A[:, i], 0.0)
        mass = float(p @ col)
        col_max = float(col.max(initial=0.0))
        if mass > max(1e-12, WARM_START_RTOL * col_max):
            A[:, i] = col / mass
        else:
            if A_init is None:
                A_init = initial_weights(p, adj)
            A[:, i] = A_init[:, i]
    return A


def _solve_column_waterfill(
    p_sup: np.ndarray,
    beta: np.ndarray,
    *,
    tol: float = 1e-12,
    max_iters: int = 200,
) -> tuple[np.ndarray, int]:
    """Solve  min Σ w_j (α_j + β_j)²  s.t.  Σ p_j α_j = 1, α ≥ 0  over the
    support (0 < p_j < 1), via eq. (9):

        α_j(λ) = ( −β_j + λ / (2 (1 − p_j)) )⁺ ,
        g(λ)   = Σ_j p_j α_j(λ)  is nondecreasing;  find g(λ) = 1 by bisection.

    Returns (α, bisection_iterations).
    """
    one_minus = 1.0 - p_sup

    def alpha_of(lam: float) -> np.ndarray:
        return np.maximum(0.0, -beta + lam / (2.0 * one_minus))

    def g(lam: float) -> float:
        return float(p_sup @ alpha_of(lam))

    lo, hi = 0.0, 1.0
    iters = 0
    while g(hi) < 1.0:
        hi *= 2.0
        iters += 1
        if hi > 1e18:
            raise FloatingPointError("bisection bracket blew up (infeasible column?)")
    while hi - lo > tol * max(1.0, hi) and iters < max_iters:
        mid = 0.5 * (lo + hi)
        if g(mid) < 1.0:
            lo = mid
        else:
            hi = mid
        iters += 1
    alpha = alpha_of(hi)
    # Exactly satisfy the equality constraint by rescaling the active set
    # (removes the residual bisection tolerance; active set is unchanged).
    s = float(p_sup @ alpha)
    if s > 0:
        alpha = alpha / s
    return alpha, iters


def _solve_column_exact(
    p_sup: np.ndarray,
    beta: np.ndarray,
) -> tuple[np.ndarray, int]:
    """The same column subproblem solved exactly: g(λ) = Σ_j p_j α_j(λ) is
    piecewise linear and nondecreasing with breakpoints λ_j = 2(1−p_j)β_j
    (where α_j activates), so instead of bisecting we sort the breakpoints
    and solve g(λ*) = 1 in closed form on the one segment that brackets it.

    O(s log s) per column against O(s · iters) for the bisection — the
    scheduler hot path under a time-varying channel (one OPT-α re-solve per
    channel epoch) is ~10× faster end to end.  Agrees with the bisection to
    its tolerance (tested), but is not bit-identical to it; the paper-
    faithful bisection stays the default.
    """
    one_minus = 1.0 - p_sup
    slope = p_sup / (2.0 * one_minus)     # d(p_j α_j)/dλ once j is active
    lam_break = 2.0 * one_minus * beta    # λ at which α_j leaves zero
    order = np.argsort(lam_break)
    lam_sorted = lam_break[order]
    csum_slope = np.cumsum(slope[order])
    csum_pb = np.cumsum((p_sup * beta)[order])
    lam = None
    for k in range(order.size):
        # active set = the k+1 smallest breakpoints; on this segment
        # g(λ) = λ·Σ_act slope − Σ_act p_j β_j, solve g = 1
        cand = (1.0 + csum_pb[k]) / csum_slope[k]
        hi = lam_sorted[k + 1] if k + 1 < order.size else np.inf
        if lam_sorted[k] <= cand <= hi:
            lam = cand
            break
    if lam is None:  # numerical ties: the last segment always extends to ∞
        lam = (1.0 + csum_pb[-1]) / csum_slope[-1]
    alpha = np.maximum(0.0, -beta + lam / (2.0 * one_minus))
    s = float(p_sup @ alpha)
    if s > 0:
        alpha = alpha / s
    return alpha, 0


_COLUMN_SOLVERS = {
    "bisect": _solve_column_waterfill,
    "exact": _solve_column_exact,
}


def solve_column(
    p: np.ndarray,
    closed_col: np.ndarray,
    beta_full: np.ndarray,
    *,
    method: str = "bisect",
) -> tuple[np.ndarray, bool, int]:
    """Paper eq. (9) for one origin column i.

    p          : (n,) connectivity probabilities
    closed_col : (n,) bool, j ∈ N_i ∪ {i}
    beta_full  : (n,) β_ji = Σ_{l ∈ L_ji} α_jl  (row mass excluding column i)
    method     : ``bisect`` (paper-faithful λ search) or ``exact`` (the
                 closed-form piecewise-linear solve; ~10× faster, identical
                 up to the bisection tolerance)

    Returns (column, feasible, bisection_iters).
    """
    if method not in _COLUMN_SOLVERS:
        known = ", ".join(sorted(_COLUMN_SOLVERS))
        raise ValueError(f"unknown column solver {method!r} (known: {known})")
    n = p.shape[0]
    col = np.zeros((n,), dtype=np.float64)
    ones = np.nonzero(closed_col & (p >= 1.0))[0]
    if ones.size > 0:
        # Zero-variance relays exist: put all mass uniformly on them (eq. 9 case 2).
        col[ones] = 1.0 / ones.size
        return col, True, 0
    sup = np.nonzero(closed_col & (p > 0.0))[0]
    if sup.size == 0:
        return col, False, 0  # nobody in N_i ∪ {i} can ever reach the PS
    alpha, iters = _COLUMN_SOLVERS[method](p[sup], beta_full[sup])
    col[sup] = alpha
    return col, True, iters


def optimize(
    p: np.ndarray,
    adj: np.ndarray,
    *,
    sweeps: int = 50,
    tol: float = 1e-10,
    A0: np.ndarray | None = None,
    method: str = "bisect",
) -> OptAlphaResult:
    """Run OPT-α Gauss–Seidel sweeps until S(p, A) stalls or `sweeps` is hit.

    One sweep = n column updates (paper Alg. 3 runs L single-column
    iterations; `sweeps` here counts full passes, i.e. L = sweeps·n).
    ``method`` selects the column solver (see :func:`solve_column`).
    """
    p = np.asarray(p, dtype=np.float64)
    adj = np.asarray(adj, dtype=bool)
    n = p.shape[0]
    m = topology.closed_mask(adj)
    A = initial_weights(p, adj) if A0 is None else np.array(A0, dtype=np.float64)
    feasible = np.ones((n,), dtype=bool)
    history = [variance_proxy(p, A)]
    bis_total = 0
    for _ in range(sweeps):
        for i in range(n):
            row_mass = A.sum(axis=1)
            beta = row_mass - A[:, i]  # β_ji = Σ_{l≠i} α_jl  (support-collapsed)
            col, ok, iters = solve_column(p, m[:, i], beta, method=method)
            A[:, i] = col
            feasible[i] = ok
            bis_total += iters
        history.append(variance_proxy(p, A))
        if abs(history[-2] - history[-1]) <= tol * max(1.0, history[-2]):
            break
    return OptAlphaResult(
        A=A,
        S_history=np.asarray(history),
        feasible_columns=feasible,
        sweeps=len(history) - 1,
        bisection_iters_total=bis_total,
    )


def optimize_masked(
    p: np.ndarray,
    adj: np.ndarray,
    active: np.ndarray,
    *,
    sweeps: int = 50,
    tol: float = 1e-10,
    A0: np.ndarray | None = None,
    method: str = "bisect",
) -> OptAlphaResult:
    """OPT-α on the *active block* of a padded client dimension.

    ``active`` is an (n_max,) boolean membership mask (client churn: clients
    not currently in the run).  The returned matrix is full (n_max, n_max)
    with every inactive row and column exactly zero — an inactive client
    neither relays nor is relayed — and its active block equals the dense
    Gauss–Seidel solve of the subproblem restricted to the active clients
    (tested).  Unbiasedness (Lemma 1) holds column-wise over the active set.

    The sweep loop visits only active columns, so a mostly-empty mask costs
    O(n_active) column solves per sweep, not O(n_max).

    ``feasible_columns`` reports **False for every inactive column**: a
    padded/departed slot has no constraint to satisfy, and reporting it True
    (the historical behavior — the vector was initialized all-True and only
    updated for active columns) made ``feasible_columns.all()`` and any
    reduction over the padded dim read success off columns that were never
    solved.  Mask with ``active & feasible_columns`` for "live and solvable",
    ``active & ~feasible_columns`` for "live but cut off from the PS".
    """
    p = np.asarray(p, dtype=np.float64)
    adj = np.asarray(adj, dtype=bool)
    active = np.asarray(active, dtype=bool)
    n = p.shape[0]
    if active.shape != (n,):
        raise ValueError(f"active mask shape {active.shape} != ({n},)")
    # Channel restricted to the active block: a departed client's links carry
    # nothing and its uplink never fires.
    adj_m = adj & active[:, None] & active[None, :]
    p_m = np.where(active, p, 0.0)
    m = topology.closed_mask(adj_m)
    m &= active[:, None] & active[None, :]
    if A0 is None:
        A = initial_weights(p_m, adj_m)
    else:
        A = np.where(m, np.asarray(A0, dtype=np.float64), 0.0)
    A[:, ~active] = 0.0
    A[~active, :] = 0.0
    # Inactive columns are never solved — they must not read "feasible".
    feasible = np.zeros((n,), dtype=bool)
    history = [variance_proxy(p_m, A)]
    bis_total = 0
    act_idx = np.nonzero(active)[0]
    for _ in range(sweeps):
        for i in act_idx:
            row_mass = A.sum(axis=1)
            beta = row_mass - A[:, i]
            col, ok, iters = solve_column(p_m, m[:, i], beta, method=method)
            A[:, i] = col
            feasible[i] = ok
            bis_total += iters
        history.append(variance_proxy(p_m, A))
        if abs(history[-2] - history[-1]) <= tol * max(1.0, history[-2]):
            break
    return OptAlphaResult(
        A=A,
        S_history=np.asarray(history),
        feasible_columns=feasible,
        sweeps=len(history) - 1,
        bisection_iters_total=bis_total,
    )


# --------------------------------------------------------------------------
# Neighborhood-blocked (sparse) OPT-α: everything O(E), nothing O(n²)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparseOptAlphaResult:
    """OPT-α solution on a :class:`~repro.core.topology.ClosedGraph`.

    ``vals[k]`` is α at entry k of the (fixed) closed-neighborhood structure:
    ``A[graph.rows[k], graph.cols[k]] = vals[k]``.  The structure covers the
    *full* graph — entries whose row or column is inactive simply carry 0 —
    so consecutive solves under per-round cohorts share one static edge
    layout (no retraces downstream, no re-analysis of the graph).
    """

    graph: topology.ClosedGraph
    vals: np.ndarray              # (nnz,) float64 α on the structure
    S_history: np.ndarray
    feasible_columns: np.ndarray  # bool (n,): False for inactive columns too
    sweeps: int
    bisection_iters_total: int

    def todense(self) -> np.ndarray:
        """Materialize the dense (n, n) matrix — small-n checks only."""
        n = self.graph.n
        A = np.zeros((n, n), dtype=np.float64)
        A[self.graph.rows, self.graph.cols] = self.vals
        return A

    def edge_relay(self):
        """The :class:`repro.core.relay.EdgeRelay` operand for the
        ``segment`` aggregation backend (host numpy, f32/i32)."""
        from repro.core import relay as relay_lib  # opt_alpha stays jax-free

        return relay_lib.EdgeRelay(
            rows=self.graph.rows.astype(np.int32),
            cols=self.graph.cols.astype(np.int32),
            vals=self.vals.astype(np.float32),
        )


def _initial_vals_sparse(
    p_m: np.ndarray, graph: topology.ClosedGraph, entry_on: np.ndarray
) -> np.ndarray:
    """Alg. 3 line 1 on the CSC structure: the exact sparse counterpart of
    ``initial_weights(p_m, adj_m)`` restricted to entries with both endpoints
    active (``entry_on``).  ``p_m`` is already zeroed on inactive slots."""
    rows, cols = graph.rows, graph.cols
    n = graph.n
    # |N_i ∪ {i}| in the masked graph = live entries per column
    deg = np.bincount(cols[entry_on], minlength=n).astype(np.float64)
    pj = p_m[rows]
    sup = entry_on & (pj > 0)
    vals = np.zeros(rows.size, dtype=np.float64)
    vals[sup] = 1.0 / (deg[cols[sup]] * pj[sup])
    mass = np.bincount(cols[sup], weights=pj[sup] * vals[sup], minlength=n)
    fix = (mass > 0) & ~np.isclose(mass, 1.0)
    scale = np.where(fix, 1.0 / np.where(fix, mass, 1.0), 1.0)
    vals *= scale[cols]
    return vals


def warm_start_vals(
    p: np.ndarray,
    graph: topology.ClosedGraph,
    vals_prev: np.ndarray,
    active: np.ndarray | None = None,
) -> np.ndarray:
    """:func:`warm_start_weights` on the CSC structure, vectorized over
    columns.  Projects a previous cohort's α onto the new ``(p, active)``:
    entries off the live support are dropped, surviving columns are rescaled
    to restore Lemma 1, and columns whose carried mass fails the
    :data:`WARM_START_RTOL` relative test fall back to the Alg. 3 initial
    values — per-round cohort sampling hits that fallback constantly, which
    is exactly the regime the relative cutoff protects.
    """
    p = np.asarray(p, dtype=np.float64)
    rows, cols = graph.rows, graph.cols
    n = graph.n
    if active is None:
        entry_on = np.ones(rows.size, dtype=bool)
        p_m = p
    else:
        active = np.asarray(active, dtype=bool)
        entry_on = active[rows] & active[cols]
        p_m = np.where(active, p, 0.0)
    pj = p_m[rows]
    keep = entry_on & (pj > 0)
    kept = np.where(keep, np.asarray(vals_prev, dtype=np.float64), 0.0)
    mass = np.bincount(cols, weights=pj * kept, minlength=n)
    col_max = np.zeros(n, dtype=np.float64)
    np.maximum.at(col_max, cols, kept)
    good = mass > np.maximum(1e-12, WARM_START_RTOL * col_max)
    init = _initial_vals_sparse(p_m, graph, entry_on)
    scale = np.where(good, 1.0 / np.where(good, mass, 1.0), 1.0)
    return np.where(good[cols], kept * scale[cols], init)


def optimize_sparse(
    p: np.ndarray,
    adj: np.ndarray | None = None,
    active: np.ndarray | None = None,
    *,
    graph: topology.ClosedGraph | None = None,
    sweeps: int = 50,
    tol: float = 1e-10,
    vals0: np.ndarray | None = None,
    method: str = "bisect",
) -> SparseOptAlphaResult:
    """Neighborhood-blocked OPT-α: Gauss–Seidel where each column solve
    touches only the closed neighborhood N_i ∪ {i}.

    Equivalent to :func:`optimize_masked` (same initial point, same column
    visit order, same solver, same stall test) but with per-sweep cost
    O(n_active · max_deg) instead of O(n_active · n²): β comes from an
    incrementally-maintained row-mass vector rather than a fresh
    ``A.sum(axis=1)`` per column.  The active block of ``todense()`` matches
    the dense solve to fp-accumulation noise (≪ 1e-8, tested).

    Pass ``graph`` (from :func:`topology.closed_csc`) to amortize structure
    extraction across solves on the same adjacency — the per-round path of
    cohort sampling; ``adj`` is then not needed.  ``vals0`` seeds the sweep
    (see :func:`warm_start_vals`).
    """
    if graph is None:
        if adj is None:
            raise ValueError("optimize_sparse needs either adj or graph")
        graph = topology.closed_csc(np.asarray(adj, dtype=bool))
    p = np.asarray(p, dtype=np.float64)
    n = graph.n
    if p.shape != (n,):
        raise ValueError(f"p shape {p.shape} != ({n},)")
    rows, cols, indptr = graph.rows, graph.cols, graph.indptr
    if active is None:
        active = np.ones(n, dtype=bool)
    else:
        active = np.asarray(active, dtype=bool)
        if active.shape != (n,):
            raise ValueError(f"active mask shape {active.shape} != ({n},)")
    entry_on = active[rows] & active[cols]
    p_m = np.where(active, p, 0.0)
    if vals0 is None:
        vals = _initial_vals_sparse(p_m, graph, entry_on)
    else:
        vals = np.where(entry_on, np.asarray(vals0, dtype=np.float64), 0.0)
    w_var = p_m * (1.0 - p_m)
    row_mass = np.bincount(rows, weights=vals, minlength=n)
    feasible = np.zeros((n,), dtype=bool)
    history = [float(np.sum(w_var * row_mass**2))]
    bis_total = 0
    act_idx = np.nonzero(active)[0]
    solver = _COLUMN_SOLVERS.get(method)
    if solver is None:
        known = ", ".join(sorted(_COLUMN_SOLVERS))
        raise ValueError(f"unknown column solver {method!r} (known: {known})")
    for _ in range(sweeps):
        for i in act_idx:
            lo, hi = indptr[i], indptr[i + 1]
            r = rows[lo:hi]
            on = entry_on[lo:hi]
            old = vals[lo:hi]
            pr = p_m[r]
            new = np.zeros(r.size, dtype=np.float64)
            ones = on & (pr >= 1.0)
            if ones.any():
                new[ones] = 1.0 / ones.sum()
                feasible[i] = True
            else:
                sup = on & (pr > 0.0)
                if not sup.any():
                    feasible[i] = False
                else:
                    beta = row_mass[r[sup]] - old[sup]
                    alpha, iters = solver(pr[sup], beta)
                    new[sup] = alpha
                    feasible[i] = True
                    bis_total += iters
            row_mass[r] += new - old
            vals[lo:hi] = new
        history.append(float(np.sum(w_var * row_mass**2)))
        if abs(history[-2] - history[-1]) <= tol * max(1.0, history[-2]):
            break
    return SparseOptAlphaResult(
        graph=graph,
        vals=vals,
        S_history=np.asarray(history),
        feasible_columns=feasible,
        sweeps=len(history) - 1,
        bisection_iters_total=bis_total,
    )


def optimize_distributed(
    p: np.ndarray,
    adj: np.ndarray,
    *,
    sweeps: int = 50,
    tol: float = 1e-10,
) -> OptAlphaResult:
    """Distributed OPT-α (paper Remark 2): every column update at client i
    uses only quantities observable within i's 2-hop neighborhood.

    β_ji = Σ_{l ∈ L_ji} α_jl involves exactly the clients l ≠ i that share
    relay j with i — i.e. 2-hop neighbors. Here each client i keeps its own
    column and, per sweep, reconstructs the β it needs from the columns of
    its 2-hop neighborhood only (enforced by masking); the result must match
    the centralized Gauss-Seidel solve column-for-column (tested).
    """
    p = np.asarray(p, dtype=np.float64)
    adj = np.asarray(adj, dtype=bool)
    n = p.shape[0]
    m = topology.closed_mask(adj)
    # two_hop[i, l] = l visible from i through some shared relay j
    two_hop = np.zeros((n, n), dtype=bool)
    for i in range(n):
        relays = np.nonzero(m[:, i])[0]
        two_hop[i] = m[relays].any(axis=0)
    A = initial_weights(p, adj)
    feasible = np.ones((n,), dtype=bool)
    history = [variance_proxy(p, A)]
    bis_total = 0
    for _ in range(sweeps):
        for i in range(n):
            # client i only reads columns of its 2-hop neighborhood
            visible = np.where(two_hop[i][None, :], A, 0.0)
            beta = visible.sum(axis=1) - visible[:, i]
            col, ok, iters = solve_column(p, m[:, i], beta)
            A[:, i] = col
            feasible[i] = ok
            bis_total += iters
        history.append(variance_proxy(p, A))
        if abs(history[-2] - history[-1]) <= tol * max(1.0, history[-2]):
            break
    return OptAlphaResult(
        A=A, S_history=np.asarray(history), feasible_columns=feasible,
        sweeps=len(history) - 1, bisection_iters_total=bis_total,
    )


def fedavg_weights(n: int) -> np.ndarray:
    """No collaboration: A = I (paper's 'standard FL' special case)."""
    return np.eye(n, dtype=np.float64)


def colrel_expected_coverage(p: np.ndarray, adj: np.ndarray) -> np.ndarray:
    """P[origin i's update reaches the PS through ≥1 relay] = 1 − Π_j (1 − p_j)
    over j ∈ N_i ∪ {i}.  Diagnostic used in EXPERIMENTS.md."""
    p = np.asarray(p, dtype=np.float64)
    m = topology.closed_mask(adj)
    cov = np.empty_like(p)
    for i in range(p.shape[0]):
        cov[i] = 1.0 - np.prod(1.0 - p[m[:, i]])
    return cov
