"""Collaborative relaying of local updates (paper §II-C, Alg. 1 lines 6-9).

Every function operates on a *stacked* pytree of client updates: each leaf has
a leading client dimension of size n.  Three execution paths compute the same
math:

  * ``relay`` — the paper-faithful local consensus  Δx̃ = A · Δx  (an einsum
    over the client dim; under GSPMD with the client dim sharded over the
    ``data`` axis this lowers to an all-gather of every neighbor's update —
    exactly the D2D exchange of Alg. 1 lines 6-7).
  * ``fused_coefficients`` / ``fused_aggregate`` — the beyond-paper fusion of
    relay + PS aggregation:  w Σ_r τ_r Δx̃_r = w Σ_o c_o Δx_o  with
    c = τᵀA.  One weighted reduce instead of an n-way gather; bit-identical
    result in simulation (linearity), recorded separately in EXPERIMENTS.md.
  * the Pallas kernel path (``repro.kernels.ops.relay_mix``) — used by the
    single-host simulator for flat parameter blocks.

The relay matrix A is always host-side numpy from ``core.opt_alpha``; it is a
constant folded into the compiled step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _check_square(A) -> jnp.ndarray:
    A = jnp.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"relay matrix must be square, got {A.shape}")
    return A


def relay(A, stacked_updates, *, precision=jax.lax.Precision.HIGHEST):
    """Local consensus Δx̃_r = Σ_o A[r, o] Δx_o for every relay r.

    ``stacked_updates``: pytree whose leaves are (n, ...) arrays.
    Returns a pytree of identical structure/shape.
    """
    A = _check_square(A)

    def mix(leaf):
        if leaf.shape[0] != A.shape[0]:
            raise ValueError(
                f"leading client dim {leaf.shape[0]} != n = {A.shape[0]}"
            )
        out = jnp.einsum(
            "ro,o...->r...", A.astype(jnp.float32), leaf.astype(jnp.float32),
            precision=precision,
        )
        return out.astype(leaf.dtype)

    return jax.tree.map(mix, stacked_updates)


def mask_relay_matrix(A, active):
    """Restrict A to the active block of a padded client dimension:
    zero every row and column of an inactive client (churn semantics — a
    departed client neither relays nor is relayed).  ``active`` is a traced
    (n,) 0/1 vector, so membership can change per round without retracing."""
    A = _check_square(A)
    active = jnp.asarray(active, dtype=jnp.float32)
    return active[:, None] * A.astype(jnp.float32) * active[None, :]


def fused_coefficients(A, tau) -> jnp.ndarray:
    """c_o = Σ_r τ_r α_ro — the per-origin coefficient of the fused
    relay+aggregate path (c = τᵀ A)."""
    A = _check_square(A)
    tau = jnp.asarray(tau, dtype=jnp.float32)
    return tau @ A.astype(jnp.float32)


def fused_aggregate(A, tau, stacked_updates, *, w: float):
    """w · Σ_r τ_r Δx̃_r computed without materializing Δx̃ (the optimized
    path).  Returns the PS model increment pytree (no client dim)."""
    c = w * fused_coefficients(A, tau)

    def reduce(leaf):
        out = jnp.tensordot(c, leaf.astype(jnp.float32), axes=(0, 0))
        return out.astype(jnp.float32)

    return jax.tree.map(reduce, stacked_updates)


def masked_aggregate(tau, stacked_relayed, *, w: float):
    """Paper-faithful PS reduction  w · Σ_r τ_r Δx̃_r  over already-relayed
    updates (eq. 2).  Blind: uses only the mask, never client identities."""
    tau = jnp.asarray(tau, dtype=jnp.float32)

    def reduce(leaf):
        out = jnp.tensordot(w * tau, leaf.astype(jnp.float32), axes=(0, 0))
        return out.astype(jnp.float32)

    return jax.tree.map(reduce, stacked_relayed)


def neighbor_support(A, adj) -> bool:
    """True iff A is supported on the closed neighborhoods of ``adj`` —
    i.e. no client uses an update it could never have received over D2D."""
    from repro.core import topology

    m = topology.closed_mask(np.asarray(adj))
    A = np.asarray(A)
    return bool(np.all(A[~m] == 0.0))
