"""Collaborative relaying of local updates (paper §II-C, Alg. 1 lines 6-9).

Every function operates on a *stacked* pytree of client updates: each leaf has
a leading client dimension of size n.  Three execution paths compute the same
math:

  * ``relay`` — the paper-faithful local consensus  Δx̃ = A · Δx  (an einsum
    over the client dim; under GSPMD with the client dim sharded over the
    ``data`` axis this lowers to an all-gather of every neighbor's update —
    exactly the D2D exchange of Alg. 1 lines 6-7).
  * ``fused_coefficients`` / ``fused_aggregate`` — the beyond-paper fusion of
    relay + PS aggregation:  w Σ_r τ_r Δx̃_r = w Σ_o c_o Δx_o  with
    c = τᵀA.  One weighted reduce instead of an n-way gather; bit-identical
    result in simulation (linearity), recorded separately in EXPERIMENTS.md.
  * the Pallas kernel path (``repro.kernels.ops.relay_mix``) — used by the
    single-host simulator for flat parameter blocks.

The relay matrix A is always host-side numpy from ``core.opt_alpha``; it is a
constant folded into the compiled step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EdgeRelay(NamedTuple):
    """Edge-list relay operator: entry k stands for A[rows[k], cols[k]] =
    vals[k], everything off the list identically zero.

    The sparse counterpart of the dense (n, n) relay matrix, produced by
    ``opt_alpha.SparseOptAlphaResult.edge_relay()`` and consumed by the
    ``relay_backend="segment"`` aggregation path — relay∘aggregate cost
    scales with the edge count E, not n².  A NamedTuple of three equal-length
    1-D arrays, so it is automatically a JAX pytree and passes through jit
    boundaries as three traced leaves; keep the edge count static across
    rounds (carry the full graph's closed structure and zero the vals of
    inactive entries) or every cohort change would retrace.

    Orientation matches the dense convention: ``rows`` indexes the relay j,
    ``cols`` the origin i whose update it forwards.
    """

    rows: jnp.ndarray  # (E,) int32 relay index j
    cols: jnp.ndarray  # (E,) int32 origin index i
    vals: jnp.ndarray  # (E,) float32 α_ji

    def todense(self, n: int) -> jnp.ndarray:
        """Scatter into the dense (n, n) matrix (small-n parity checks and
        the dense backends; never on the segment hot path)."""
        return (
            jnp.zeros((n, n), dtype=jnp.float32)
            .at[self.rows, self.cols]
            .add(self.vals.astype(jnp.float32))
        )


def edge_relay_from_dense(A, *, tol: float = 0.0) -> EdgeRelay:
    """Host-side helper: build an EdgeRelay from a dense matrix, keeping
    entries with |A| > tol (tol=0 keeps explicit structural zeros out)."""
    A = np.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"relay matrix must be square, got {A.shape}")
    rows, cols = np.nonzero(np.abs(A) > tol)
    return EdgeRelay(
        rows=np.asarray(rows, dtype=np.int32),
        cols=np.asarray(cols, dtype=np.int32),
        vals=np.asarray(A[rows, cols], dtype=np.float32),
    )


def as_relay_operand(A, *, n: int, backend: str = "einsum"):
    """Normalize a relay operand for an aggregation backend.

    Dense inputs go to a float32 (n, n) array; an :class:`EdgeRelay` stays
    an EdgeRelay (int32/float32 leaves) for ``backend="segment"`` and is
    densified otherwise — the dense backends (einsum / pallas kernels) have
    no sparse lowering, and the densify keeps small-n parity checks able to
    run any backend against a sparse policy's output.  The one refusal,
    dense matrix + segment backend, lives in the aggregation layer where the
    error can point at the policy knob.
    """
    if A is None:
        return None
    if isinstance(A, EdgeRelay):
        er = EdgeRelay(
            rows=jnp.asarray(A.rows, dtype=jnp.int32),
            cols=jnp.asarray(A.cols, dtype=jnp.int32),
            vals=jnp.asarray(A.vals, dtype=jnp.float32),
        )
        if backend == "segment":
            return er
        return er.todense(n)
    return jnp.asarray(A, jnp.float32)


def _check_square(A) -> jnp.ndarray:
    A = jnp.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"relay matrix must be square, got {A.shape}")
    return A


def relay(A, stacked_updates, *, precision=jax.lax.Precision.HIGHEST):
    """Local consensus Δx̃_r = Σ_o A[r, o] Δx_o for every relay r.

    ``stacked_updates``: pytree whose leaves are (n, ...) arrays.
    Returns a pytree of identical structure/shape.
    """
    A = _check_square(A)

    def mix(leaf):
        if leaf.shape[0] != A.shape[0]:
            raise ValueError(
                f"leading client dim {leaf.shape[0]} != n = {A.shape[0]}"
            )
        out = jnp.einsum(
            "ro,o...->r...", A.astype(jnp.float32), leaf.astype(jnp.float32),
            precision=precision,
        )
        return out.astype(leaf.dtype)

    return jax.tree.map(mix, stacked_updates)


def mask_relay_matrix(A, active):
    """Restrict A to the active block of a padded client dimension:
    zero every row and column of an inactive client (churn semantics — a
    departed client neither relays nor is relayed).  ``active`` is a traced
    (n,) 0/1 vector, so membership can change per round without retracing.
    On an :class:`EdgeRelay` the same mask folds into the edge values —
    any entry touching an inactive endpoint goes exactly to zero."""
    active = jnp.asarray(active, dtype=jnp.float32)
    if isinstance(A, EdgeRelay):
        vals = A.vals.astype(jnp.float32) * active[A.rows] * active[A.cols]
        return EdgeRelay(rows=A.rows, cols=A.cols, vals=vals)
    A = _check_square(A)
    return active[:, None] * A.astype(jnp.float32) * active[None, :]


def fused_coefficients(A, tau) -> jnp.ndarray:
    """c_o = Σ_r τ_r α_ro — the per-origin coefficient of the fused
    relay+aggregate path (c = τᵀ A).  For an :class:`EdgeRelay` the
    contraction is a segment-sum over edges grouped by origin column:
    O(E) instead of O(n²)."""
    tau = jnp.asarray(tau, dtype=jnp.float32)
    if isinstance(A, EdgeRelay):
        return jax.ops.segment_sum(
            tau[A.rows] * A.vals.astype(jnp.float32),
            A.cols,
            num_segments=tau.shape[0],
        )
    A = _check_square(A)
    return tau @ A.astype(jnp.float32)


def segment_mix(A: EdgeRelay, buf) -> jnp.ndarray:
    """Δ̃ = A·Δ on the flat (n, D) buffer via per-edge gather + segment-sum
    over the relay rows — the paper-faithful (unfused) consensus at O(E·D).
    The E×D gathered intermediate makes the fused coefficient path the hot
    choice at scale; this one exists for parity and the unfused strategies."""
    if not isinstance(A, EdgeRelay):
        raise TypeError("segment_mix needs an EdgeRelay operand")
    buf = jnp.asarray(buf, jnp.float32)
    contrib = A.vals.astype(jnp.float32)[:, None] * buf[A.cols]
    return jax.ops.segment_sum(contrib, A.rows, num_segments=buf.shape[0])


def fused_aggregate(A, tau, stacked_updates, *, w: float):
    """w · Σ_r τ_r Δx̃_r computed without materializing Δx̃ (the optimized
    path).  Returns the PS model increment pytree (no client dim)."""
    c = w * fused_coefficients(A, tau)

    def reduce(leaf):
        out = jnp.tensordot(c, leaf.astype(jnp.float32), axes=(0, 0))
        return out.astype(jnp.float32)

    return jax.tree.map(reduce, stacked_updates)


def masked_aggregate(tau, stacked_relayed, *, w: float):
    """Paper-faithful PS reduction  w · Σ_r τ_r Δx̃_r  over already-relayed
    updates (eq. 2).  Blind: uses only the mask, never client identities."""
    tau = jnp.asarray(tau, dtype=jnp.float32)

    def reduce(leaf):
        out = jnp.tensordot(w * tau, leaf.astype(jnp.float32), axes=(0, 0))
        return out.astype(jnp.float32)

    return jax.tree.map(reduce, stacked_relayed)


def neighbor_support(A, adj) -> bool:
    """True iff A is supported on the closed neighborhoods of ``adj`` —
    i.e. no client uses an update it could never have received over D2D."""
    from repro.core import topology

    m = topology.closed_mask(np.asarray(adj))
    A = np.asarray(A)
    return bool(np.all(A[~m] == 0.0))
