"""PS aggregation strategies (paper §II-D, Alg. 2, and the Fig. 2-4 baselines).

All strategies consume a stacked pytree of per-client quantities (leading dim
n) plus the round's τ mask, and produce the *global model increment* that the
server optimizer (plain step or global momentum, paper Fig. 4) applies.

Strategies
----------
  colrel           w=1/n blind masked sum of *relayed* updates (eq. 2)
  colrel_fused     same update computed via the fused coefficients (optimized)
  fedavg_blind     w=1/n blind masked sum of *raw* updates (missing ⇒ zero)
  fedavg_nonblind  masked mean over the successful clients (PS knows ids)
  no_dropout       plain 1/n average, perfect connectivity upper bound

Client churn (padded client dimension)
--------------------------------------
Every increment function accepts an optional ``active`` mask: a traced (n,)
0/1 vector marking which of the ``n = n_max`` padded client slots are live
this round.  With a mask, the averaging weight renormalizes to 1/n_active,
τ is intersected with the mask, and (for the colrel strategies) the relay
matrix is restricted to the active block — so an inactive client contributes
*exactly zero* to the increment and unbiasedness holds over the active set.
``active=None`` is the full-membership fast path: it compiles with the
static 1/n weight and is bit-identical to the fixed-n formulation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import relay as relay_lib
from repro.utils import tree_axpy, tree_scale, tree_zeros_like


def active_weight(active, *, n: int):
    """The blind averaging weight: 1/n_active (traced) under a churn mask,
    the static python float 1/n without one."""
    if active is None:
        return 1.0 / n
    active = jnp.asarray(active, dtype=jnp.float32)
    return 1.0 / jnp.maximum(active.sum(), 1.0)


def colrel_increment(A, tau, stacked_updates, *, n: int, fused: bool = True,
                     active=None):
    """ColRel PS increment.  ``fused=True`` is the optimized path (identical
    math); ``fused=False`` materializes Δx̃ per relay (paper-faithful)."""
    w = active_weight(active, n=n)
    if active is not None:
        A = relay_lib.mask_relay_matrix(A, active)
        tau = jnp.asarray(tau, jnp.float32) * jnp.asarray(active, jnp.float32)
    if fused:
        return relay_lib.fused_aggregate(A, tau, stacked_updates, w=w)
    relayed = relay_lib.relay(A, stacked_updates)
    return relay_lib.masked_aggregate(tau, relayed, w=w)


def fedavg_blind_increment(tau, stacked_updates, *, n: int, active=None):
    w = active_weight(active, n=n)
    if active is not None:
        tau = jnp.asarray(tau, jnp.float32) * jnp.asarray(active, jnp.float32)
    return relay_lib.masked_aggregate(tau, stacked_updates, w=w)


def fedavg_nonblind_increment(tau, stacked_updates, *, active=None):
    tau = jnp.asarray(tau, dtype=jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    denom = jnp.maximum(tau.sum(), 1.0)

    def reduce(leaf):
        return jnp.tensordot(tau / denom, leaf.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(reduce, stacked_updates)


def no_dropout_increment(stacked_updates, *, n: int, active=None):
    if active is None:
        return jax.tree.map(
            lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0),
            stacked_updates,
        )
    a = jnp.asarray(active, jnp.float32)
    w = a / jnp.maximum(a.sum(), 1.0)

    def reduce(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(reduce, stacked_updates)


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Bundles a strategy name with its increment function.

    ``fn(tau, stacked_updates, A=None, active=None) -> increment pytree``.
    For the colrel strategies A is a *traced input* so a time-varying channel
    can swap relay matrices between rounds without retracing the jitted step;
    when omitted, the matrix bound at construction time is used
    (static-channel callers).  ``active`` is the traced churn mask of the
    padded client dimension (None ⇒ full membership, static-weight path).
    """

    name: str
    fn: Callable  # (tau, stacked_updates, A=None, active=None) -> increment


def make_aggregator(
    strategy: str,
    *,
    n: int,
    A=None,
) -> Aggregator:
    default_A = A

    def _resolve(A_arg):
        A_eff = default_A if A_arg is None else A_arg
        if A_eff is None:
            raise ValueError("colrel aggregation needs a relay matrix A "
                             "(bind one at construction or pass it per call)")
        return A_eff

    if strategy == "colrel":
        return Aggregator(
            "colrel",
            lambda tau, upd, A=None, active=None: colrel_increment(
                _resolve(A), tau, upd, n=n, fused=False, active=active),
        )
    if strategy == "colrel_fused":
        return Aggregator(
            "colrel_fused",
            lambda tau, upd, A=None, active=None: colrel_increment(
                _resolve(A), tau, upd, n=n, fused=True, active=active),
        )
    if strategy == "fedavg_blind":
        return Aggregator(
            "fedavg_blind",
            lambda tau, upd, A=None, active=None: fedavg_blind_increment(
                tau, upd, n=n, active=active),
        )
    if strategy == "fedavg_nonblind":
        return Aggregator(
            "fedavg_nonblind",
            lambda tau, upd, A=None, active=None: fedavg_nonblind_increment(
                tau, upd, active=active),
        )
    if strategy == "no_dropout":
        return Aggregator(
            "no_dropout",
            lambda tau, upd, A=None, active=None: no_dropout_increment(
                upd, n=n, active=active),
        )
    raise ValueError(f"unknown aggregation strategy: {strategy!r}")


# --------------------------------------------------------------------------
# Server optimizer (paper Fig. 4 uses global momentum at the PS)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerOpt:
    """x ← x + lr · (m ← γ m + increment).  γ=0, lr=1 is plain Alg. 2."""

    momentum: float = 0.0
    lr: float = 1.0

    def init(self, params):
        if self.momentum == 0.0:
            return None
        return tree_zeros_like(params)

    def apply(self, params, state, increment):
        def upd(p, inc):
            return (p.astype(jnp.float32) + self.lr * inc).astype(p.dtype)

        if self.momentum == 0.0:
            return jax.tree.map(upd, params, increment), None
        new_state = tree_axpy(1.0, increment, tree_scale(self.momentum, state))
        new_params = jax.tree.map(upd, params, new_state)
        return new_params, new_state
