"""PS aggregation strategies (paper §II-D, Alg. 2, and the Fig. 2-4 baselines).

All strategies consume a stacked pytree of per-client quantities (leading dim
n) plus the round's τ mask, and produce the *global model increment* that the
server optimizer (plain step or global momentum, paper Fig. 4) applies.

Strategies
----------
  colrel           w=1/n blind masked sum of *relayed* updates (eq. 2)
  colrel_fused     same update computed via the fused coefficients (optimized)
  fedavg_blind     w=1/n blind masked sum of *raw* updates (missing ⇒ zero)
  fedavg_nonblind  masked mean over the successful clients (PS knows ids)
  no_dropout       plain 1/n average, perfect connectivity upper bound

Client churn (padded client dimension)
--------------------------------------
Every increment function accepts an optional ``active`` mask: a traced (n,)
0/1 vector marking which of the ``n = n_max`` padded client slots are live
this round.  With a mask, the averaging weight renormalizes to 1/n_active,
τ is intersected with the mask, and (for the colrel strategies) the relay
matrix is restricted to the active block — so an inactive client contributes
*exactly zero* to the increment and unbiasedness holds over the active set.
``active=None`` is the full-membership fast path: it compiles with the
static 1/n weight and is bit-identical to the fixed-n formulation.

Flat-buffer hot path (``relay_backend``)
----------------------------------------
Every strategy also has a ``*_flat`` variant consuming the raveled ``(n, D)``
buffer (``repro.utils.stacked_ravel``) instead of the stacked pytree, with a
``backend`` knob dispatching the (n,n)·(n,D) contraction to the Pallas
kernels (``repro.kernels``): ``einsum`` is the pure-XLA reference, ``pallas``
materializes Δ̃ = A·Δ through the mix kernel, ``pallas_fused`` runs the
relay∘aggregate composition u = (w·τᵀA)·Δ as one kernel pass, and
``segment`` consumes a sparse ``relay.EdgeRelay`` operand and contracts via
``jax.ops.segment_sum`` — O(E) in the edge count, the n ≫ 10³ regime of
cohort sampling over sparse geometric graphs.  The pytree ``Aggregator.fn``
is now a thin ravel → flat → unravel wrapper, so all callers share one math
definition.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import relay as relay_lib
from repro.kernels import ops as kernel_ops
from repro.utils import (
    stacked_ravel,
    tree_axpy,
    tree_scale,
    tree_unravel,
    tree_zeros_like,
)


def active_weight(active, *, n: int):
    """The blind averaging weight: 1/n_active (traced) under a churn mask,
    the static python float 1/n without one."""
    if active is None:
        return 1.0 / n
    active = jnp.asarray(active, dtype=jnp.float32)
    return 1.0 / jnp.maximum(active.sum(), 1.0)


def colrel_increment(A, tau, stacked_updates, *, n: int, fused: bool = True,
                     active=None):
    """ColRel PS increment.  ``fused=True`` is the optimized path (identical
    math); ``fused=False`` materializes Δx̃ per relay (paper-faithful)."""
    w = active_weight(active, n=n)
    if active is not None:
        A = relay_lib.mask_relay_matrix(A, active)
        tau = jnp.asarray(tau, jnp.float32) * jnp.asarray(active, jnp.float32)
    if fused:
        return relay_lib.fused_aggregate(A, tau, stacked_updates, w=w)
    relayed = relay_lib.relay(A, stacked_updates)
    return relay_lib.masked_aggregate(tau, relayed, w=w)


def fedavg_blind_increment(tau, stacked_updates, *, n: int, active=None):
    w = active_weight(active, n=n)
    if active is not None:
        tau = jnp.asarray(tau, jnp.float32) * jnp.asarray(active, jnp.float32)
    return relay_lib.masked_aggregate(tau, stacked_updates, w=w)


def fedavg_nonblind_increment(tau, stacked_updates, *, active=None):
    tau = jnp.asarray(tau, dtype=jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    denom = jnp.maximum(tau.sum(), 1.0)

    def reduce(leaf):
        return jnp.tensordot(tau / denom, leaf.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(reduce, stacked_updates)


def no_dropout_increment(stacked_updates, *, n: int, active=None):
    if active is None:
        return jax.tree.map(
            lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0),
            stacked_updates,
        )
    a = jnp.asarray(active, jnp.float32)
    w = a / jnp.maximum(a.sum(), 1.0)

    def reduce(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(reduce, stacked_updates)


# --------------------------------------------------------------------------
# Flat-buffer increments: same math on the raveled (n, D) buffer, with the
# relay_backend dispatch to the Pallas kernels
# --------------------------------------------------------------------------


def colrel_increment_flat(A, tau, buf, *, n: int, fused: bool = True,
                          active=None, backend: str = "einsum",
                          block_d: int | None = None, interpret=None):
    """ColRel PS increment over the (n, D) buffer → (D,).

    ``fused=True`` (or ``backend='pallas_fused'``, which implies it) computes
    u = (w·τᵀA)·Δ without materializing the relayed updates; ``fused=False``
    materializes Δ̃ = A·Δ (paper-faithful protocol shape) then runs the blind
    masked sum w·Σ τ_r Δ̃_r.  Churn: inactive rows/cols of A are zeroed and
    τ intersected with the mask, so inactive slots contribute exactly zero.

    ``backend="segment"`` takes A as an :class:`~repro.core.relay.EdgeRelay`
    (dense matrices are refused — the point is never materializing (n, n));
    the coefficient contraction τᵀA becomes an O(E) segment-sum and the rest
    of the pipeline is unchanged.  Conversely the dense backends accept an
    EdgeRelay by densifying it — a small-n parity convenience.
    """
    if backend == "segment" and not isinstance(A, relay_lib.EdgeRelay):
        raise ValueError(
            "relay_backend='segment' needs an EdgeRelay operand (e.g. a "
            "sparse OPT-α policy / SparseOptAlphaResult.edge_relay()); "
            "got a dense relay matrix"
        )
    if backend != "segment" and isinstance(A, relay_lib.EdgeRelay):
        A = A.todense(buf.shape[0])
    w = active_weight(active, n=n)
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        a = jnp.asarray(active, jnp.float32)
        A = relay_lib.mask_relay_matrix(A, a)
        tau = tau * a
    if fused or backend == "pallas_fused":
        coeffs = w * relay_lib.fused_coefficients(A, tau)
        reduce_backend = (
            "einsum" if backend in ("einsum", "segment") else "pallas_fused"
        )
        return kernel_ops.reduce_flat(
            coeffs, buf, backend=reduce_backend,
            block_d=block_d, interpret=interpret,
        )
    mixed = kernel_ops.mix_flat(
        A, buf, backend=backend, block_d=block_d, interpret=interpret
    )
    return kernel_ops.reduce_flat(w * tau, mixed, backend="einsum")


def fedavg_blind_increment_flat(tau, buf, *, n: int, active=None,
                                backend: str = "einsum",
                                block_d: int | None = None, interpret=None):
    w = active_weight(active, n=n)
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    return _coeff_reduce(w * tau, buf, backend, block_d, interpret)


def fedavg_nonblind_increment_flat(tau, buf, *, active=None,
                                   backend: str = "einsum",
                                   block_d: int | None = None, interpret=None):
    tau = jnp.asarray(tau, jnp.float32)
    if active is not None:
        tau = tau * jnp.asarray(active, jnp.float32)
    coeffs = tau / jnp.maximum(tau.sum(), 1.0)
    return _coeff_reduce(coeffs, buf, backend, block_d, interpret)


def no_dropout_increment_flat(buf, *, n: int, active=None,
                              backend: str = "einsum",
                              block_d: int | None = None, interpret=None):
    if active is None:
        coeffs = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        a = jnp.asarray(active, jnp.float32)
        coeffs = a / jnp.maximum(a.sum(), 1.0)
    return _coeff_reduce(coeffs, buf, backend, block_d, interpret)


def _coeff_reduce(coeffs, buf, backend, block_d, interpret):
    # non-colrel strategies are already a single weighted reduce with dense
    # (n,) coefficients: both kernel backends collapse to the fused-reduction
    # kernel, and "segment" (nothing sparse left to exploit) to the einsum —
    # so an all-inactive cohort stays the exact-zero coefficient vector on
    # every backend rather than tripping a sparse path with no edges.
    reduce_backend = (
        "einsum" if backend in ("einsum", "segment") else "pallas_fused"
    )
    return kernel_ops.reduce_flat(
        coeffs, buf, backend=reduce_backend, block_d=block_d,
        interpret=interpret,
    )


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Bundles a strategy name with its increment functions.

    ``fn(tau, stacked_updates, A=None, active=None) -> increment pytree``
    is the structured entry point: it ravels the stacked updates to the
    contiguous ``(n, D)`` buffer, runs ``flat_fn``, and unravels the result
    (leaves stay in the f32 buffer dtype — the server optimizer owns the
    cast back to the parameter dtype).  ``flat_fn(tau, buf, A=None,
    active=None) -> (D,)`` is the raveled hot path the engines call directly
    when they already hold the buffer.

    For the colrel strategies A is a *traced input* so a time-varying channel
    can swap relay matrices between rounds without retracing the jitted step;
    when omitted, the matrix bound at construction time is used
    (static-channel callers).  ``active`` is the traced churn mask of the
    padded client dimension (None ⇒ full membership, static-weight path).
    """

    name: str
    fn: Callable  # (tau, stacked_updates, A=None, active=None) -> increment
    flat_fn: Callable  # (tau, buf, A=None, active=None) -> (D,) increment
    relay_backend: str = "einsum"


def make_aggregator(
    strategy: str,
    *,
    n: int,
    A=None,
    relay_backend: str = "einsum",
    block_d: int | None = None,
    interpret=None,
) -> Aggregator:
    """``relay_backend`` ∈ ``repro.kernels.ops.RELAY_BACKENDS`` picks the
    einsum reference or the Pallas kernel for the (n,n)·(n,D) contraction;
    ``block_d`` / ``interpret`` tune the kernel (None ⇒ kernel defaults,
    interpret auto-on off-TPU)."""
    kernel_ops.validate_backend(relay_backend)
    default_A = A
    kw = dict(backend=relay_backend, block_d=block_d, interpret=interpret)

    def _resolve(A_arg):
        A_eff = default_A if A_arg is None else A_arg
        if A_eff is None:
            raise ValueError("colrel aggregation needs a relay matrix A "
                             "(bind one at construction or pass it per call)")
        return A_eff

    if strategy == "colrel":
        def flat_fn(tau, buf, A=None, active=None):
            return colrel_increment_flat(
                _resolve(A), tau, buf, n=n, fused=False, active=active, **kw)
    elif strategy == "colrel_fused":
        def flat_fn(tau, buf, A=None, active=None):
            return colrel_increment_flat(
                _resolve(A), tau, buf, n=n, fused=True, active=active, **kw)
    elif strategy == "fedavg_blind":
        def flat_fn(tau, buf, A=None, active=None):
            return fedavg_blind_increment_flat(
                tau, buf, n=n, active=active, **kw)
    elif strategy == "fedavg_nonblind":
        def flat_fn(tau, buf, A=None, active=None):
            return fedavg_nonblind_increment_flat(
                tau, buf, active=active, **kw)
    elif strategy == "no_dropout":
        def flat_fn(tau, buf, A=None, active=None):
            return no_dropout_increment_flat(buf, n=n, active=active, **kw)
    else:
        raise ValueError(f"unknown aggregation strategy: {strategy!r}")

    def fn(tau, upd, A=None, active=None):
        buf, spec = stacked_ravel(upd)
        return tree_unravel(spec, flat_fn(tau, buf, A, active), cast=False)

    return Aggregator(strategy, fn, flat_fn, relay_backend)


# --------------------------------------------------------------------------
# Server optimizer (paper Fig. 4 uses global momentum at the PS)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerOpt:
    """x ← x + lr · (m ← γ m + increment).  γ=0, lr=1 is plain Alg. 2."""

    momentum: float = 0.0
    lr: float = 1.0

    def init(self, params):
        if self.momentum == 0.0:
            return None
        return tree_zeros_like(params)

    def apply(self, params, state, increment):
        def upd(p, inc):
            return (p.astype(jnp.float32) + self.lr * inc).astype(p.dtype)

        if self.momentum == 0.0:
            return jax.tree.map(upd, params, increment), None
        new_state = tree_axpy(1.0, increment, tree_scale(self.momentum, state))
        new_params = jax.tree.map(upd, params, new_state)
        return new_params, new_state
