"""PS aggregation strategies (paper §II-D, Alg. 2, and the Fig. 2-4 baselines).

All strategies consume a stacked pytree of per-client quantities (leading dim
n) plus the round's τ mask, and produce the *global model increment* that the
server optimizer (plain step or global momentum, paper Fig. 4) applies.

Strategies
----------
  colrel           w=1/n blind masked sum of *relayed* updates (eq. 2)
  colrel_fused     same update computed via the fused coefficients (optimized)
  fedavg_blind     w=1/n blind masked sum of *raw* updates (missing ⇒ zero)
  fedavg_nonblind  masked mean over the successful clients (PS knows ids)
  no_dropout       plain 1/n average, perfect connectivity upper bound
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import relay as relay_lib
from repro.utils import tree_axpy, tree_scale, tree_zeros_like


def colrel_increment(A, tau, stacked_updates, *, n: int, fused: bool = True):
    """ColRel PS increment.  ``fused=True`` is the optimized path (identical
    math); ``fused=False`` materializes Δx̃ per relay (paper-faithful)."""
    w = 1.0 / n
    if fused:
        return relay_lib.fused_aggregate(A, tau, stacked_updates, w=w)
    relayed = relay_lib.relay(A, stacked_updates)
    return relay_lib.masked_aggregate(tau, relayed, w=w)


def fedavg_blind_increment(tau, stacked_updates, *, n: int):
    return relay_lib.masked_aggregate(tau, stacked_updates, w=1.0 / n)


def fedavg_nonblind_increment(tau, stacked_updates):
    tau = jnp.asarray(tau, dtype=jnp.float32)
    denom = jnp.maximum(tau.sum(), 1.0)

    def reduce(leaf):
        return jnp.tensordot(tau / denom, leaf.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(reduce, stacked_updates)


def no_dropout_increment(stacked_updates, *, n: int):
    return jax.tree.map(
        lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0), stacked_updates
    )


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Bundles a strategy name with its increment function.

    ``fn(tau, stacked_updates, A=None) -> increment pytree``.  For the colrel
    strategies A is a *traced input* so a time-varying channel can swap relay
    matrices between rounds without retracing the jitted step; when omitted,
    the matrix bound at construction time is used (static-channel callers).
    """

    name: str
    fn: Callable  # (tau, stacked_updates, A=None) -> increment pytree


def make_aggregator(
    strategy: str,
    *,
    n: int,
    A=None,
) -> Aggregator:
    default_A = A

    def _resolve(A_arg):
        A_eff = default_A if A_arg is None else A_arg
        if A_eff is None:
            raise ValueError("colrel aggregation needs a relay matrix A "
                             "(bind one at construction or pass it per call)")
        return A_eff

    if strategy == "colrel":
        return Aggregator(
            "colrel",
            lambda tau, upd, A=None: colrel_increment(
                _resolve(A), tau, upd, n=n, fused=False),
        )
    if strategy == "colrel_fused":
        return Aggregator(
            "colrel_fused",
            lambda tau, upd, A=None: colrel_increment(
                _resolve(A), tau, upd, n=n, fused=True),
        )
    if strategy == "fedavg_blind":
        return Aggregator(
            "fedavg_blind",
            lambda tau, upd, A=None: fedavg_blind_increment(tau, upd, n=n),
        )
    if strategy == "fedavg_nonblind":
        return Aggregator(
            "fedavg_nonblind",
            lambda tau, upd, A=None: fedavg_nonblind_increment(tau, upd),
        )
    if strategy == "no_dropout":
        return Aggregator(
            "no_dropout", lambda tau, upd, A=None: no_dropout_increment(upd, n=n)
        )
    raise ValueError(f"unknown aggregation strategy: {strategy!r}")


# --------------------------------------------------------------------------
# Server optimizer (paper Fig. 4 uses global momentum at the PS)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerOpt:
    """x ← x + lr · (m ← γ m + increment).  γ=0, lr=1 is plain Alg. 2."""

    momentum: float = 0.0
    lr: float = 1.0

    def init(self, params):
        if self.momentum == 0.0:
            return None
        return tree_zeros_like(params)

    def apply(self, params, state, increment):
        def upd(p, inc):
            return (p.astype(jnp.float32) + self.lr * inc).astype(p.dtype)

        if self.momentum == 0.0:
            return jax.tree.map(upd, params, increment), None
        new_state = tree_axpy(1.0, increment, tree_scale(self.momentum, state))
        new_params = jax.tree.map(upd, params, new_state)
        return new_params, new_state
