"""Mixture-of-Experts FFN (Mixtral / Grok-1 style: softmax router, top-2).

Dispatch is scatter/gather-based rather than one-hot-einsum-based: slot
assignment is computed with a cumsum over router one-hots (cheap, int32) and
tokens are moved with ``.at[slots].set`` / ``take``.  This keeps
``cost_analysis`` FLOPs equal to the *active* expert compute (2·E·C·d·f per
matmul) instead of polluting the roofline with fake dispatch-matmul FLOPs —
and maps to all-to-alls rather than broadcast-gathers once sharded.

Capacity-overflow tokens are dropped (standard practice; overflow slot E·C
is a write-off buffer row).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)

    def ew(k, din, dout, scale):
        return (scale * jax.random.normal(k, (E, din, dout), jnp.float32)).astype(
            cfg.pdtype
        )

    p = {
        "router": common.init_dense(ks[0], d, E, cfg.pdtype),
        "up": ew(ks[1], d, f, d**-0.5),
        "down": ew(ks[2], f, d, f**-0.5),
    }
    if cfg.mlp_gated:
        p["gate"] = ew(ks[3], d, f, d**-0.5)
    return p


def moe_ffn(p, x, cfg: ModelConfig):
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar).

    Dispatch is *group-wise*: each batch row routes independently (vmap over
    B), so with the batch dim sharded over the data axis every scatter/gather
    stays device-local — no cross-shard collective-permute storm (§Perf
    iteration 3; the flat-token variant cost grok-1 ~29 TB/device of
    collective-permute per 32k prefill).  Capacity is per group.
    """
    out, aux = jax.vmap(
        lambda row: _moe_ffn_group(p, row, cfg), in_axes=0, out_axes=(0, 0)
    )(x)
    return out, jnp.mean(aux)


def _moe_ffn_group(p, x, cfg: ModelConfig):
    """x (S, D) — one routing group."""
    mcfg = cfg.moe
    S, D = x.shape
    T = S
    E, K = mcfg.n_experts, mcfg.top_k
    C = max(1, int(mcfg.capacity_factor * T * K / E))

    xt = x.reshape(T, D)
    logits = common.dense(p["router"], xt, cdtype=jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    top1 = expert_ids[:, 0]
    f_e = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e) * mcfg.aux_loss_weight

    # Slot assignment: flatten the K choices, count position within expert.
    flat_e = expert_ids.reshape(T * K)  # choice-major per token
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (TK,E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive count
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (TK,)
    overflow = pos >= C
    slots = jnp.where(overflow, E * C, flat_e * C + pos)  # E*C = dump row

    buf = jnp.zeros((E * C + 1, D), cfg.cdtype)
    xt_rep = jnp.repeat(xt.astype(cfg.cdtype), K, axis=0)  # token t appears K times
    buf = buf.at[slots].set(xt_rep)
    eb = buf[: E * C].reshape(E, C, D)

    # Expert FFN: batched over experts — FLOPs = active compute only.
    act = common.activation(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", eb, p["up"].astype(cfg.cdtype))
    if "gate" in p:
        g = jnp.einsum("ecd,edf->ecf", eb, p["gate"].astype(cfg.cdtype))
        h = act(g) * up
    else:
        h = act(up)
    y = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(cfg.cdtype))

    yflat = jnp.concatenate([y.reshape(E * C, D), jnp.zeros((1, D), cfg.cdtype)])
    gathered = yflat[slots]  # (TK, D); dropped tokens read zeros
    gathered = gathered * jnp.where(overflow, 0.0, gate_vals.reshape(T * K)).astype(
        cfg.cdtype
    )[:, None]
    out = gathered.reshape(T, K, D).sum(axis=1).reshape(S, D)
    return out, aux
