"""RG-LRU recurrent block (RecurrentGemma / Griffin) — the recurrent 2/3 of
the hybrid architecture.  Linear per-channel recurrence

    r_t = σ(W_a x_t + b_a)            (recurrence gate)
    i_t = σ(W_x x_t + b_x)            (input gate)
    a_t = exp(-c · softplus(Λ) · r_t) (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

run with ``lax.associative_scan`` over the sequence (state is (B, S, width) —
no d_state blow-up, so no chunking needed).  The full Griffin recurrent block
is: linear → causal conv(4) → RG-LRU on one branch, gated by GeLU(linear) on
the other, then an output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru_block(key, cfg: ModelConfig):
    d, w = cfg.d_model, _width(cfg)
    dc = cfg.rglru.conv_width
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (griffin init)
    lam = jax.random.uniform(ks[0], (w,), minval=2.0, maxval=6.0)
    return {
        "in_x": common.init_dense(ks[1], d, w, cfg.pdtype),
        "in_gate": common.init_dense(ks[2], d, w, cfg.pdtype),
        "conv_w": (0.1 * jax.random.normal(ks[3], (dc, w), jnp.float32)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((w,), cfg.pdtype),
        "W_a": common.init_dense(ks[4], w, w, cfg.pdtype, bias=True),
        "W_x": common.init_dense(ks[5], w, w, cfg.pdtype, bias=True),
        "lam": lam.astype(cfg.pdtype),
        "out": common.init_dense(jax.random.fold_in(key, 7), w, d, cfg.pdtype, scale=w**-0.5),
    }


def _gates(p, x, cfg: ModelConfig):
    r = jax.nn.sigmoid(common.dense(p["W_a"], x, cdtype=jnp.float32))
    i = jax.nn.sigmoid(common.dense(p["W_x"], x, cdtype=jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, gated_in


def _causal_conv(p, x, cfg: ModelConfig):
    dc = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * p["conv_w"][i].astype(cfg.cdtype)
        for i in range(dc)
    )
    return out + p["conv_b"].astype(cfg.cdtype)


# chunk length for the linear recurrence: bounds the (B, chunk, W) f32
# gate/state tensors the backward pass must hold (EXPERIMENTS.md §Perf it. 5)
CHUNK = 512


def _combine(l, r):
    return l[0] * r[0], l[1] * r[0] + r[1]


def _recurrence_from_xb(p, xb, cfg: ModelConfig, h0):
    """Gates + linear recurrence, chunked over the sequence.

    The W_a/W_x projections, the f32 decay/input gates and the associative
    scan all live *inside* the per-chunk checkpoint, so the backward pass
    holds one (B, CHUNK, W) working set instead of five (B, S, W) f32
    tensors.  xb: (B, S, W) post-conv activations (bf16).
    """
    B, S, W = xb.shape
    q = min(CHUNK, S)
    if S % q:
        a, b = _gates(p, xb, cfg)  # short sequences: one-shot
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0)
        _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
        return h.astype(cfg.cdtype)
    nc = S // q
    xr = xb.reshape(B, nc, q, W).swapaxes(0, 1)
    h0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    @jax.checkpoint
    def chunk_step(h, xc):
        ac, bc = _gates(p, xc, cfg)
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        _, hc = jax.lax.associative_scan(_combine, (ac, bc), axis=1)
        return hc[:, -1], hc.astype(cfg.cdtype)

    _, hs = jax.lax.scan(chunk_step, h0, xr)
    return hs.swapaxes(0, 1).reshape(B, S, W)


def rglru_block(p, x, cfg: ModelConfig, h0=None):
    """Full-sequence path.  x (B,S,D) -> (out (B,S,D), h_final (B,W))."""
    xb = common.dense(p["in_x"], x, cdtype=cfg.cdtype)
    gate = jax.nn.gelu(common.dense(p["in_gate"], x, cdtype=cfg.cdtype))
    xb = _causal_conv(p, xb, cfg)
    h = _recurrence_from_xb(p, xb, cfg, h0)
    y = h * gate
    return common.dense(p["out"], y, cdtype=cfg.cdtype), h[:, -1].astype(jnp.float32)


def init_rglru_state(cfg: ModelConfig, batch: int):
    w, dc = _width(cfg), cfg.rglru.conv_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, w), cfg.cdtype),
    }


def rglru_decode_block(p, x1, state, cfg: ModelConfig):
    """One-token step.  x1 (B,1,D) -> (out (B,1,D), new state)."""
    xb = common.dense(p["in_x"], x1, cdtype=cfg.cdtype)  # (B,1,W)
    gate = jax.nn.gelu(common.dense(p["in_gate"], x1, cdtype=cfg.cdtype))
    window = jnp.concatenate([state["conv"], xb], axis=1)  # (B,dc,W)
    conv = jnp.einsum("btw,tw->bw", window.astype(cfg.cdtype), p["conv_w"].astype(cfg.cdtype))
    xc = (conv + p["conv_b"].astype(cfg.cdtype))[:, None]
    a, b = _gates(p, xc, cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = h[:, None].astype(cfg.cdtype) * gate
    out = common.dense(p["out"], y, cdtype=cfg.cdtype)
    return out, {"h": h, "conv": window[:, 1:]}
