"""Shared building blocks for the model zoo: norms, MLPs, RoPE, embeddings.

All models are pure-pytree functional: ``init_*`` builds nested dicts of
arrays, ``*_fwd`` applies them.  Layer stacks are stored stacked along a
leading layer dim and driven by ``lax.scan`` so compile time is O(1) in depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def init_dense(key, d_in, d_out, dtype, *, bias=False, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, *, cdtype):
    y = jnp.einsum("...i,io->...o", x.astype(cdtype), p["w"].astype(cdtype))
    if "b" in p:
        y = y + p["b"].astype(cdtype)
    return y


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "up": init_dense(ks[0], d, f, cfg.pdtype),
        "down": init_dense(ks[1], f, d, cfg.pdtype, scale=f**-0.5),
    }
    if cfg.mlp_gated:
        p["gate"] = init_dense(ks[2], d, f, cfg.pdtype)
    return p


def mlp(p, x, cfg: ModelConfig):
    act = activation(cfg.act)
    up = dense(p["up"], x, cdtype=cfg.cdtype)
    h = act(dense(p["gate"], x, cdtype=cfg.cdtype)) * up if "gate" in p else act(up)
    return dense(p["down"], h, cdtype=cfg.cdtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support for glm4)
# --------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig):
    hd = cfg.hd
    rot = int(hd * cfg.rotary_pct) // 2 * 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, cfg: ModelConfig):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    inv, rot = rope_freqs(cfg)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    y = jnp.stack([out1, out2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([y, xp], axis=-1).astype(x.dtype)


def init_embedding(key, vocab, d, dtype):
    return {"table": _normal(key, (vocab, d), 1.0, dtype)}


def embed(p, tokens, *, cdtype):
    return p["table"].astype(cdtype)[tokens]


def unembed(p, x, *, cdtype):
    return jnp.einsum("...d,vd->...v", x.astype(cdtype), p["table"].astype(cdtype))


def cross_entropy(logits, labels):
    """Mean token-level CE.  logits (..., V) f32-cast; labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def stack_layers(init_one, key, n_layers: int):
    """Initialize n layers and stack each leaf along a leading layer dim."""
    keys = jax.random.split(key, n_layers)
    layers = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)
