"""ResNet-20 for CIFAR-shaped inputs — the paper's §V model.

Faithful 3-stage (16/32/64 channels, 3 basic blocks each) CIFAR ResNet.
One documented deviation (DESIGN.md §8): GroupNorm(8) replaces BatchNorm so
the model stays purely functional — BN running statistics interact badly with
federated parameter averaging and add mutable state for no benefit to the
protocol under study.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * (2.0 / fan_in) ** 0.5
    return w.astype(dtype)


def _conv(w, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _init_gn(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _gn(p, x, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    xf = x.astype(jnp.float32).reshape(B, H, W, g, C // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = xf.var(axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(B, H, W, C)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def _init_block(key, cin, cout, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout, dtype),
        "gn1": _init_gn(cout, dtype),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout, dtype),
        "gn2": _init_gn(cout, dtype),
    }
    if cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout, dtype)
    return p


def _block(p, x, stride):
    h = jax.nn.relu(_gn(p["gn1"], _conv(p["conv1"], x, stride)))
    h = _gn(p["gn2"], _conv(p["conv2"], h))
    sc = _conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def init_resnet20(key, cfg: ModelConfig, num_classes: int = 10):
    dtype = cfg.pdtype
    ks = jax.random.split(key, 12)
    widths = [16, 32, 64]
    params = {"stem": _conv_init(ks[0], 3, 3, 3, 16, dtype), "gn0": _init_gn(16, dtype)}
    cin = 16
    i = 1
    for s, w in enumerate(widths):
        for b in range(3):
            params[f"s{s}b{b}"] = _init_block(ks[i], cin, w, dtype)
            cin = w
            i += 1
    params["fc"] = {
        "w": jax.random.normal(ks[i], (64, num_classes), jnp.float32).astype(dtype)
        * 64**-0.5,
        "b": jnp.zeros((num_classes,), dtype),
    }
    return params


def resnet20_logits(params, cfg: ModelConfig, images):
    """images (B, 32, 32, 3) -> logits (B, 10)."""
    x = images.astype(cfg.cdtype)
    x = jax.nn.relu(_gn(params["gn0"], _conv(params["stem"], x)))
    for s in range(3):
        for b in range(3):
            stride = 2 if (s > 0 and b == 0) else 1
            x = _block(params[f"s{s}b{b}"], x, stride)
    x = x.mean(axis=(1, 2))
    return x @ params["fc"]["w"].astype(cfg.cdtype) + params["fc"]["b"].astype(cfg.cdtype)


def resnet20_loss(params, cfg: ModelConfig, batch):
    logits = resnet20_logits(params, cfg, batch["images"]).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
