"""Mamba-1 (selective SSM) backbone — falcon-mamba-7b family.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel is replaced by a
*chunked associative scan*: the sequence is split into chunks; within a chunk
the linear recurrence h_t = a_t ⊙ h_{t-1} + b_t runs as
``lax.associative_scan`` (parallel, MXU/VPU friendly), and a ``lax.scan``
carries the boundary state across chunks.  This bounds the materialized state
to (B, chunk, d_inner, d_state) instead of (B, S, d_inner, d_state), which is
the same blocking trade-off the original "hardware-aware" kernel makes for
SRAM — re-derived here for VMEM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

CHUNK = 256


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def init_mamba_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    di, dtr, ds, dc = _dims(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": common.init_dense(ks[0], d, 2 * di, cfg.pdtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (dc, di), jnp.float32)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "x_proj": common.init_dense(ks[2], di, dtr + 2 * ds, cfg.pdtype),
        "dt_proj": common.init_dense(ks[3], dtr, di, cfg.pdtype, bias=True),
        "A_log": jnp.log(A).astype(cfg.pdtype),
        "D": jnp.ones((di,), cfg.pdtype),
        "out_proj": common.init_dense(ks[4], di, d, cfg.pdtype, scale=di**-0.5),
        "norm": common.init_rmsnorm(d, cfg.pdtype),
    }


def _ssm_inputs(p, xz, cfg: ModelConfig):
    """Project conv output to (delta, B, C) and the decay a = exp(Δ·A)."""
    di, dtr, ds, _ = _dims(cfg)
    proj = common.dense(p["x_proj"], xz, cdtype=cfg.cdtype)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(common.dense(p["dt_proj"], dt, cdtype=cfg.cdtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds), negative
    # a: (..., di, ds); b: (..., di, ds) = Δ ⊙ x (outer with B)
    a = jnp.exp(delta.astype(jnp.float32)[..., :, None] * A)
    b = (delta * xz).astype(jnp.float32)[..., :, None] * Bm.astype(jnp.float32)[..., None, :]
    return a, b, Cm.astype(jnp.float32)


def _chunked_scan(a, b, C, h0):
    """Linear recurrence via chunked associative scan.

    a, b: (B, S, di, ds); C: (B, S, ds); h0: (B, di, ds).
    Returns (y (B, S, di) f32, h_final).
    """
    Bsz, S, di, ds = a.shape
    q = min(CHUNK, S)
    assert S % q == 0, f"seq {S} not a multiple of chunk {q}"
    nc = S // q
    ar = a.reshape(Bsz, nc, q, di, ds).swapaxes(0, 1)
    br = b.reshape(Bsz, nc, q, di, ds).swapaxes(0, 1)
    Cr = C.reshape(Bsz, nc, q, ds).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def chunk_step(h, inp):
        ac, bc, cc = inp
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_t = acc_a * h[:, None] + acc_b  # (B, q, di, ds)
        y = jnp.einsum("bqds,bqs->bqd", h_t, cc)
        return h_t[:, -1], y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (ar, br, Cr))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, di)
    return y, h_fin


def _causal_conv(p, x, cfg: ModelConfig):
    """Depthwise causal conv over seq: x (B,S,di)."""
    dc = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * p["conv_w"][i].astype(cfg.cdtype)
        for i in range(dc)
    )
    return out + p["conv_b"].astype(cfg.cdtype)


def mamba_layer(p, x, cfg: ModelConfig, h0=None):
    """Full-sequence path. x (B,S,D). Returns (out, h_final)."""
    di, *_ = _dims(cfg)
    ds = cfg.ssm.d_state
    B = x.shape[0]
    resid = x
    x = common.rmsnorm(p["norm"], x, eps=cfg.norm_eps)
    xz = common.dense(p["in_proj"], x, cdtype=cfg.cdtype)
    xpart, z = jnp.split(xz, 2, axis=-1)
    xpart = jax.nn.silu(_causal_conv(p, xpart, cfg))
    a, b, C = _ssm_inputs(p, xpart, cfg)
    h0 = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0
    y, h_fin = _chunked_scan(a, b, C, h0)
    y = y.astype(cfg.cdtype) + p["D"].astype(cfg.cdtype) * xpart
    y = y * jax.nn.silu(z)
    out = common.dense(p["out_proj"], y, cdtype=cfg.cdtype)
    return resid + out, h_fin


def init_mamba_state(cfg: ModelConfig, batch: int):
    di, _, ds, dc = _dims(cfg)
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), cfg.cdtype),
    }


def mamba_decode_layer(p, x1, state, cfg: ModelConfig):
    """One-token step. x1 (B,1,D). Returns (out (B,1,D), new state)."""
    resid = x1
    x = common.rmsnorm(p["norm"], x1, eps=cfg.norm_eps)
    xz = common.dense(p["in_proj"], x, cdtype=cfg.cdtype)
    xpart, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    window = jnp.concatenate([state["conv"], xpart], axis=1)  # (B,dc,di)
    conv = jnp.einsum("bti,ti->bi", window.astype(cfg.cdtype), p["conv_w"].astype(cfg.cdtype))
    xc = jax.nn.silu(conv + p["conv_b"].astype(cfg.cdtype))[:, None]
    a, b, C = _ssm_inputs(p, xc, cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = jnp.einsum("bds,bs->bd", h, C[:, 0])[:, None]
    y = y.astype(cfg.cdtype) + p["D"].astype(cfg.cdtype) * xc
    y = y * jax.nn.silu(z)
    out = common.dense(p["out_proj"], y, cdtype=cfg.cdtype)
    new_state = {"h": h, "conv": window[:, 1:]}
    return resid + out, new_state
