"""Model assembly: full LM stacks per architecture family.

Every family exposes the same five entry points consumed by the FL engine,
the serving path and the dry-run launcher:

    init(key, cfg)                          -> params
    loss(params, cfg, batch)                -> scalar loss
    prefill(params, cfg, batch)             -> (last_logits, cache)
    decode(params, cfg, cache, tokens)      -> (logits, cache)
    (plus ``registry.input_specs`` for shapes)

Layer stacks are stacked-pytree + ``lax.scan`` (compile time O(1) in depth);
every scanned train block is wrapped in ``jax.checkpoint`` (full remat — the
baseline activation policy; revisited in EXPERIMENTS.md §Perf).
Cross-entropy is computed in sequence chunks so the (B, S, V) logits tensor
is never materialized.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mamba, moe, rglru

CE_CHUNK = 256


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------


def _positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None] + offset, (B, S))


def _init_dense_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["mlp"] = common.init_mlp(ks[2], cfg)
    return p


def _dense_block(p, x, positions, cfg: ModelConfig, *, collect_kv=False):
    h = common.rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
    if cfg.sliding_window:
        a, kv = attention.sliding_window_attention(
            p["attn"], h, positions, cfg, window=cfg.sliding_window
        )
    else:
        a, kv = attention.full_attention(p["attn"], h, positions, cfg, causal=True)
    x = x + a
    h = common.rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
    if "moe" in p:
        m, aux = moe.moe_ffn(p["moe"], h, cfg)
    else:
        m, aux = common.mlp(p["mlp"], h, cfg), jnp.float32(0.0)
    x = x + m
    return x, aux, (kv if collect_kv else None)


def _dense_block_decode(p, x1, cache, pos, cfg: ModelConfig):
    h = common.rmsnorm(p["ln1"], x1, eps=cfg.norm_eps)
    a, cache = attention.decode_attention(
        p["attn"], h, cache, pos, cfg, window=cfg.sliding_window
    )
    x1 = x1 + a
    h = common.rmsnorm(p["ln2"], x1, eps=cfg.norm_eps)
    if "moe" in p:
        m, _ = moe.moe_ffn(p["moe"], h, cfg)
    else:
        m = common.mlp(p["mlp"], h, cfg)
    return x1 + m, cache


def _logits(params, cfg: ModelConfig, x):
    x = common.rmsnorm(params["norm"], x, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        return common.unembed(params["embed"], x, cdtype=cfg.cdtype)
    return common.dense(params["head"], x, cdtype=cfg.cdtype)


def _chunked_ce(params, cfg: ModelConfig, x, labels):
    """Mean CE without materializing (B, S, V).  x (B,S,D), labels (B,S)."""
    B, S, _ = x.shape
    c = min(CE_CHUNK, S)
    assert S % c == 0
    xc = x.reshape(B, S // c, c, -1).swapaxes(0, 1)
    lc = labels.reshape(B, S // c, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        xch, lch = inp
        logits = _logits(params, cfg, xch)
        return carry + common.cross_entropy(logits, lch) * (c / S), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (xc, lc))
    return total


def _cache_capacity(cfg: ModelConfig, total_len: int) -> int:
    w = cfg.sliding_window
    return min(total_len, w) if w else total_len


# Ring-buffer headroom reserved by prefill so subsequent decode steps do not
# evict live positions of full-attention caches.
PREFILL_HEADROOM = 128


# --------------------------------------------------------------------------
# dense / moe LM
# --------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = {
        "embed": common.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "blocks": common.stack_layers(
            lambda k: _init_dense_block(k, cfg), ks[1], cfg.n_layers
        ),
        "norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = common.init_dense(ks[2], cfg.d_model, cfg.vocab, cfg.pdtype)
    return params


def lm_backbone(params, cfg: ModelConfig, tokens):
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    @jax.checkpoint
    def body(carry, layer_p):
        x, aux = carry
        x, a, _ = _dense_block(layer_p, x, pos, cfg)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    return x, aux


def lm_loss(params, cfg: ModelConfig, batch):
    x, aux = lm_backbone(params, cfg, batch["tokens"])
    return _chunked_ce(params, cfg, x, batch["labels"]) + aux


def lm_prefill(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = _cache_capacity(cfg, S + PREFILL_HEADROOM)
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    def body(x, layer_p):
        x, _, (k, v) = _dense_block(layer_p, x, pos, cfg, collect_kv=True)
        cache = attention.fill_cache_from_prefill(
            attention.init_cache(cfg, B, cap), k, v, S
        )
        return x, cache

    x, caches = jax.lax.scan(body, x, params["blocks"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"layers": caches, "t": jnp.int32(S)}


def lm_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    """Cache stand-in for decode dry-runs: full cache of `seq_len` tokens."""
    cap = _cache_capacity(cfg, seq_len)
    one = attention.init_cache(cfg, batch_size, cap)
    layers = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape), one
    )
    return {"layers": layers, "t": jnp.int32(seq_len)}


def lm_decode(params, cfg: ModelConfig, cache, tokens):
    """tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = cache["t"]

    def body(x, inp):
        layer_p, layer_cache = inp
        x, new_cache = _dense_block_decode(layer_p, x, layer_cache, pos, cfg)
        return x, new_cache

    x, caches = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
    return _logits(params, cfg, x), {"layers": caches, "t": pos + 1}


# --------------------------------------------------------------------------
# VLM: groups of (cross_attn_every - 1) self layers + 1 gated cross layer
# --------------------------------------------------------------------------


def _init_cross_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "xattn": attention.init_attention(ks[0], cfg, cross=True),
        "gate_a": jnp.zeros((), cfg.pdtype),
        "ln2": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "mlp": common.init_mlp(ks[1], cfg),
        "gate_m": jnp.zeros((), cfg.pdtype),
    }


def _cross_block(p, x, mem_k, mem_v, cfg: ModelConfig):
    h = common.rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
    a = attention.cross_attention(p["xattn"], h, mem_k, mem_v, cfg)
    x = x + jnp.tanh(p["gate_a"].astype(jnp.float32)).astype(x.dtype) * a
    h = common.rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
    m = common.mlp(p["mlp"], h, cfg)
    return x + jnp.tanh(p["gate_m"].astype(jnp.float32)).astype(x.dtype) * m


def init_vlm(key, cfg: ModelConfig):
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    n_self = every - 1
    ks = jax.random.split(key, 5)

    def init_group(k):
        k1, k2 = jax.random.split(k)
        return {
            "selfs": common.stack_layers(lambda kk: _init_dense_block(kk, cfg), k1, n_self),
            "cross": _init_cross_block(k2, cfg),
        }

    params = {
        "embed": common.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "groups": common.stack_layers(init_group, ks[1], n_groups),
        "norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "head": common.init_dense(ks[2], cfg.d_model, cfg.vocab, cfg.pdtype),
    }
    return params


def vlm_backbone(params, cfg: ModelConfig, tokens, img_embeds):
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)
    img = img_embeds.astype(cfg.cdtype)

    @jax.checkpoint
    def group_body(x, gp):
        def self_body(x, lp):
            x, _, _ = _dense_block(lp, x, pos, cfg)
            return x, None

        x, _ = jax.lax.scan(self_body, x, gp["selfs"])
        mk, mv = attention.project_memory(gp["cross"]["xattn"], img, cfg)
        x = _cross_block(gp["cross"], x, mk, mv, cfg)
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    return x


def vlm_loss(params, cfg: ModelConfig, batch):
    x = vlm_backbone(params, cfg, batch["tokens"], batch["img_embeds"])
    return _chunked_ce(params, cfg, x, batch["labels"])


def vlm_prefill(params, cfg: ModelConfig, batch):
    tokens, img = batch["tokens"], batch["img_embeds"].astype(cfg.cdtype)
    B, S = tokens.shape
    cap = _cache_capacity(cfg, S + PREFILL_HEADROOM)
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    def group_body(x, gp):
        def self_body(x, lp):
            x, _, (k, v) = _dense_block(lp, x, pos, cfg, collect_kv=True)
            cache = attention.fill_cache_from_prefill(
                attention.init_cache(cfg, B, cap), k, v, S
            )
            return x, cache

        x, self_caches = jax.lax.scan(self_body, x, gp["selfs"])
        mk, mv = attention.project_memory(gp["cross"]["xattn"], img, cfg)
        x = _cross_block(gp["cross"], x, mk, mv, cfg)
        return x, (self_caches, (mk, mv))

    x, (caches, mem_kv) = jax.lax.scan(group_body, x, params["groups"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"layers": caches, "mem_kv": mem_kv, "t": jnp.int32(S)}


def vlm_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    every = cfg.cross_attn_every
    n_groups, n_self = cfg.n_layers // every, every - 1
    cap = _cache_capacity(cfg, seq_len)
    one = attention.init_cache(cfg, batch_size, cap)
    layers = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (n_groups, n_self) + leaf.shape), one
    )
    mem = jnp.zeros((n_groups, batch_size, cfg.n_image_tokens, cfg.n_kv, cfg.hd), cfg.cdtype)
    return {"layers": layers, "mem_kv": (mem, mem), "t": jnp.int32(seq_len)}


def vlm_decode(params, cfg: ModelConfig, cache, tokens):
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = cache["t"]

    def group_body(x, inp):
        gp, self_caches, (mk, mv) = inp

        def self_body(x, sinp):
            lp, lc = sinp
            x, nc = _dense_block_decode(lp, x, lc, pos, cfg)
            return x, nc

        x, new_caches = jax.lax.scan(self_body, x, (gp["selfs"], self_caches))
        x = _cross_block(gp["cross"], x, mk, mv, cfg)
        return x, new_caches

    x, caches = jax.lax.scan(
        group_body, x, (params["groups"], cache["layers"], cache["mem_kv"])
    )
    return _logits(params, cfg, x), {
        "layers": caches,
        "mem_kv": cache["mem_kv"],
        "t": pos + 1,
    }


# --------------------------------------------------------------------------
# encoder-decoder (whisper): stub frontend supplies frame embeddings
# --------------------------------------------------------------------------


def _init_enc_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "mlp": common.init_mlp(ks[1], cfg),
    }


def _init_dec_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "lnx": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "xattn": attention.init_attention(ks[1], cfg, cross=True),
        "ln2": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "mlp": common.init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    return {
        "enc_blocks": common.stack_layers(
            lambda k: _init_enc_block(k, cfg), ks[0], cfg.n_enc_layers
        ),
        "enc_norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "embed": common.init_embedding(ks[1], cfg.vocab, cfg.d_model, cfg.pdtype),
        "blocks": common.stack_layers(
            lambda k: _init_dec_block(k, cfg), ks[2], cfg.n_layers
        ),
        "norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "head": common.init_dense(ks[3], cfg.d_model, cfg.vocab, cfg.pdtype),
    }


def encode(params, cfg: ModelConfig, frame_embeds):
    x = frame_embeds.astype(cfg.cdtype)
    B, F, _ = x.shape
    pos = _positions(B, F)

    @jax.checkpoint
    def body(x, lp):
        h = common.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        a, _ = attention.full_attention(lp["attn"], h, pos, cfg, causal=False)
        x = x + a
        h = common.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        return x + common.mlp(lp["mlp"], h, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return common.rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)


def _dec_block(p, x, positions, memory, cfg: ModelConfig, *, collect_kv=False):
    h = common.rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
    a, kv = attention.full_attention(p["attn"], h, positions, cfg, causal=True)
    x = x + a
    h = common.rmsnorm(p["lnx"], x, eps=cfg.norm_eps)
    mk, mv = attention.project_memory(p["xattn"], memory, cfg)
    x = x + attention.cross_attention(p["xattn"], h, mk, mv, cfg)
    h = common.rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
    x = x + common.mlp(p["mlp"], h, cfg)
    return x, (kv if collect_kv else None), (mk, mv)


def encdec_loss(params, cfg: ModelConfig, batch):
    memory = encode(params, cfg, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    @jax.checkpoint
    def body(x, lp):
        x, _, _ = _dec_block(lp, x, pos, memory, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return _chunked_ce(params, cfg, x, batch["labels"])


def encdec_prefill(params, cfg: ModelConfig, batch):
    memory = encode(params, cfg, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = _cache_capacity(cfg, S + PREFILL_HEADROOM)
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    def body(x, lp):
        x, (k, v), mem_kv = _dec_block(lp, x, pos, memory, cfg, collect_kv=True)
        cache = attention.fill_cache_from_prefill(
            attention.init_cache(cfg, B, cap), k, v, S
        )
        return x, (cache, mem_kv)

    x, (caches, mem_kv) = jax.lax.scan(body, x, params["blocks"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"layers": caches, "mem_kv": mem_kv, "t": jnp.int32(S)}


def encdec_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    cap = _cache_capacity(cfg, seq_len)
    one = attention.init_cache(cfg, batch_size, cap)
    layers = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape), one
    )
    mem = jnp.zeros(
        (cfg.n_layers, batch_size, cfg.enc_frames, cfg.n_kv, cfg.hd), cfg.cdtype
    )
    return {"layers": layers, "mem_kv": (mem, mem), "t": jnp.int32(seq_len)}


def encdec_decode(params, cfg: ModelConfig, cache, tokens):
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = cache["t"]

    def body(x, inp):
        lp, lc, (mk, mv) = inp
        h = common.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        a, nc = attention.decode_attention(lp["attn"], h, lc, pos, cfg)
        x = x + a
        h = common.rmsnorm(lp["lnx"], x, eps=cfg.norm_eps)
        x = x + attention.cross_attention(lp["xattn"], h, mk, mv, cfg)
        h = common.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        x = x + common.mlp(lp["mlp"], h, cfg)
        return x, nc

    x, caches = jax.lax.scan(body, x, (params["blocks"], cache["layers"], cache["mem_kv"]))
    return _logits(params, cfg, x), {
        "layers": caches,
        "mem_kv": cache["mem_kv"],
        "t": pos + 1,
    }


# --------------------------------------------------------------------------
# SSM (falcon-mamba)
# --------------------------------------------------------------------------


def init_mamba_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "embed": common.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "blocks": common.stack_layers(
            lambda k: mamba.init_mamba_layer(k, cfg), ks[1], cfg.n_layers
        ),
        "norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "head": common.init_dense(ks[2], cfg.d_model, cfg.vocab, cfg.pdtype),
    }


def mamba_loss(params, cfg: ModelConfig, batch):
    x = common.embed(params["embed"], batch["tokens"], cdtype=cfg.cdtype)

    @jax.checkpoint
    def body(x, lp):
        x, _ = mamba.mamba_layer(lp, x, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return _chunked_ce(params, cfg, x, batch["labels"])


def mamba_prefill(params, cfg: ModelConfig, batch):
    x = common.embed(params["embed"], batch["tokens"], cdtype=cfg.cdtype)
    B = x.shape[0]

    def body(x, lp):
        # conv tail (last d_conv-1 *pre-conv* activations) must come from the
        # layer input, so recompute the in_proj tail before running the layer.
        xn = common.rmsnorm(lp["norm"], x, eps=cfg.norm_eps)
        tail = common.dense(
            lp["in_proj"], xn[:, -(cfg.ssm.d_conv - 1) :], cdtype=cfg.cdtype
        )
        conv_tail = jnp.split(tail, 2, axis=-1)[0]
        x, h = mamba.mamba_layer(lp, x, cfg)
        return x, {"h": h, "conv": conv_tail}

    x, states = jax.lax.scan(body, x, params["blocks"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"layers": states, "t": jnp.int32(batch["tokens"].shape[1])}


def mamba_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    one = mamba.init_mamba_state(cfg, batch_size)
    layers = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape), one
    )
    return {"layers": layers, "t": jnp.int32(seq_len)}


def mamba_decode(params, cfg: ModelConfig, cache, tokens):
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)

    def body(x, inp):
        lp, st = inp
        x, st = mamba.mamba_decode_layer(lp, x, st, cfg)
        return x, st

    x, states = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
    return _logits(params, cfg, x), {"layers": states, "t": cache["t"] + 1}


# --------------------------------------------------------------------------
# hybrid (recurrentgemma): (rec, rec, attn) groups + remainder rec layers
# --------------------------------------------------------------------------


def _hybrid_counts(cfg: ModelConfig):
    pat = len(cfg.rglru.block_pattern)  # 3
    return cfg.n_layers // pat, cfg.n_layers % pat


def _init_temporal_unit(key, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(key)
    unit = {
        "ln1": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "ln2": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "mlp": common.init_mlp(k2, cfg),
    }
    if kind == "recurrent":
        unit["rec"] = rglru.init_rglru_block(k1, cfg)
    else:
        unit["attn"] = attention.init_attention(k1, cfg)
    return unit


def _temporal_unit_fwd(p, x, positions, cfg: ModelConfig, state=None):
    """One griffin layer: temporal mixer + MLP, both residual.
    Returns (x, new_state_or_kv)."""
    h = common.rmsnorm(p["ln1"], x, eps=cfg.norm_eps)
    if "rec" in p:
        o, hfin = rglru.rglru_block(p["rec"], h, cfg)
        out_state = hfin
    else:
        o, (k, v) = attention.sliding_window_attention(
            p["attn"], h, positions, cfg, window=cfg.rglru.local_window
        )
        out_state = (k, v)
    x = x + o
    h = common.rmsnorm(p["ln2"], x, eps=cfg.norm_eps)
    return x + common.mlp(p["mlp"], h, cfg), out_state


def init_hybrid(key, cfg: ModelConfig):
    n_groups, rem = _hybrid_counts(cfg)
    ks = jax.random.split(key, 6)

    def init_group(k):
        kk = jax.random.split(k, len(cfg.rglru.block_pattern))
        return {
            f"u{i}": _init_temporal_unit(kk[i], cfg, kind)
            for i, kind in enumerate(cfg.rglru.block_pattern)
        }

    params = {
        "embed": common.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "groups": common.stack_layers(init_group, ks[1], n_groups),
        "norm": common.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "head": common.init_dense(ks[2], cfg.d_model, cfg.vocab, cfg.pdtype),
    }
    if rem:
        params["rem"] = common.stack_layers(
            lambda k: _init_temporal_unit(k, cfg, "recurrent"), ks[3], rem
        )
    return params


def hybrid_backbone(params, cfg: ModelConfig, tokens):
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    @jax.checkpoint
    def group_body(x, gp):
        for i in range(len(cfg.rglru.block_pattern)):
            x, _ = _temporal_unit_fwd(gp[f"u{i}"], x, pos, cfg)
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "rem" in params:

        @jax.checkpoint
        def rem_body(x, lp):
            x, _ = _temporal_unit_fwd(lp, x, pos, cfg)
            return x, None

        x, _ = jax.lax.scan(rem_body, x, params["rem"])
    return x


def hybrid_loss(params, cfg: ModelConfig, batch):
    x = hybrid_backbone(params, cfg, batch["tokens"])
    return _chunked_ce(params, cfg, x, batch["labels"])


def _hybrid_unit_state(cfg: ModelConfig, kind: str, B: int, cap: int):
    if kind == "recurrent":
        return rglru.init_rglru_state(cfg, B)
    return attention.init_cache(cfg, B, cap)


def hybrid_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    n_groups, rem = _hybrid_counts(cfg)
    cap = min(seq_len, cfg.rglru.local_window)
    group_state = {
        f"u{i}": _hybrid_unit_state(cfg, kind, batch_size, cap)
        for i, kind in enumerate(cfg.rglru.block_pattern)
    }
    groups = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (n_groups,) + leaf.shape), group_state
    )
    cache = {"groups": groups, "t": jnp.int32(seq_len)}
    if rem:
        rs = rglru.init_rglru_state(cfg, batch_size)
        cache["rem"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (rem,) + leaf.shape), rs
        )
    return cache


def hybrid_prefill(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = min(S + PREFILL_HEADROOM, cfg.rglru.local_window)
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = _positions(B, S)

    def group_body2(x, gp):
        states = {}
        for i, kind in enumerate(cfg.rglru.block_pattern):
            unit = gp[f"u{i}"]
            h = common.rmsnorm(unit["ln1"], x, eps=cfg.norm_eps)
            if kind == "recurrent":
                xb = common.dense(unit["rec"]["in_x"], h, cdtype=cfg.cdtype)
                dc = cfg.rglru.conv_width
                conv_tail = xb[:, -(dc - 1) :]
                o, hfin = rglru.rglru_block(unit["rec"], h, cfg)
                x = x + o
                states[f"u{i}"] = {"h": hfin, "conv": conv_tail}
            else:
                o, (k, v) = attention.sliding_window_attention(
                    unit["attn"], h, pos, cfg, window=cfg.rglru.local_window
                )
                x = x + o
                states[f"u{i}"] = attention.fill_cache_from_prefill(
                    attention.init_cache(cfg, B, cap), k, v, S
                )
            hh = common.rmsnorm(unit["ln2"], x, eps=cfg.norm_eps)
            x = x + common.mlp(unit["mlp"], hh, cfg)
        return x, states

    x, groups = jax.lax.scan(group_body2, x, params["groups"])
    cache = {"groups": groups, "t": jnp.int32(S)}
    if "rem" in params:

        def rem_body(x, lp):
            h = common.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
            xb = common.dense(lp["rec"]["in_x"], h, cdtype=cfg.cdtype)
            conv_tail = xb[:, -(cfg.rglru.conv_width - 1) :]
            o, hfin = rglru.rglru_block(lp["rec"], h, cfg)
            x = x + o
            hh = common.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
            x = x + common.mlp(lp["mlp"], hh, cfg)
            return x, {"h": hfin, "conv": conv_tail}

        x, rem_states = jax.lax.scan(rem_body, x, params["rem"])
        cache["rem"] = rem_states
    logits = _logits(params, cfg, x[:, -1:])
    return logits, cache


def _hybrid_unit_decode(unit, kind, x1, state, pos, cfg: ModelConfig):
    h = common.rmsnorm(unit["ln1"], x1, eps=cfg.norm_eps)
    if kind == "recurrent":
        o, st = rglru.rglru_decode_block(unit["rec"], h, state, cfg)
    else:
        o, st = attention.decode_attention(
            unit["attn"], h, state, pos, cfg, window=cfg.rglru.local_window
        )
    x1 = x1 + o
    hh = common.rmsnorm(unit["ln2"], x1, eps=cfg.norm_eps)
    return x1 + common.mlp(unit["mlp"], hh, cfg), st


def hybrid_decode(params, cfg: ModelConfig, cache, tokens):
    x = common.embed(params["embed"], tokens, cdtype=cfg.cdtype)
    pos = cache["t"]

    def group_body(x, inp):
        gp, gstate = inp
        new_states = {}
        for i, kind in enumerate(cfg.rglru.block_pattern):
            x, st = _hybrid_unit_decode(gp[f"u{i}"], kind, x, gstate[f"u{i}"], pos, cfg)
            new_states[f"u{i}"] = st
        return x, new_states

    x, groups = jax.lax.scan(group_body, x, (params["groups"], cache["groups"]))
    new_cache = {"groups": groups, "t": pos + 1}
    if "rem" in params:

        def rem_body(x, inp):
            lp, st = inp
            x, st = _hybrid_unit_decode(lp, "recurrent", x, st, pos, cfg)
            return x, st

        x, rem_states = jax.lax.scan(rem_body, x, (params["rem"], cache["rem"]))
        new_cache["rem"] = rem_states
    x = common.rmsnorm(params["norm"], x, eps=cfg.norm_eps)
    logits = common.dense(params["head"], x, cdtype=cfg.cdtype)
    return logits, new_cache
