"""Uniform model API over every architecture family.

``get_model(cfg)`` returns a ``ModelDef`` with init / loss / prefill /
decode / init_cache / input_specs closures; the FL engine, serving path and
the multi-pod dry-run consume only this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import resnet, stacks


@dataclasses.dataclass(frozen=True)
class ModelDef:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jax.Array]
    prefill: Optional[Callable[[Any, dict], tuple]] = None
    decode: Optional[Callable[[Any, Any, jax.Array], tuple]] = None
    init_cache: Optional[Callable[[int, int], Any]] = None

    def input_specs(self, shape: ShapeConfig, *, batch_override: int = 0) -> dict:
        """ShapeDtypeStruct stand-ins for one global batch of `shape`."""
        return input_specs(self.cfg, shape, batch_override=batch_override)


def _specs_train(cfg: ModelConfig, B: int, S: int) -> dict:
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs = {"tokens": tok, "labels": tok}
    if cfg.family == "audio":
        # stub mel+conv frontend: precomputed frame embeddings; decoder text
        # length S // 8 (audio-to-text compression; DESIGN.md §5)
        dec = max(stacks.CE_CHUNK, S // 8)
        specs = {
            "frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((B, dec), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, dec), jnp.int32),
        }
    elif cfg.family == "vlm":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )
    elif cfg.family == "resnet":
        specs = {
            "images": jax.ShapeDtypeStruct((B, 32, 32, 3), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    return specs


def _specs_prefill(cfg: ModelConfig, B: int, S: int) -> dict:
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        specs = {
            "frame_embeds": jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    elif cfg.family == "vlm":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, batch_override: int = 0) -> dict:
    B = batch_override or shape.global_batch
    if shape.kind == "train":
        return _specs_train(cfg, B, shape.seq_len)
    if shape.kind == "prefill":
        return _specs_prefill(cfg, B, shape.seq_len)
    # decode: ONE new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def get_model(cfg: ModelConfig) -> ModelDef:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelDef(
            cfg,
            init=lambda key: stacks.init_lm(key, cfg),
            loss=lambda p, b: stacks.lm_loss(p, cfg, b),
            prefill=lambda p, b: stacks.lm_prefill(p, cfg, b),
            decode=lambda p, c, t: stacks.lm_decode(p, cfg, c, t),
            init_cache=lambda bs, sl: stacks.lm_init_cache(cfg, bs, sl),
        )
    if fam == "vlm":
        return ModelDef(
            cfg,
            init=lambda key: stacks.init_vlm(key, cfg),
            loss=lambda p, b: stacks.vlm_loss(p, cfg, b),
            prefill=lambda p, b: stacks.vlm_prefill(p, cfg, b),
            decode=lambda p, c, t: stacks.vlm_decode(p, cfg, c, t),
            init_cache=lambda bs, sl: stacks.vlm_init_cache(cfg, bs, sl),
        )
    if fam == "audio":
        return ModelDef(
            cfg,
            init=lambda key: stacks.init_encdec(key, cfg),
            loss=lambda p, b: stacks.encdec_loss(p, cfg, b),
            prefill=lambda p, b: stacks.encdec_prefill(p, cfg, b),
            decode=lambda p, c, t: stacks.encdec_decode(p, cfg, c, t),
            init_cache=lambda bs, sl: stacks.encdec_init_cache(cfg, bs, sl),
        )
    if fam == "ssm":
        return ModelDef(
            cfg,
            init=lambda key: stacks.init_mamba_lm(key, cfg),
            loss=lambda p, b: stacks.mamba_loss(p, cfg, b),
            prefill=lambda p, b: stacks.mamba_prefill(p, cfg, b),
            decode=lambda p, c, t: stacks.mamba_decode(p, cfg, c, t),
            init_cache=lambda bs, sl: stacks.mamba_init_cache(cfg, bs, sl),
        )
    if fam == "hybrid":
        return ModelDef(
            cfg,
            init=lambda key: stacks.init_hybrid(key, cfg),
            loss=lambda p, b: stacks.hybrid_loss(p, cfg, b),
            prefill=lambda p, b: stacks.hybrid_prefill(p, cfg, b),
            decode=lambda p, c, t: stacks.hybrid_decode(p, cfg, c, t),
            init_cache=lambda bs, sl: stacks.hybrid_init_cache(cfg, bs, sl),
        )
    if fam == "resnet":
        return ModelDef(
            cfg,
            init=lambda key: resnet.init_resnet20(key, cfg),
            loss=lambda p, b: resnet.resnet20_loss(p, cfg, b),
        )
    raise ValueError(f"unknown family: {fam!r}")
