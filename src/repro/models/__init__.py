"""Model zoo: pure-pytree functional architectures (dense GQA, MoE, Mamba-1,
RG-LRU hybrid, encoder-decoder audio, VLM cross-attention, ResNet-20)."""
from repro.models.registry import ModelDef, get_model, input_specs

__all__ = ["ModelDef", "get_model", "input_specs"]
