"""Attention: GQA/MHA with RoPE, qk-norm, QKV-bias, sliding-window and
cross-attention variants, plus KV-cache prefill/decode paths.

Sharding notes (see DESIGN.md §4): activations are never sharded on the head
dim — projections shard their fused ``n_heads·head_dim`` output columns over
the ``model`` mesh axis, and decode KV caches shard the *sequence* dim, so no
head-count divisibility constraint ever arises.

Sliding-window training/prefill uses the chunked two-block scheme (each
window-sized chunk attends to itself causally and to the previous chunk with
a distance mask) giving O(S·2W) score memory instead of O(S²).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "q": common.init_dense(ks[0], d, cfg.n_heads * hd, cfg.pdtype, bias=cfg.qkv_bias),
        "k": common.init_dense(ks[1], d, cfg.n_kv * hd, cfg.pdtype, bias=cfg.qkv_bias),
        "v": common.init_dense(ks[2], d, cfg.n_kv * hd, cfg.pdtype, bias=cfg.qkv_bias),
        "o": common.init_dense(ks[3], cfg.n_heads * hd, d, cfg.pdtype),
    }
    if cfg.qk_norm and not cross:
        p["qn"] = common.init_rmsnorm(hd, cfg.pdtype)
        p["kn"] = common.init_rmsnorm(hd, cfg.pdtype)
    return p


def _project_q(p, x, cfg: ModelConfig):
    B, S = x.shape[:2]
    q = common.dense(p["q"], x, cdtype=cfg.cdtype).reshape(B, S, cfg.n_heads, cfg.hd)
    if "qn" in p:
        q = common.rmsnorm(p["qn"], q, eps=cfg.norm_eps)
    return q


def _project_kv(p, x, cfg: ModelConfig):
    B, S = x.shape[:2]
    k = common.dense(p["k"], x, cdtype=cfg.cdtype).reshape(B, S, cfg.n_kv, cfg.hd)
    v = common.dense(p["v"], x, cdtype=cfg.cdtype).reshape(B, S, cfg.n_kv, cfg.hd)
    if "kn" in p:
        k = common.rmsnorm(p["kn"], k, eps=cfg.norm_eps)
    return k, v


def _gqa_scores(q, k, cfg: ModelConfig):
    """q (B,Sq,H,hd), k (B,Sk,Kv,hd) -> scores (B,Kv,G,Sq,Sk) with G=H/Kv."""
    B, Sq, H, hd = q.shape
    G = H // cfg.n_kv
    qg = q.reshape(B, Sq, cfg.n_kv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * (hd**-0.5)
    return s.astype(jnp.float32)


def _gqa_out(scores, v, p, cfg: ModelConfig):
    """scores (B,Kv,G,Sq,Sk) f32 post-softmax, v (B,Sk,Kv,hd) -> (B,Sq,D)."""
    B, Kv, G, Sq, _ = scores.shape
    o = jnp.einsum("bkgqs,bskh->bqkgh", scores.astype(cfg.cdtype), v)
    o = o.reshape(B, Sq, cfg.n_heads * cfg.hd)
    return common.dense(p["o"], o, cdtype=cfg.cdtype)


# Above this sequence length the quadratic score tensor is replaced by the
# blockwise online-softmax path (flash-attention recurrence in pure JAX).
BLOCKWISE_THRESHOLD = 2048
Q_CHUNK = 512
KV_CHUNK = 1024


def blockwise_gqa(q, k, v, *, pos_q, pos_k, causal: bool, window: int,
                  cfg: ModelConfig, q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK):
    """Flash-style attention: nested scans over (q chunks × kv blocks) with the
    online-softmax recurrence — peak score buffer is (B, Kv, G, qc, kc) instead
    of (B, H, S, S).  Supports causal and sliding-window masks; this is the
    TPU-idiomatic replacement for the CUDA fused kernels the source models use.

    q (B,Sq,H,hd) / k,v (B,Sk,Kv,hd) post-RoPE.  Returns (B, Sq, H·hd).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    Kv = cfg.n_kv
    G = H // Kv
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    assert Sq % qc == 0 and Sk % kc == 0, (Sq, qc, Sk, kc)
    nq, nk = Sq // qc, Sk // kc
    scale = hd**-0.5

    from repro.sharding.hints import hint

    # Pin a stable layout for the whole nested scan (see sharding/hints.py):
    # batch → client axes; the q-chunk dim → "model" (sequence-parallel
    # attention); K/V blocks replicated over "model".  Without this GSPMD
    # re-shards every (layer × q-chunk × kv-block) iteration.
    qr = jnp.moveaxis(q.reshape(B, nq, qc, Kv, G, hd), 1, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, Kv, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, Kv, hd), 1, 0)
    pq = jnp.moveaxis(pos_q.reshape(B, nq, qc), 1, 0)
    pk = jnp.moveaxis(pos_k.reshape(B, nk, kc), 1, 0)
    qr = hint(qr, None, "batch", "qchunk", None, None, None)
    kr = hint(kr, None, "batch", None, None, None)
    vr = hint(vr, None, "batch", None, None, None)
    pq = hint(pq, None, "batch", "qchunk")

    def q_chunk_body(_, q_in):
        q_blk, pq_blk = q_in  # (B,qc,Kv,G,hd), (B,qc)
        m0 = hint(jnp.full((B, Kv, G, qc), -1e30, jnp.float32),
                  "batch", None, None, "qchunk")
        l0 = hint(jnp.zeros((B, Kv, G, qc), jnp.float32),
                  "batch", None, None, "qchunk")
        a0 = hint(jnp.zeros((B, Kv, G, qc, hd), jnp.float32),
                  "batch", None, None, "qchunk", None)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            k_blk, v_blk, pk_blk = kv_in
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk).astype(jnp.float32) * scale
            valid = jnp.ones((B, 1, 1, qc, kc), bool)
            if causal:
                valid &= pk_blk[:, None, None, None, :] <= pq_blk[:, None, None, :, None]
            if window:
                valid &= pk_blk[:, None, None, None, :] > (
                    pq_blk[:, None, None, :, None] - window
                )
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p_.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kr, vr, pk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)  # (B,Kv,G,qc,hd)

    _, chunks = jax.lax.scan(q_chunk_body, None, (qr, pq))  # (nq,B,Kv,G,qc,hd)
    out = jnp.moveaxis(chunks, 0, 1)  # (B,nq,Kv,G,qc,hd)
    out = jnp.moveaxis(out, 4, 2)     # (B,nq,qc,Kv,G,hd)
    return out.reshape(B, Sq, H * hd)


def full_attention(p, x, positions, cfg: ModelConfig, *, causal: bool = True):
    """Training / prefill path.  Quadratic for short sequences, blockwise
    online-softmax beyond BLOCKWISE_THRESHOLD.  Returns (out, (k, v))."""
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    q = common.apply_rope(q, positions, cfg)
    k = common.apply_rope(k, positions, cfg)
    if x.shape[1] > BLOCKWISE_THRESHOLD:
        o = blockwise_gqa(
            q, k, v, pos_q=positions, pos_k=positions, causal=causal, window=0,
            cfg=cfg,
        )
        return common.dense(p["o"], o, cdtype=cfg.cdtype), (k, v)
    scores = _gqa_scores(q, k, cfg)
    if causal:
        mask = positions[:, None, None, :, None] >= positions[:, None, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(w, v, p, cfg), (k, v)


def sliding_window_attention(p, x, positions, cfg: ModelConfig, *, window: int):
    """Chunked SWA (train/prefill): chunks of size W attend to (prev, self).

    Requires S % W == 0 (launchers pad); exact for row-contiguous positions.
    Returns (out, (k, v)) where k, v cover the full sequence.
    """
    B, S, _ = x.shape
    W = window
    if S <= W:
        out, kv = full_attention(p, x, positions, cfg, causal=True)
        return out, kv
    if S > BLOCKWISE_THRESHOLD:
        # long-sequence path: blockwise online softmax with the window mask
        q = _project_q(p, x, cfg)
        k, v = _project_kv(p, x, cfg)
        q = common.apply_rope(q, positions, cfg)
        k = common.apply_rope(k, positions, cfg)
        o = blockwise_gqa(
            q, k, v, pos_q=positions, pos_k=positions, causal=True, window=W,
            cfg=cfg,
        )
        return common.dense(p["o"], o, cdtype=cfg.cdtype), (k, v)
    if S % W:
        # end-pad to a multiple of W: padded keys sit at later positions than
        # every real query, so the causal chunk mask already excludes them.
        pad = W - S % W
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        # pad value irrelevant: the iota chunk mask already excludes padded
        # keys (they follow every real query within their chunk)
        pp = jnp.pad(positions, ((0, 0), (0, pad)))
        out, (k, v) = sliding_window_attention(p, xp, pp, cfg, window=W)
        return out[:, :S], (k[:, :S], v[:, :S])
    nc = S // W
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    q = common.apply_rope(q, positions, cfg)
    k = common.apply_rope(k, positions, cfg)

    hd, Kv = cfg.hd, cfg.n_kv
    G = cfg.n_heads // Kv
    qc = q.reshape(B, nc, W, cfg.n_heads, hd)
    kc = k.reshape(B, nc, W, Kv, hd)
    vc = v.reshape(B, nc, W, Kv, hd)
    # previous chunk (chunk 0's "previous" is masked out entirely)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kp, kc], axis=2)  # (B, nc, 2W, Kv, hd)
    v2 = jnp.concatenate([vp, vc], axis=2)
    qg = qc.reshape(B, nc, W, Kv, G, hd)
    scores = jnp.einsum("bcqkgh,bcskh->bckgqs", qg, k2).astype(jnp.float32) * (
        hd**-0.5
    )
    i = jax.lax.broadcasted_iota(jnp.int32, (W, 2 * W), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (W, 2 * W), 1)
    # prev half (j < W): valid iff j > i (distance < W); own half: causal j-W <= i
    mask = jnp.where(j < W, j > i, (j - W) <= i)
    first = jax.lax.broadcasted_iota(jnp.int32, (nc, 1, 1), 0) == 0
    mask = mask[None] & (~first | (j[None] >= W))  # chunk 0 has no prev
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bckgqs,bcskh->bcqkgh", w.astype(cfg.cdtype), v2)
    o = o.reshape(B, S, cfg.n_heads * hd)
    return common.dense(p["o"], o, cdtype=cfg.cdtype), (k, v)


def cross_attention(p, x, kv_src_k, kv_src_v, cfg: ModelConfig):
    """Decoder attends to a fixed encoder/vision memory (no mask, no rope)."""
    q = _project_q(p, x, cfg)
    Sq, Sk = x.shape[1], kv_src_k.shape[1]
    if Sq > BLOCKWISE_THRESHOLD and Sq * Sk > BLOCKWISE_THRESHOLD**2:
        B = x.shape[0]
        pos_q = jnp.zeros((B, Sq), jnp.int32)
        # memory length rarely divides KV_CHUNK: pad keys, mask via pos_k = -1
        kc = min(KV_CHUNK, Sk)
        pad = (-Sk) % kc
        kp = jnp.pad(kv_src_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(kv_src_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(
            jnp.zeros((B, Sk), jnp.int32), ((0, 0), (0, pad)), constant_values=1
        )
        o = blockwise_gqa(
            q, kp, vp, pos_q=pos_q, pos_k=pos_k, causal=True, window=0, cfg=cfg
        )  # "causal" here means: mask pos_k(=1 on pads) > pos_q(=0) — pads only
        return common.dense(p["o"], o, cdtype=cfg.cdtype)
    scores = _gqa_scores(q, kv_src_k, cfg)
    w = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(w, kv_src_v, p, cfg)


def project_memory(p, mem, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder/vision memory."""
    return _project_kv(p, mem, cfg)


# --------------------------------------------------------------------------
# KV cache (ring buffer; capacity = min(seq_len, window) for SWA archs)
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv, cfg.hd), cfg.cdtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv, cfg.hd), cfg.cdtype),
        "pos": jnp.full((capacity,), -(2**30), jnp.int32),
    }


def fill_cache_from_prefill(cache, k, v, prefill_len: int):
    """Write the last `capacity` positions of a prefill into the ring.

    The slot layout is statically known and contiguous modulo one wrap, so
    this is at most two ``dynamic_update_slice`` block writes — never an
    index scatter.  (A permutation scatter into the model-axis-sharded cache
    dim lowered to a collective-permute storm: ~46 TB/device on the 32k
    prefill dry-runs.  EXPERIMENTS.md §Perf iteration 1.)
    """
    cap = cache["k"].shape[1]
    take = min(cap, prefill_len)
    start_pos = prefill_len - take
    start_slot = start_pos % cap
    first = min(take, cap - start_slot)  # length before the ring wraps

    def write(buf, vals, slot, *, seq_axis):
        idx = [0] * buf.ndim
        idx[seq_axis] = slot
        return jax.lax.dynamic_update_slice(buf, vals.astype(buf.dtype), tuple(idx))

    kk, vv = k[:, -take:], v[:, -take:]
    pos_vals = jnp.arange(start_pos, prefill_len, dtype=jnp.int32)
    ck, cv, cp = cache["k"], cache["v"], cache["pos"]
    ck = write(ck, kk[:, :first], start_slot, seq_axis=1)
    cv = write(cv, vv[:, :first], start_slot, seq_axis=1)
    cp = write(cp, pos_vals[:first], start_slot, seq_axis=0)
    if first < take:  # wrapped tail goes to slot 0
        ck = write(ck, kk[:, first:], 0, seq_axis=1)
        cv = write(cv, vv[:, first:], 0, seq_axis=1)
        cp = write(cp, pos_vals[first:], 0, seq_axis=0)
    return {"k": ck, "v": cv, "pos": cp}


def decode_attention(p, x1, cache, pos, cfg: ModelConfig, *, window: int = 0):
    """One-token decode.  x1 (B,1,D); pos scalar int32 (next position index).

    Returns (out (B,1,D), new cache).
    """
    B = x1.shape[0]
    cap = cache["k"].shape[1]
    q = _project_q(p, x1, cfg)
    k1, v1 = _project_kv(p, x1, cfg)
    pos_arr = jnp.full((B, 1), pos, jnp.int32)
    q = common.apply_rope(q, pos_arr, cfg)
    k1 = common.apply_rope(k1, pos_arr, cfg)
    slot = pos % cap
    ck = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype), (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
    scores = _gqa_scores(q, ck, cfg)  # (B,Kv,G,1,cap)
    valid = (cpos >= 0) & (cpos <= pos)  # empty slots hold -2**30
    if window:
        valid &= cpos > pos - window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, cv, p, cfg)
    return out, {"k": ck, "v": cv, "pos": cpos}
