"""Logical sharding hints: the *annotation* half of the sharding subsystem.

The subsystem splits three ways.  `launch/mesh.py` builds the meshes (the
physical axis vocabulary: ``clients`` / ``data`` / ``model`` / ``pod``);
`sharding/rules.py` is the *table* — given a pytree family and a mesh it
resolves PartitionSpecs centrally, which works when the caller knows which
family it holds (round batches, the flat delta buffer, a parameter tree).
This module covers the remaining case: tensors born *inside* model code
(attention intermediates, KV blocks) whose layout only the model author
can name.  Model code annotates them with **logical** axis names via
:func:`hint`; a launcher activates a logical→mesh mapping with
:func:`axis_rules`.  With no mapping active every hint is a no-op, so
model code stays mesh-agnostic and single-device tests (and the federated
engines, which never activate a mapping) are untouched.

Motivation (EXPERIMENTS.md §Perf iteration 2): without pinned layouts,
GSPMD resharded the blockwise-attention inner loop every iteration — a
collective-permute storm of ~29 TB/device on grok-1 32k prefill.  Pinning
(batch → client axes, q-chunk → "model") keeps every per-iteration tensor
in one layout: attention parallelizes over query chunks on the model axis
and K/V blocks stay batch-sharded.

Contract details worth knowing (pinned by `tests/test_hints.py`): unknown
or ``None`` logical names mean "no constraint on this dim"; under an
active mapping a rank mismatch between tensor and annotation is an error,
not a silent skip;
mappings nest (inner :func:`axis_rules` wins, restored on exit) because
they ride a `contextvars.ContextVar` — thread- and async-safe for the
prefetcher's worker thread.  See `docs/distributed.md` for where hints sit
relative to the sharded engine's spec-table path.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_rules: contextvars.ContextVar = contextvars.ContextVar("sharding_hints", default=None)


@contextlib.contextmanager
def axis_rules(mesh, mapping: dict):
    """mapping: logical name -> mesh axis (str), tuple of axes, or None."""
    token = _rules.set((mesh, dict(mapping)))
    try:
        yield
    finally:
        _rules.reset(token)


def hint(x, *logical):
    """Constrain ``x`` (rank len(logical)) to the active logical mapping.
    Unknown/None logical names mean 'no constraint on this dim'."""
    active = _rules.get()
    if active is None:
        return x
    mesh, mapping = active
    if x.ndim != len(logical):
        raise ValueError(f"hint rank mismatch: {x.shape} vs {logical}")
    axes = []
    ok = False
    for dim, name in zip(x.shape, logical):
        mapped = mapping.get(name) if name else None
        if mapped is None:
            axes.append(None)
            continue
        parts = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        size = 1
        for a in parts:
            size *= mesh.shape[a]
        if dim % size == 0 and dim >= size:
            axes.append(mapped)
            ok = True
        else:
            axes.append(None)
    if not ok:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))
