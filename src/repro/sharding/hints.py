"""Logical sharding hints: model code annotates tensors with *logical* axis
names; launchers activate a mapping from logical names to mesh axes.  With no
mapping active the hints are no-ops, so model code stays mesh-agnostic and
single-device tests are unaffected.

Motivation (EXPERIMENTS.md §Perf iteration 2): without pinned layouts, GSPMD
resharded the blockwise-attention inner loop every iteration — a
collective-permute storm of ~29 TB/device on grok-1 32k prefill.  Pinning
(batch → client axes, q-chunk → "model") keeps every per-iteration tensor in
one layout: attention parallelizes over query chunks on the model axis and
K/V blocks stay batch-sharded.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_rules: contextvars.ContextVar = contextvars.ContextVar("sharding_hints", default=None)


@contextlib.contextmanager
def axis_rules(mesh, mapping: dict):
    """mapping: logical name -> mesh axis (str), tuple of axes, or None."""
    token = _rules.set((mesh, dict(mapping)))
    try:
        yield
    finally:
        _rules.reset(token)


def hint(x, *logical):
    """Constrain ``x`` (rank len(logical)) to the active logical mapping.
    Unknown/None logical names mean 'no constraint on this dim'."""
    active = _rules.get()
    if active is None:
        return x
    mesh, mapping = active
    if x.ndim != len(logical):
        raise ValueError(f"hint rank mismatch: {x.shape} vs {logical}")
    axes = []
    ok = False
    for dim, name in zip(x.shape, logical):
        mapped = mapping.get(name) if name else None
        if mapped is None:
            axes.append(None)
            continue
        parts = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        size = 1
        for a in parts:
            size *= mesh.shape[a]
        if dim % size == 0 and dim >= size:
            axes.append(mapped)
            ok = True
        else:
            axes.append(None)
    if not ok:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))
