"""Sharding rules: map parameter/batch/cache pytrees to PartitionSpecs.

Scheme (DESIGN.md §4):
  * weights: largest divisible dim → "model"; in ``fsdp_tp`` mode a second
    divisible dim → "data" (ZeRO-3-style storage sharding, gathered by GSPMD
    at use).  Stacked-layer leading dims (under blocks/groups/rem/enc_blocks)
    are never sharded.
  * train batches (n_clients, T, b, ...): client dim → client axes
    ("data" or ("pod","data")).
  * serve batches (B, ...): batch dim → client axes; KV caches shard batch →
    client axes and the cache-sequence dim → "model" (avoids every head-count
    divisibility issue; GQA kv ∈ {1,2,8} never divides 16).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

STACK_KEYS = ("blocks", "groups", "rem", "enc_blocks", "selfs")


def client_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_has_stack(path) -> bool:
    return any(getattr(p, "key", None) in STACK_KEYS for p in path)


def _param_spec(path, leaf, mesh, mode: str):
    model_n = mesh.shape["model"]
    data_n = mesh.shape["data"]
    skip = 1 if _path_has_stack(path) else 0
    # VLM group-stacks are two deep (groups, selfs): skip every stack dim
    n_stack = sum(1 for p in path if getattr(p, "key", None) in STACK_KEYS)
    skip = n_stack
    dims = list(leaf.shape)
    spec = [None] * len(dims)
    # choose the model-sharded dim: largest dim (idx >= skip) divisible by model_n
    cands = [
        (size, i) for i, size in enumerate(dims)
        if i >= skip and size % model_n == 0 and size >= model_n
    ]
    if cands:
        _, mi = max(cands)
        spec[mi] = "model"
        if mode == "fsdp_tp":
            cands2 = [
                (size, i) for i, size in enumerate(dims)
                if i >= skip and i != mi and size % data_n == 0 and size >= data_n
            ]
            if cands2:
                _, di = max(cands2)
                spec[di] = "data"
    return P(*spec)


def param_specs(params, mesh, mode: str = "tp"):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_param_spec(path, leaf, mesh, mode) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def train_batch_specs(batch, mesh):
    """Round batches: leaves (n_clients, T, b, ...) — client dim sharded."""
    ca = client_axes(mesh)
    return jax.tree.map(lambda leaf: P(ca, *([None] * (leaf.ndim - 1))), batch)


def serve_batch_specs(batch, mesh):
    ca = client_axes(mesh)
    ca_size = 1
    for a in ca:
        ca_size *= mesh.shape[a]

    def spec(leaf):
        if leaf.ndim and leaf.shape[0] % ca_size == 0 and leaf.shape[0] >= ca_size:
            return P(ca, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))  # e.g. long_500k: global_batch = 1

    return jax.tree.map(spec, batch)


def cache_specs(cache, mesh, batch_size: int):
    """KV caches / SSM states with leading stacked-layer dims.

    The batch dim is identified by exact size match against `batch_size`
    (caches mix layer-stack, capacity, head and state dims — size matching is
    the only robust rule).  Batch → client axes; then the largest remaining
    divisible dim (cache sequence / d_inner / memory length) → "model";
    ``pos`` ring buffers shard their capacity dim over "model" to stay
    aligned with the k/v leaves.
    """
    ca = client_axes(mesh)
    model_n = mesh.shape["model"]
    ca_size = 1
    for a in ca:
        ca_size *= mesh.shape[a]

    def spec(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        if leaf.ndim == 0:
            return P()
        s = [None] * leaf.ndim
        if "pos" in keys:  # (L[, G], cap): no batch dim
            if leaf.shape[-1] % model_n == 0:
                s[-1] = "model"
            return P(*s)
        bi = None
        if batch_size % ca_size == 0:
            for i, size in enumerate(leaf.shape):
                if size == batch_size:
                    bi = i
                    break
        if bi is not None:
            s[bi] = ca
        cands = [
            (size, i) for i, size in enumerate(leaf.shape)
            if i != bi and size % model_n == 0 and size >= model_n
            # leading layer-stack dims sit before the batch dim: never shard
            # them (caches always carry a stacked-layer dim 0)
            and (i > bi if bi is not None else i >= 1)
        ]
        if cands:
            _, mi = max(cands)
            s[mi] = "model"
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
