"""Sharding rules: the one place pytree structure meets mesh axes.

Every distributed entry point — the GSPMD mesh step, the `shard_map`
client-sharded engine (`repro.fl.distributed.build_sharded_scan_round_step`),
the serving path — resolves its PartitionSpecs here, so "which dim lives on
which axis" is a table, not a convention scattered across call sites.  The
rules, by pytree family:

* **weights** (:func:`param_specs`): largest divisible dim → ``"model"``;
  in ``fsdp_tp`` mode a second divisible dim → ``"data"`` (ZeRO-3-style
  storage sharding, gathered by GSPMD at use).  Stacked-layer leading dims
  (under ``blocks``/``groups``/``rem``/``enc_blocks``/``selfs``) are never
  sharded.  In the federated engines the *parameters stay replicated* —
  every client starts each round from the same global model — so these
  specs serve the model-zoo serving path and the D-axis increment mode.
* **train batches** (:func:`train_batch_specs`): leaves
  ``(n_clients, T, b, ...)`` — the client dim → client axes (``"data"`` or
  ``("pod","data")`` on the production mesh, :func:`client_axes`).
* **round-stacked train batches** (:func:`round_batch_specs`): the scan
  engines stack a whole epoch, leaves ``(R, n_clients, T, b, ...)`` — dim 1
  (clients) → the mesh's client axis, everything else replicated.  This is
  the spec the sharded engine's prefetcher uses to ``device_put`` each
  staged chunk directly into its sharded layout (no gather-then-scatter).
* **the raveled (n, D) delta buffer** (:func:`flat_buffer_specs`): the
  relay hot spot.  Clients-axis mode shards dim 0 (handled by `shard_map`,
  not a spec); D-axis mode constrains dim 1 → ``"model"`` so GSPMD
  partitions the ``(n,n)·(n,D)`` contraction over parameters — the mode for
  models too large to replicate (ROADMAP item 1's D = 10⁷ sweep).
* **serve batches / KV caches** (:func:`serve_batch_specs`,
  :func:`cache_specs`): batch dim → client axes; caches additionally shard
  the cache-sequence dim → ``"model"`` (avoids every head-count
  divisibility issue; GQA kv ∈ {1,2,8} never divides 16).

:func:`to_shardings` turns any spec tree into `NamedSharding`s for a
concrete mesh.  Rule resolution is pure shape arithmetic — no device state
is touched, so the rules are unit-testable on any host
(`tests/test_sharding_rules.py`).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

STACK_KEYS = ("blocks", "groups", "rem", "enc_blocks", "selfs")


def client_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_has_stack(path) -> bool:
    return any(getattr(p, "key", None) in STACK_KEYS for p in path)


def _param_spec(path, leaf, mesh, mode: str):
    model_n = mesh.shape["model"]
    data_n = mesh.shape["data"]
    skip = 1 if _path_has_stack(path) else 0
    # VLM group-stacks are two deep (groups, selfs): skip every stack dim
    n_stack = sum(1 for p in path if getattr(p, "key", None) in STACK_KEYS)
    skip = n_stack
    dims = list(leaf.shape)
    spec = [None] * len(dims)
    # choose the model-sharded dim: largest dim (idx >= skip) divisible by model_n
    cands = [
        (size, i) for i, size in enumerate(dims)
        if i >= skip and size % model_n == 0 and size >= model_n
    ]
    if cands:
        _, mi = max(cands)
        spec[mi] = "model"
        if mode == "fsdp_tp":
            cands2 = [
                (size, i) for i, size in enumerate(dims)
                if i >= skip and i != mi and size % data_n == 0 and size >= data_n
            ]
            if cands2:
                _, di = max(cands2)
                spec[di] = "data"
    return P(*spec)


def param_specs(params, mesh, mode: str = "tp"):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_param_spec(path, leaf, mesh, mode) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def train_batch_specs(batch, mesh):
    """Round batches: leaves (n_clients, T, b, ...) — client dim sharded."""
    ca = client_axes(mesh)
    return jax.tree.map(lambda leaf: P(ca, *([None] * (leaf.ndim - 1))), batch)


def shard_axis(mesh) -> str:
    """The client-shard axis of a mesh: ``"clients"`` on a client mesh
    (`launch.mesh.make_client_mesh`), else the first client axis of the
    production mesh layout."""
    return "clients" if "clients" in mesh.axis_names else client_axes(mesh)[0]


def round_batch_specs(batch, mesh):
    """Epoch-stacked round batches: leaves (R, n_clients, T, b, ...) —
    dim 1 (the client dim) sharded over the mesh's client axis, the round
    dim and everything per-client replicated.  This is the staging layout
    of the sharded engine: `SegmentPrefetcher` device_puts each chunk with
    these specs so every device receives exactly its clients' bytes."""
    ax = shard_axis(mesh)
    return jax.tree.map(
        lambda leaf: P(None, ax, *([None] * (leaf.ndim - 2))), batch
    )


def flat_buffer_specs(mesh, *, n: int | None = None, d: int | None = None):
    """PartitionSpec of the raveled (n, D) delta buffer in D-axis mode:
    dim 1 → "model" when D divides the model-axis size (else fully
    replicated — a constraint that does not divide is worse than none).
    ``n``/``d`` are the buffer dims when known; d=None defers the
    divisibility check to GSPMD (the constraint is still well-formed)."""
    model_n = mesh.shape.get("model", 1)
    if model_n <= 1:
        return P(None, None)
    if d is not None and (d % model_n != 0 or d < model_n):
        return P(None, None)
    return P(None, "model")


def serve_batch_specs(batch, mesh):
    ca = client_axes(mesh)
    ca_size = 1
    for a in ca:
        ca_size *= mesh.shape[a]

    def spec(leaf):
        if leaf.ndim and leaf.shape[0] % ca_size == 0 and leaf.shape[0] >= ca_size:
            return P(ca, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))  # e.g. long_500k: global_batch = 1

    return jax.tree.map(spec, batch)


def cache_specs(cache, mesh, batch_size: int):
    """KV caches / SSM states with leading stacked-layer dims.

    The batch dim is identified by exact size match against `batch_size`
    (caches mix layer-stack, capacity, head and state dims — size matching is
    the only robust rule).  Batch → client axes; then the largest remaining
    divisible dim (cache sequence / d_inner / memory length) → "model";
    ``pos`` ring buffers shard their capacity dim over "model" to stay
    aligned with the k/v leaves.
    """
    ca = client_axes(mesh)
    model_n = mesh.shape["model"]
    ca_size = 1
    for a in ca:
        ca_size *= mesh.shape[a]

    def spec(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        if leaf.ndim == 0:
            return P()
        s = [None] * leaf.ndim
        if "pos" in keys:  # (L[, G], cap): no batch dim
            if leaf.shape[-1] % model_n == 0:
                s[-1] = "model"
            return P(*s)
        bi = None
        if batch_size % ca_size == 0:
            for i, size in enumerate(leaf.shape):
                if size == batch_size:
                    bi = i
                    break
        if bi is not None:
            s[bi] = ca
        cands = [
            (size, i) for i, size in enumerate(leaf.shape)
            if i != bi and size % model_n == 0 and size >= model_n
            # leading layer-stack dims sit before the batch dim: never shard
            # them (caches always carry a stacked-layer dim 0)
            and (i > bi if bi is not None else i >= 1)
        ]
        if cands:
            _, mi = max(cands)
            s[mi] = "model"
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
