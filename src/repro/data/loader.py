"""Federated batch sampling: stacked per-client batches for the FL engine.

A round batch has leaves shaped (n_clients, T, local_batch, ...) — T local
steps per round, one minibatch each — matching ``fl.simulator`` /
``fl.distributed`` expectations.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import ArrayDataset


class FederatedLoader:
    def __init__(self, ds: ArrayDataset, parts: list[np.ndarray], *, seed: int = 0):
        self.ds = ds
        self.parts = parts
        self.rng = np.random.default_rng(seed)

    @property
    def n_clients(self) -> int:
        return len(self.parts)

    def round_batch(self, local_steps: int, local_batch: int, *, lm: bool = False):
        """Sample (n, T, b, ...) input/label arrays for one round."""
        n = self.n_clients
        xs, ys = [], []
        for part in self.parts:
            idx = self.rng.choice(part, size=(local_steps, local_batch), replace=True)
            xs.append(self.ds.inputs[idx])
            ys.append(self.ds.labels[idx])
        x = np.stack(xs)  # (n, T, b, ...)
        y = np.stack(ys)
        if lm:
            # inputs are (.., seq+1) token arrays: split into tokens/labels
            return {"tokens": x[..., :-1], "labels": x[..., 1:]}
        key = "images" if x.ndim >= 5 else "inputs"
        return {key: x, "labels": y}
