"""Federated batch sampling: stacked per-client batches for the FL engine.

A round batch has leaves shaped (n_clients, T, local_batch, ...) — T local
steps per round, one minibatch each — matching ``fl.simulator`` /
``fl.distributed`` expectations.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import ArrayDataset

# Below this many clients the per-client ``rng.choice`` loop is cheap and its
# RNG stream is pinned by existing trajectories/tests; at cohort-sampling
# scale (n ≳ 10³) the loop itself dominates staging, so ``round_batch``
# switches to one vectorized gather (different stream, same distribution).
VECTORIZED_MIN_CLIENTS = 256


class FederatedLoader:
    """``vectorized`` ∈ {None, True, False}: None (default) auto-enables the
    single-gather sampling path when ``n_clients >= VECTORIZED_MIN_CLIENTS``
    and every partition has equal size; True/False force it.  The vectorized
    path draws all ``n·T·b`` sample indices with one ``rng.integers`` call
    and gathers the dataset once — its RNG stream differs from the loop
    path's (one ``choice`` per client), which is why small-n defaults keep
    the historical stream."""

    def __init__(
        self,
        ds: ArrayDataset,
        parts: list[np.ndarray],
        *,
        seed: int = 0,
        vectorized: bool | None = None,
    ):
        self.ds = ds
        self.parts = parts
        self.rng = np.random.default_rng(seed)
        sizes = {len(p) for p in parts}
        equal = len(sizes) == 1
        if vectorized is None:
            vectorized = equal and len(parts) >= VECTORIZED_MIN_CLIENTS
        elif vectorized and not equal:
            raise ValueError(
                "vectorized sampling needs equal-size partitions "
                f"(got sizes {sorted(sizes)})"
            )
        self.vectorized = bool(vectorized)
        # (n, m) partition matrix: row i lists client i's dataset indices
        self._part_mat = (
            np.stack([np.asarray(p) for p in parts]) if self.vectorized else None
        )

    @property
    def n_clients(self) -> int:
        return len(self.parts)

    def round_batch(self, local_steps: int, local_batch: int, *, lm: bool = False):
        """Sample (n, T, b, ...) input/label arrays for one round."""
        n = self.n_clients
        if self.vectorized:
            mat = self._part_mat
            r = self.rng.integers(
                0, mat.shape[1], size=(n, local_steps, local_batch)
            )
            idx = np.take_along_axis(mat[:, None, :], r, axis=2)  # (n, T, b)
            x = self.ds.inputs[idx]
            y = self.ds.labels[idx]
        else:
            xs, ys = [], []
            for part in self.parts:
                idx = self.rng.choice(
                    part, size=(local_steps, local_batch), replace=True
                )
                xs.append(self.ds.inputs[idx])
                ys.append(self.ds.labels[idx])
            x = np.stack(xs)  # (n, T, b, ...)
            y = np.stack(ys)
        if lm:
            # inputs are (.., seq+1) token arrays: split into tokens/labels
            return {"tokens": x[..., :-1], "labels": x[..., 1:]}
        key = "images" if x.ndim >= 5 else "inputs"
        return {key: x, "labels": y}
