"""Client data partitioning: IID and the paper's sort-and-partition non-IID.

Paper §V: "the training data is initially sorted based on labels, and then
divided into blocks and distributed among clients in a skewed fashion so that
each client has data from only a few classes."
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import ArrayDataset


def iid_partition(ds: ArrayDataset, n_clients: int, *, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


def sort_and_partition(
    ds: ArrayDataset, n_clients: int, *, shards_per_client: int = 2, seed: int = 0
) -> list[np.ndarray]:
    """Sort by label, cut into n_clients·shards_per_client blocks, deal
    `shards_per_client` random blocks to each client."""
    rng = np.random.default_rng(seed)
    order = np.argsort(ds.labels, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    return [
        np.sort(np.concatenate([shards[perm[i * shards_per_client + j]]
                                for j in range(shards_per_client)]))
        for i in range(n_clients)
    ]


def client_label_histogram(ds: ArrayDataset, parts: list[np.ndarray], n_classes: int):
    return np.stack(
        [np.bincount(ds.labels[p], minlength=n_classes) for p in parts]
    )
