"""Synthetic datasets (the container is offline — DESIGN.md §8).

* ``cifar_like``: 10-class Gaussian-prototype images, CIFAR-shaped
  (32×32×3).  Linearly separable at high SNR, genuinely learnable by the
  ResNet/MLP models, and class structure makes the paper's sort-and-partition
  non-IID pathology reproducible.
* ``lm_tokens``: affine-recurrence token streams  t_{k+1} = (a·t_k + b) mod V
  with per-stream (a, b) and noise — next-token prediction is learnable and
  per-client (a, b) skew provides non-IID-ness for LM FL experiments.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ArrayDataset:
    """In-memory dataset; leaves indexed along axis 0."""
    inputs: np.ndarray
    labels: np.ndarray

    def __len__(self) -> int:
        return int(self.labels.shape[0])


def cifar_like(
    n: int, *, n_classes: int = 10, snr: float = 2.0, seed: int = 0,
    proto_seed: int = 12345,
) -> ArrayDataset:
    """``proto_seed`` fixes the class prototypes (the *task*); ``seed`` draws
    the samples — so train/test splits share the task but not the noise."""
    protos = np.random.default_rng(proto_seed).normal(
        size=(n_classes, 32, 32, 3)).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=(n,))
    noise = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
    images = snr * protos[labels] + noise
    images /= np.sqrt(1.0 + snr**2)
    return ArrayDataset(images.astype(np.float32), labels.astype(np.int32))


def gaussian_classification(
    n: int, *, dim: int = 64, n_classes: int = 10, snr: float = 2.0, seed: int = 0,
    proto_seed: int = 12345,
) -> ArrayDataset:
    """Flat-feature variant for MLP / logistic-regression experiments."""
    protos = np.random.default_rng(proto_seed).normal(
        size=(n_classes, dim)).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=(n,))
    x = snr * protos[labels] + rng.normal(size=(n, dim)).astype(np.float32)
    x /= np.sqrt(1.0 + snr**2)
    return ArrayDataset(x.astype(np.float32), labels.astype(np.int32))


def lm_tokens(
    n_seqs: int, seq_len: int, *, vocab: int = 512, n_streams: int = 8,
    noise: float = 0.05, seed: int = 0
) -> ArrayDataset:
    """Token sequences; labels are next tokens (shift by one).

    ``labels[i] = stream id`` so the same partition machinery (IID vs
    sort-and-partition) applies to LM data as to classification data.
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(1, vocab - 1, size=(n_streams,)) | 1  # odd → full cycle-ish
    b = rng.integers(0, vocab, size=(n_streams,))
    stream = rng.integers(0, n_streams, size=(n_seqs,))
    toks = np.empty((n_seqs, seq_len + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=(n_seqs,))
    for k in range(seq_len):
        nxt = (a[stream] * toks[:, k] + b[stream]) % vocab
        flip = rng.random(n_seqs) < noise
        nxt = np.where(flip, rng.integers(0, vocab, size=(n_seqs,)), nxt)
        toks[:, k + 1] = nxt
    return ArrayDataset(toks.astype(np.int32), stream.astype(np.int32))


def quadratic_problem(dim: int, n_clients: int, *, hetero: float = 1.0, seed: int = 0):
    """Strongly-convex quadratic ERM where Thm. 1 assumptions hold exactly.

    Client i's loss:  f_i(x) = 0.5 (x - c_i)ᵀ H (x - c_i),  H ≻ 0 shared.
    Global optimum x* = mean(c_i).  Returns (H, centers, x_star).
    """
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    eig = np.linspace(1.0, 10.0, dim)  # μ = 1, L = 10
    H = (q * eig) @ q.T
    centers = hetero * rng.normal(size=(n_clients, dim))
    return H.astype(np.float32), centers.astype(np.float32), centers.mean(0).astype(np.float32)
