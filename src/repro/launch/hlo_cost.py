"""Loop-aware cost model over compiled (partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, so any
``lax.scan`` (layer stacks, CE chunks, blockwise attention) under-reports
FLOPs / bytes / collective traffic by its trip count.  This module re-derives
per-device costs from ``compiled.as_text()`` with loop multiplicity:

  * computations are parsed into top-level op lines (fusion bodies stay
    internal — their operands/results are the HBM-visible traffic);
  * ``while`` trip counts are inferred from the loop-carried tuple: scanned
    inputs/outputs are stacked arrays whose leading dim is the trip count
    (the most common leading dim ≥ 2 across rank-≥2 tuple elements);
  * costs roll up recursively: while bodies × trip, call/conditional × 1.

FLOPs are counted for dot/convolution ops (2 · |out| · K); HBM bytes as
operand + result bytes of top-level non-trivial ops; collective bytes by kind
from the op result size.  All numbers are per-device (the partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE = re.compile(r"\b(\w+)\[([0-9,]*)\]")
_OPLINE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$")
_CALLED_SINGLE = re.compile(r"(?:body|condition|to_apply)=%?([\w.\-]+)")
_CALLED_LIST = re.compile(r"(?:branch_computations|called_computations|calls)=\{([^}]*)\}")
_KIND = re.compile(r"^(?:\([^)]*\)|\w+\[[^\]]*\]\S*)\s+([\w\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops whose result/operands we exclude from HBM traffic accounting
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "copy-start", "copy-done", "iota",
}


def _shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    kind: str
    line: str
    called: list[str]


def parse_computations(text: str, comp_text: dict[str, str] | None = None
                       ) -> dict[str, list[OpInfo]]:
    """name -> top-level op lines.  Computations start at column 0 with
    ``%name (...`` or ``ENTRY``; ops are indented lines containing ``=``.
    If ``comp_text`` is given it is filled with name -> raw body text."""
    comps: dict[str, list[OpInfo]] = {}
    current = None
    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                current = m.group(1)
                comps[current] = []
                if comp_text is not None:
                    comp_text[current] = ""
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
            continue
        if current is None:
            continue
        if comp_text is not None:
            comp_text[current] = comp_text[current] + line + "\n"
        if "=" not in line:
            continue
        m = _OPLINE.match(line)
        if not m:
            continue
        rhs = m.group(1)
        km = _KIND.match(rhs)
        if not km:
            continue
        kind = km.group(1)
        called = [c for c in _CALLED_SINGLE.findall(rhs)]
        for cm in _CALLED_LIST.finditer(rhs):
            called += [c.strip().lstrip("%") for c in cm.group(1).split(",") if c.strip()]
        comps[current].append(OpInfo(kind, rhs, called))
    return comps


_CONST_INT = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_COMPARE = re.compile(r"compare\(([^)]*)\).*direction=(LT|LE|GT|GE)")


def _trip_from_condition(cond_name: str, comp_text: dict[str, str]) -> int | None:
    """Trip count from the condition cluster (the compare may be fused): the
    loop bound is the largest s32[] constant in the condition computation or
    the computations it calls (jax scans: compare(i, constant(trip), LT))."""
    text = comp_text.get(cond_name)
    if text is None:
        return None
    cluster = [text]
    for m in _CALLED_LIST.finditer(text):
        for c in m.group(1).split(","):
            c = c.strip().lstrip("%")
            if c in comp_text:
                cluster.append(comp_text[c])
    for m in _CALLED_SINGLE.finditer(text):
        if m.group(1) in comp_text:
            cluster.append(comp_text[m.group(1)])
    consts = [int(m.group(2)) for t in cluster for m in _CONST_INT.finditer(t)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else None


def _while_trip_count(op: OpInfo, comp_text: dict[str, str] | None = None) -> int:
    """Trip count: prefer the condition's compare constant; fall back to the
    most common stacked-operand leading dim in the loop tuple."""
    if comp_text is not None:
        for c in op.called:
            t = _trip_from_condition(c, comp_text)
            if t is not None and t > 0:
                return t
    head = op.line.split(" while(")[0]
    lead = Counter()
    for _, dims in _shapes(head):
        if len(dims) >= 2 and dims[0] > 1:
            lead[dims[0]] += 1
    if not lead:
        return 1
    return lead.most_common(1)[0][0]


_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+\[[0-9,]*\])")


def build_def_shapes(text: str) -> dict[str, list]:
    """Global map op-name -> (dtype, dims) from every definition line."""
    defs: dict[str, list] = {}
    for line in text.splitlines():
        m = _DEF.match(line)
        if m:
            s = _shapes(m.group(2))
            if s:
                defs[m.group(1)] = s[0]
    return defs


def _dot_flops(op: OpInfo, defs: dict) -> float:
    out_b = _shapes(op.line.split(" dot(")[0])
    if not out_b:
        return 0.0
    out_elems = 1
    for d in out_b[0][1]:
        out_elems *= d
    inner = op.line.split(" dot(", 1)[1]
    m = _OPERANDS.match("(" + inner)
    lhs_dims = None
    if m:
        # Operands usually carry their shape inline ("f32[32,64]{1,0} %x");
        # the first shape in the operand list is the lhs.  Fall back to the
        # global def map for bare-name operands.
        op_shapes = _shapes(m.group(1))
        if op_shapes:
            lhs_dims = op_shapes[0][1]
        else:
            first = m.group(1).split(",")[0].strip()
            name = first.split()[-1].lstrip("%") if first else ""
            if name in defs:
                lhs_dims = defs[name][1]
    cdims = _CONTRACT.search(op.line)
    k = 1
    if cdims and lhs_dims is not None:
        for i in cdims.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def _conv_flops(op: OpInfo) -> float:
    # approximate: 2 * |out| * (kernel spatial * in_channels)
    parts = op.line.split(" convolution(", 1)
    out_s = _shapes(parts[0])
    ops = _shapes(parts[1].split("),")[0]) if len(parts) > 1 else []
    if not out_s or len(ops) < 2:
        return 0.0
    out_elems = 1
    for d in out_s[0][1]:
        out_elems *= d
    kdims = ops[1][1]
    k = 1
    for d in kdims[:-1]:  # all but output-feature dim (HWIO heuristic)
        k *= d
    return 2.0 * out_elems * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v


def analyze(text: str) -> dict:
    comp_text: dict[str, str] = {}
    comps = parse_computations(text, comp_text)
    defs = build_def_shapes(text)
    memo: dict[str, Cost] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        total = Cost()
        for op in comps[name]:
            own = Cost()
            if op.kind == "dot":
                own.flops = _dot_flops(op, defs)
            elif op.kind == "convolution":
                own.flops = _conv_flops(op)
            if op.kind in COLLECTIVES:
                head = op.line.split(f" {op.kind}(")[0]
                own.collectives[op.kind] = float(_bytes_of(_shapes(head)))
            if op.kind not in _SKIP_BYTES:
                own.hbm_bytes = float(_bytes_of(_shapes(op.line)))
            mult = 1.0
            sub = Cost()
            if op.kind == "while":
                mult = float(_while_trip_count(op, comp_text))
                for c in op.called:
                    sub.add(cost_of(c, stack + (name,)))
            elif op.called:
                for c in op.called:
                    sub.add(cost_of(c, stack + (name,)))
            total.add(own)
            total.add(sub, mult)
        memo[name] = total
        return total

    entry = cost_of("__entry__")
    coll_total = float(sum(entry.collectives.values()))
    return {
        "flops": entry.flops,
        "hbm_bytes": entry.hbm_bytes,
        "collectives": {**entry.collectives, "total": coll_total},
    }
