import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Attribute per-device collective bytes to jax source operations.

The §Perf workflow's diagnosis step: compiles one (arch × shape) combo and
groups loop-corrected collective bytes by HLO ``op_name`` metadata (which
carries the jax trace path, e.g. ``.../bqkgh,bskh->bkgqs/dot_general``), so
a collective-permute storm can be pinned to the exact einsum that caused it.

  PYTHONPATH=src python -m repro.launch.attribute --arch grok-1-314b \
      --shape prefill_32k [--multi-pod] [--relay-mode fused] [--top 15]
"""

import argparse
import re
from collections import defaultdict

from repro.configs.base import INPUT_SHAPES
from repro.launch import dryrun as dr
from repro.launch import hlo_cost

_OPNAME = re.compile(r'op_name="([^"]*)"')


def attribute(hlo_text: str) -> dict:
    """(collective kind, op_name prefix) -> loop-corrected bytes/device."""
    comp_text: dict[str, str] = {}
    comps = hlo_cost.parse_computations(hlo_text, comp_text)

    mult: dict[str, float] = defaultdict(float)

    def walk(name, m, stack=()):
        if name in stack or name not in comps:
            return
        mult[name] += m
        for op in comps[name]:
            if op.kind == "while":
                t = hlo_cost._while_trip_count(op, comp_text)
                for c in op.called:
                    walk(c, m * t, stack + (name,))
            elif op.called:
                for c in op.called:
                    walk(c, m, stack + (name,))

    walk("__entry__", 1.0)
    out: dict = defaultdict(float)
    for name, ops in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in ops:
            if op.kind in hlo_cost.COLLECTIVES:
                head = op.line.split(f" {op.kind}(")[0]
                b = hlo_cost._bytes_of(hlo_cost._shapes(head)) * m
                mm = _OPNAME.search(op.line)
                out[(op.kind, mm.group(1)[:120] if mm else "?")] += b
    return dict(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--relay-mode", default="faithful")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    mesh = dr.make_production_mesh(multi_pod=args.multi_pod)
    if INPUT_SHAPES[args.shape].kind == "train":
        lowered, _, _ = dr.build_train_lowering(
            args.arch, args.shape, mesh, args.relay_mode)
    else:
        lowered, _, _ = dr.build_serve_lowering(args.arch, args.shape, mesh)
    attr = attribute(lowered.compile().as_text())
    for (kind, src), b in sorted(attr.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{b / 1e9:10.1f} GB  {kind:20s} {src}")


if __name__ == "__main__":
    main()
