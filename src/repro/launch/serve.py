"""Serving launcher: batched prefill + decode over the model zoo.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 16

Loads (or random-inits) a model, prefills the prompt batch, then greedy-
decodes with the KV cache / SSM state machinery — the same serve_step the
dry-run lowers at production shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import registry as creg
from repro.models import registry as mreg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=sorted(creg.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--restore", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = creg.get_config(args.arch, reduced=args.reduced)
    if cfg.family == "resnet":
        raise SystemExit("resnet20 is a classifier; nothing to decode")
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(args.seed))
    if args.restore:
        params = checkpoint.restore(args.restore, params)

    B, S = args.batch, args.prompt_len
    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model))

    prefill = jax.jit(md.prefill)
    decode = jax.jit(md.decode)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t1 = time.time()
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    outs = [np.asarray(toks)]
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t2 = time.time()
    gen = np.concatenate(outs, axis=1)
    print(f"prefill: {B}x{S} in {t1-t0:.2f}s; "
          f"decode: {args.new_tokens} tokens in {t2-t1:.2f}s "
          f"({B*args.new_tokens/(t2-t1):.1f} tok/s batch-aggregate)")
    for b in range(min(B, 4)):
        print(f"  request {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
