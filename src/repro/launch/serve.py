"""Serving launcher: batched prefill + decode over the model zoo, plus a
snapshot-watching eval loop for the continuous-training service.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 16

    # live eval against a training run publishing into checkpoints/
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
        --watch checkpoints --max-polls 30

One-shot mode loads (or random-inits) a model, prefills the prompt batch,
then greedy-decodes with the KV cache / SSM state machinery — the same
serve_step the dry-run lowers at production shapes.

Watch mode (:class:`SnapshotEvalLoop`) polls the ``LATEST`` pointer the
trainer rotates (``repro.checkpoint.publish``); whenever it names a new
snapshot the loop reloads just the params (the server-optimizer state and
RNG key in the snapshot are ignored — eval only needs the model) and runs
the eval function against a fixed held-out batch, giving a live
loss-vs-round readout of the run in progress.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint


class SnapshotEvalLoop:
    """Poll a checkpoint directory's ``LATEST`` pointer and evaluate each
    new snapshot.

    ``params_like`` gives the pytree structure to restore into (eval-only:
    extra snapshot entries like the server state are ignored).  ``eval_fn``
    maps ``(params, batch) -> scalar loss``.  :meth:`poll` reloads iff the
    pointer changed and returns True on reload; :meth:`eval_batch` scores a
    batch against the currently-loaded params; :meth:`watch` packages the
    poll/eval/sleep cycle.
    """

    def __init__(self, ckpt_dir: str, *, params_like, eval_fn=None):
        self.ckpt_dir = ckpt_dir
        self.params_like = params_like
        self.eval_fn = eval_fn
        self.params = None
        self.round: int | None = None
        self._seen: str | None = None

    def poll(self) -> bool:
        """Reload params iff the ``LATEST`` pointer names a new snapshot."""
        path = checkpoint.latest_checkpoint(self.ckpt_dir)
        if path is None or path == self._seen:
            return False
        self.params = checkpoint.restore(
            path, {"params": self.params_like}
        )["params"]
        self.round = int(checkpoint.load_metadata(path).get("round", -1))
        self._seen = path
        return True

    def eval_batch(self, batch) -> float:
        if self.params is None:
            raise RuntimeError("no snapshot loaded yet — poll() first")
        if self.eval_fn is None:
            raise RuntimeError("no eval_fn configured")
        return float(self.eval_fn(self.params, batch))

    def watch(self, batch, *, max_polls: int, interval: float = 2.0,
              on_eval=None, sleep=time.sleep) -> list[tuple[int, float]]:
        """Run up to ``max_polls`` poll cycles, evaluating on each new
        snapshot.  Returns the ``(round, loss)`` history.  ``sleep`` is
        injectable so tests can run the loop without waiting."""
        history: list[tuple[int, float]] = []
        for i in range(max_polls):
            if self.poll():
                loss = self.eval_batch(batch)
                history.append((self.round, loss))
                if on_eval is not None:
                    on_eval(self.round, loss)
            if i + 1 < max_polls:
                sleep(interval)
        return history


def _decode_demo(md, cfg, params, args) -> None:  # pragma: no cover - CLI
    B, S = args.batch, args.prompt_len
    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model)
        )

    prefill = jax.jit(md.prefill)
    decode = jax.jit(md.decode)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t1 = time.time()
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    outs = [np.asarray(toks)]
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t2 = time.time()
    gen = np.concatenate(outs, axis=1)
    print(f"prefill: {B}x{S} in {t1-t0:.2f}s; "
          f"decode: {args.new_tokens} tokens in {t2-t1:.2f}s "
          f"({B*args.new_tokens/(t2-t1):.1f} tok/s batch-aggregate)")
    for b in range(min(B, 4)):
        print(f"  request {b}: {gen[b].tolist()}")


def main() -> None:  # pragma: no cover - CLI glue
    from repro.configs import registry as creg
    from repro.models import registry as mreg

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=sorted(creg.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--restore", default="")
    ap.add_argument("--watch", default="",
                    help="checkpoint dir to poll for new snapshots")
    ap.add_argument("--max-polls", type=int, default=30)
    ap.add_argument("--poll-interval", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = creg.get_config(args.arch, reduced=args.reduced)
    if cfg.family == "resnet":
        raise SystemExit("resnet20 is a classifier; nothing to decode")
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(args.seed))

    if args.watch:
        key = jax.random.key(args.seed + 1)
        # same split the training loader uses: draw seq+1 tokens, labels
        # are the next-token shift (md.loss needs both keys)
        toks = jax.random.randint(
            key, (args.batch, args.prompt_len + 1), 0, cfg.vocab
        )
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        loop = SnapshotEvalLoop(
            args.watch, params_like=params, eval_fn=jax.jit(md.loss),
        )
        print(f"watching {args.watch} ({args.max_polls} polls, "
              f"{args.poll_interval}s apart)")
        loop.watch(
            batch, max_polls=args.max_polls, interval=args.poll_interval,
            on_eval=lambda rnd, loss: print(
                f"round {rnd:4d} eval_loss={loss:.4f}"),
        )
        return

    if args.restore:
        with np.load(args.restore) as z:
            # publish() snapshots namespace model leaves under params/
            # (alongside rng_key + optional server state); bare trees
            # from checkpoint.save() have no prefix
            nested = any(k.startswith("params/") for k in z.keys())
        if nested:
            params = checkpoint.restore(
                args.restore, {"params": params}
            )["params"]
        else:
            params = checkpoint.restore(args.restore, params)
    _decode_demo(md, cfg, params, args)


if __name__ == "__main__":
    main()
