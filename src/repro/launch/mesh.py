"""Production meshes.  Functions, not module constants — importing this module
never touches jax device state (the dry-run must set XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (16 data, 16 model).  Multi-pod: 2 × 256 as
    (2 pod, 16 data, 16 model); the client axes are ("pod","data")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)"
        )
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_local_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh over however many local devices exist (tests)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    import numpy as np
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
