"""Device meshes: the physical axes every sharded component agrees on.

Everything distributed in this repo is phrased against a named
:class:`jax.sharding.Mesh`; this module is the single place meshes are
constructed, so the axis-name vocabulary stays consistent across
`sharding/rules.py` (PartitionSpecs), `fl/ring.py` (ring collectives) and
`fl/distributed.py` (the sharded round step).  Three shapes:

* :func:`make_client_mesh` — the federated production mesh: a 1-D
  ``("clients",)`` mesh where each device owns a contiguous block of
  client slots.  This is what `build_sharded_scan_round_step` and the
  ``mesh8_*`` bench scenarios run on; on a CPU host, force the device
  count first (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
* :func:`make_production_mesh` — the serving/TP mesh from the model zoo:
  ``(16 data, 16 model)`` per pod, optionally ``(2 pod, 16 data,
  16 model)``.  The client axes are ``("data",)`` or ``("pod", "data")``
  (see :func:`repro.sharding.rules.client_axes`).
* :func:`make_local_mesh` — a small ``(data, model)`` mesh over whatever
  local devices exist (tests, dry-runs).

Functions, not module constants — importing this module never touches jax
device state (``XLA_FLAGS`` must be set before the *first* device query,
so eager ``jax.devices()`` at import time would lock the topology too
early; ``launch/dryrun.py`` and the subprocess tests rely on this).
"""
from __future__ import annotations

import jax


def make_client_mesh(n_devices: int | None = None, *, axis: str = "clients"):
    """1-D mesh over ``n_devices`` (default: all local devices), axis named
    ``"clients"`` — each device owns one shard of the padded client dim.

    The sharded round step requires ``n_clients % n_devices == 0`` (it is
    validated at build time, not here: a mesh is just topology).
    """
    import numpy as np

    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a client mesh, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count={n} before any "
            "jax import"
        )
    return jax.sharding.Mesh(np.asarray(devices[:n]), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (16 data, 16 model).  Multi-pod: 2 × 256 as
    (2 pod, 16 data, 16 model); the client axes are ("pod","data")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)"
        )
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_local_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh over however many local devices exist (tests)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    import numpy as np
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
