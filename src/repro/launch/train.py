"""Continuous-training service: stream federated rounds, publish snapshots.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
        --rounds 50 --engine async --delay poisson --publish-every 10 \
        --ckpt-dir checkpoints

:class:`ContinuousTrainer` drives any of the round engines (per-round loop,
epoch scan, pipelined scan, or the asynchronous staleness-weighted engine)
over a :class:`~repro.channels.ChannelSchedule` in checkpoint-sized bursts:
the schedule / policy / batch stream stay live across bursts (one continuous
round stream, exactly as if a single ``run_*`` call had covered the whole
horizon), and every ``publish_every`` rounds the full training state is
published via :func:`repro.checkpoint.publish` with atomic latest-pointer
rotation.  ``--rounds 0`` streams indefinitely; the serving loop
(``repro.launch.serve --watch``) reloads the newest snapshot as it lands.

Resume: :meth:`ContinuousTrainer.restore_latest` reloads params, server
state, RNG key and round counter; :meth:`ContinuousTrainer.advance_stream`
replays the (deterministic, seed-rebuilt) schedule / policy / batch stream
to the restored round.  For the synchronous engines the resumed trajectory
is bitwise-equal to the uninterrupted run (``tests/test_resume.py``); the
async engine restarts with an empty arrival buffer (in-flight updates are
lost on a crash — the production semantic), so its resumed stream is
statistically, not bitwise, continuous.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint
from repro.channels.delay import make_delays
from repro.fl.async_engine import AsyncRoundEngine
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop

ENGINES = ("loop", "scan", "pipelined", "async")


class ContinuousTrainer:
    """Burst-wise driver of one engine over one live channel stream.

    ``engine`` ∈ {loop, scan, pipelined, async}.  The trainer owns the
    training state (params, server state, RNG key, round counter); the
    caller owns the stream (``schedule``, ``policy``, ``next_batch``) —
    they are stateful and advance only when rounds run, which is what makes
    the burst sequence one continuous trajectory.

    ``publish_every > 0`` + ``ckpt_dir`` publishes the full training state
    every N rounds (and after the final burst) with atomic latest-pointer
    rotation, keeping the newest ``keep`` snapshots.
    """

    def __init__(self, sim, *, schedule, next_batch, lr, policy=None,
                 engine: str = "loop", chunk: int = 32, delays=None,
                 staleness_decay: float = 0.8, buffer_k: int = 0,
                 ckpt_dir: str | None = None, publish_every: int = 0,
                 keep: int = 3, metadata: dict | None = None, tracer=None):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (known: {ENGINES})")
        self.sim = sim
        self.schedule = schedule
        self.next_batch = next_batch
        self.lr = lr
        self.policy = policy
        self.engine_name = engine
        self.ckpt_dir = ckpt_dir
        self.publish_every = publish_every
        self.keep = keep
        self.metadata = metadata or {}
        if engine == "scan":
            self._engine = EpochScanEngine(sim, chunk=chunk, tracer=tracer)
        elif engine == "pipelined":
            self._engine = PipelinedScanEngine(sim, chunk=chunk, tracer=tracer)
        elif engine == "async":
            self._engine = AsyncRoundEngine(
                sim, delays=delays, staleness_decay=staleness_decay,
                buffer_k=buffer_k, tracer=tracer,
            )
        else:
            self._engine = None
        self._started = False
        self.params = None
        self.server_state = None
        self.key = None
        self.round = 0

    # ------------------------------------------------------------ lifecycle

    def init(self, params, key) -> None:
        """Fresh training state at round 0."""
        self.params = params
        self.server_state = self.sim.init_server_state(params)
        self.key = key
        self.round = 0
        self._started = False

    def restore_latest(self) -> bool:
        """Reload the newest published snapshot (params, server state, RNG
        key, round counter).  Call :meth:`init` first — the restore
        validates against the initialized structures.  Returns False when
        no snapshot exists.  The stream is *not* rewound: follow up with
        :meth:`advance_stream` to replay schedule/policy/batches."""
        if self.params is None:
            raise RuntimeError("call init() before restore_latest()")
        if self.ckpt_dir is None:
            return False
        path = checkpoint.latest_checkpoint(self.ckpt_dir)
        if path is None:
            return False
        params, server_state, key, rnd = checkpoint.restore_training_state(
            path, params_like=self.params,
            server_state_like=self.server_state,
        )
        self.params, self.server_state = params, server_state
        self.key, self.round = key, rnd
        self._started = False
        return True

    def advance_stream(self, rounds: int | None = None) -> None:
        """Replay ``rounds`` (default: the restored round counter) through
        the schedule, policy and batch stream without training — the
        deterministic fast-forward that aligns a seed-rebuilt stream with a
        restored state."""
        for state in self.schedule.rounds(self.round if rounds is None else rounds):
            if self.policy is not None:
                self.policy.relay_matrix(state)
            self.next_batch()

    # -------------------------------------------------------------- running

    def run(self, rounds: int, *, on_publish=None, stop=None) -> dict:
        """Run ``rounds`` more rounds in publish-sized bursts.  Returns the
        per-round metrics (host numpy, concatenated over bursts).
        ``on_publish(path, round)`` fires after each snapshot;``stop()`` is
        polled between bursts (True ⇒ return early, after a final
        publish)."""
        if self.params is None:
            raise RuntimeError("call init() (and optionally restore) first")
        burst = self.publish_every if self.publish_every > 0 else rounds
        collected: list[dict] = []
        remaining = rounds
        while remaining > 0:
            n = min(burst, remaining)
            metrics = self._run_burst(n)
            collected.append(
                {k: np.asarray(v) for k, v in metrics.items()}
            )
            remaining -= n
            self.round += n
            if self.publish_every > 0:
                self._publish(on_publish)
            if stop is not None and stop():
                break
        if self.publish_every == 0 and self.ckpt_dir is not None:
            self._publish(on_publish)
        if not collected:
            return {}
        return {
            k: np.concatenate([c[k] for c in collected])
            for k in collected[0]
        }

    def _run_burst(self, rounds: int) -> dict:
        if self.engine_name == "loop":
            out = run_rounds_loop(
                self.sim, self.key, self.params, self.server_state,
                schedule=self.schedule, rounds=rounds,
                next_batch=self.next_batch, lr=self.lr, policy=self.policy,
            )
        elif self.engine_name == "async":
            out = self._engine.run_schedule(
                self.key, self.params, self.server_state,
                schedule=self.schedule, rounds=rounds,
                next_batch=self.next_batch, lr=self.lr, policy=self.policy,
                reset=not self._started,
            )
        else:
            out = self._engine.run_schedule(
                self.key, self.params, self.server_state,
                schedule=self.schedule, rounds=rounds,
                next_batch=self.next_batch, lr=self.lr, policy=self.policy,
            )
        self.params, self.server_state, metrics, self.key = out
        self._started = True
        return metrics

    def _publish(self, on_publish) -> None:
        if self.ckpt_dir is None:
            return
        path = checkpoint.publish(
            self.ckpt_dir, params=self.params, server_state=self.server_state,
            key=self.key, round=self.round, keep=self.keep,
            metadata=dict(self.metadata, engine=self.engine_name),
        )
        if on_publish is not None:
            on_publish(path, self.round)


# ------------------------------------------------------------------ the CLI


def build_topology(name: str, n: int, k: int):
    from repro.core import topology

    if name == "ring":
        return topology.ring(n, k)
    if name == "fct":
        return topology.fully_connected(n)
    if name == "disconnected":
        return topology.disconnected(n)
    if name == "clusters":
        return topology.clusters(n, max(1, n // 4))
    raise ValueError(name)


def build_connectivity(profile: str, n: int, p_hom: float):
    from repro.core import connectivity

    if profile == "homogeneous":
        return connectivity.homogeneous(n, p_hom)
    if profile == "paper" and n == 10:
        return connectivity.paper_heterogeneous()
    return connectivity.heterogeneous_profile(n)


def main() -> None:  # pragma: no cover - CLI glue over ContinuousTrainer
    from repro import channels
    from repro.configs import registry as creg
    from repro.core import opt_alpha
    from repro.core.aggregation import ServerOpt
    from repro.data.loader import FederatedLoader
    from repro.data.partition import iid_partition, sort_and_partition
    from repro.data.synthetic import lm_tokens
    from repro.fl.simulator import FLSimulator
    from repro.models import registry as mreg
    from repro.optim.sgd import ClientOpt

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=sorted(creg.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=50,
                    help="0 = stream indefinitely (Ctrl-C to stop)")
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--strategy", default="colrel_fused",
                    choices=["colrel_fused", "fedavg_blind", "no_dropout"])
    ap.add_argument("--engine", default="loop", choices=list(ENGINES))
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--delay", default="none",
                    choices=["none", "poisson", "geometric"])
    ap.add_argument("--delay-rate", type=float, default=1.0)
    ap.add_argument("--delay-max", type=int, default=8)
    ap.add_argument("--staleness-decay", type=float, default=0.8)
    ap.add_argument("--buffer-k", type=int, default=0)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topology-k", type=int, default=1)
    ap.add_argument("--p-profile", default="heterogeneous",
                    choices=["homogeneous", "heterogeneous", "paper"])
    ap.add_argument("--p", type=float, default=0.2, help="homogeneous p")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--server-momentum", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--publish-every", type=int, default=0)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest snapshot in --ckpt-dir")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    n = args.clients
    cfg = creg.get_config(args.arch, reduced=args.reduced)
    if cfg.family == "resnet":
        raise SystemExit("use benchmarks/fig*.py for the resnet paper runs")
    md = mreg.get_model(cfg)

    conn = build_connectivity(args.p_profile, n, args.p)
    adj = build_topology(args.topology, n, args.topology_k)
    res = opt_alpha.optimize(conn.p, adj, sweeps=50)
    print(f"OPT-α: S {res.S_history[0]:.3f} -> {res.S_history[-1]:.3f} "
          f"({res.sweeps} sweeps, feasible={res.feasible_columns.all()})")

    ds = lm_tokens(4096, args.seq_len, vocab=cfg.vocab, seed=args.seed)
    parts = (sort_and_partition(ds, n, seed=args.seed) if args.non_iid
             else iid_partition(ds, n, seed=args.seed))
    loader = FederatedLoader(ds, parts, seed=args.seed)

    sim = FLSimulator(
        md.loss, n_clients=n, strategy=args.strategy, A=res.A, p=conn.p,
        local_steps=args.local_steps,
        client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
        server_opt=ServerOpt(momentum=args.server_momentum),
    )
    trainer = ContinuousTrainer(
        sim,
        schedule=channels.StaticChannel(adj, conn.p),
        next_batch=lambda: loader.round_batch(
            args.local_steps, args.local_batch, lm=True
        ),
        lr=args.lr,
        engine=args.engine,
        chunk=args.chunk,
        delays=make_delays(args.delay, n, rate=args.delay_rate,
                           max_delay=args.delay_max, seed=args.seed + 11),
        staleness_decay=args.staleness_decay,
        buffer_k=args.buffer_k,
        ckpt_dir=args.ckpt_dir or None,
        publish_every=args.publish_every,
        keep=args.keep,
        metadata={"arch": args.arch, "strategy": args.strategy},
    )
    trainer.init(md.init(jax.random.key(args.seed)),
                 jax.random.key(args.seed + 1))
    if args.resume and trainer.restore_latest():
        print(f"resumed from round {trainer.round}; replaying the stream")
        trainer.advance_stream()

    t0 = time.time()

    def log_burst(metrics, base_round):
        losses = np.asarray(metrics["loss"])
        for i, loss in enumerate(losses):
            r = base_round + i
            if r % args.log_every == 0 or i == len(losses) - 1:
                print(f"round {r:4d} loss={float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")

    def on_publish(path, rnd):
        print(f"published {path} @ round {rnd}")

    try:
        if args.rounds > 0:
            base = trainer.round
            metrics = trainer.run(args.rounds, on_publish=on_publish)
            log_burst(metrics, base)
        else:
            burst = args.publish_every or args.log_every
            while True:
                base = trainer.round
                metrics = trainer.run(burst, on_publish=on_publish)
                log_burst(metrics, base)
    except KeyboardInterrupt:
        print(f"interrupted at round {trainer.round}")


if __name__ == "__main__":
    main()
