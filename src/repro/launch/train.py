"""FL training launcher (runs on the local devices; reduced configs on CPU).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
        --rounds 50 --strategy colrel --topology ring --p-profile heterogeneous

Drives the ColRel protocol end-to-end: OPT-α weight optimization → federated
rounds over the assigned architecture (LM-token synthetic data) → checkpoint.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import registry as creg
from repro.core import connectivity, opt_alpha, topology
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition, sort_and_partition
from repro.data.synthetic import lm_tokens
from repro.fl.simulator import FLSimulator
from repro.models import registry as mreg
from repro.optim.sgd import ClientOpt


def build_topology(name: str, n: int, k: int):
    if name == "ring":
        return topology.ring(n, k)
    if name == "fct":
        return topology.fully_connected(n)
    if name == "disconnected":
        return topology.disconnected(n)
    if name == "clusters":
        return topology.clusters(n, max(1, n // 4))
    raise ValueError(name)


def build_connectivity(profile: str, n: int, p_hom: float):
    if profile == "homogeneous":
        return connectivity.homogeneous(n, p_hom)
    if profile == "paper" and n == 10:
        return connectivity.paper_heterogeneous()
    return connectivity.heterogeneous_profile(n)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=sorted(creg.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--strategy", default="colrel",
                    choices=["colrel", "colrel_fused", "fedavg_blind",
                             "fedavg_nonblind", "no_dropout"])
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topology-k", type=int, default=1)
    ap.add_argument("--p-profile", default="heterogeneous",
                    choices=["homogeneous", "heterogeneous", "paper"])
    ap.add_argument("--p", type=float, default=0.2, help="homogeneous p")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--server-momentum", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    n = args.clients
    cfg = creg.get_config(args.arch, reduced=args.reduced)
    if cfg.family == "resnet":
        raise SystemExit("use benchmarks/fig*.py for the resnet paper runs")
    md = mreg.get_model(cfg)

    conn = build_connectivity(args.p_profile, n, args.p)
    adj = build_topology(args.topology, n, args.topology_k)
    res = opt_alpha.optimize(conn.p, adj, sweeps=50)
    print(f"OPT-α: S {res.S_history[0]:.3f} -> {res.S_history[-1]:.3f} "
          f"({res.sweeps} sweeps, feasible={res.feasible_columns.all()})")

    ds = lm_tokens(4096, args.seq_len, vocab=cfg.vocab, seed=args.seed)
    parts = (sort_and_partition(ds, n, seed=args.seed) if args.non_iid
             else iid_partition(ds, n, seed=args.seed))
    loader = FederatedLoader(ds, parts, seed=args.seed)

    sim = FLSimulator(
        md.loss, n_clients=n, strategy=args.strategy, A=res.A, p=conn.p,
        local_steps=args.local_steps,
        client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
        server_opt=ServerOpt(momentum=args.server_momentum),
    )
    params = md.init(jax.random.key(args.seed))
    state = sim.init_server_state(params)
    key = jax.random.key(args.seed + 1)
    t0 = time.time()
    for r in range(args.rounds):
        key, sub = jax.random.split(key)
        batch = loader.round_batch(args.local_steps, args.local_batch, lm=True)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, m = sim.run_round(sub, params, state, batch, args.lr)
        if r % args.log_every == 0 or r == args.rounds - 1:
            print(f"round {r:4d} loss={float(m['loss']):.4f} "
                  f"tau={np.asarray(m['tau']).astype(int)} "
                  f"|Δ|={float(m['delta_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")
    if args.checkpoint:
        checkpoint.save(args.checkpoint, params,
                        metadata={"arch": args.arch, "rounds": args.rounds,
                                  "strategy": args.strategy})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
