import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination on placeholder devices and record the roofline inputs.

For train shapes the lowered computation is the full ColRel round
(T local SGD steps per client → D2D relay → blind τ-masked PS aggregation);
for prefill/decode shapes it is the serving step.  Nothing is ever executed —
inputs are ShapeDtypeStructs — but a successful ``.lower().compile()`` proves
the sharding config is coherent (no mismatched collectives, divisibility
failures, or unpartitionable ops) and yields ``cost_analysis()`` /
``memory_analysis()`` / the compiled HLO collective schedule.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every pair, cached
Artifacts: benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as creg
from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.core import connectivity, opt_alpha, topology
from repro.core.aggregation import ServerOpt
from repro.fl.distributed import build_round_step
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import registry as mreg
from repro.optim.sgd import ClientOpt
from repro.sharding import rules
from repro.sharding import hints

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun"
)

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

# archs whose parameters exceed single-chip-slice HBM at 1-D TP → 2-D sharding.
# qwen3-14b moved to 1-D TP in §Perf iteration 4: FSDP weight all-gathers were
# re-issued per client slice (×16) under the vmap; at 1.75 GB/device the
# weights fit replicated and the gathers vanish.
FSDP_ARCHS = {
    "grok-1-314b", "mixtral-8x22b", "qwen2.5-32b", "qwen1.5-32b",
}
# serving re-reads weights every step: pay FSDP all-gathers only when bf16
# params genuinely exceed a 16-way TP slice (§Perf iteration 2) —
# grok 314B: 39 GB/dev, mixtral 141B: 17.6 GB/dev; qwen-32Bs fit at ~4 GB/dev
SERVE_FSDP_ARCHS = {"grok-1-314b", "mixtral-8x22b"}

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from the partitioned HLO."""
    out: dict[str, float] = {}
    for shapes, kind in COLLECTIVE_RE.findall(hlo_text):
        out[kind] = out.get(kind, 0) + _shape_bytes(shapes)
    out["total"] = sum(out.values())
    return out


def _dryrun_cfg(arch: str, shape_name: str) -> ModelConfig:
    shape = INPUT_SHAPES[shape_name]
    cfg = creg.get_config(arch)
    cfg = creg.for_shape(cfg, shape)
    return dataclasses.replace(cfg, param_dtype="bfloat16", compute_dtype="bfloat16")


def _n_clients(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def build_train_lowering(arch: str, shape_name: str, mesh, relay_mode: str = "faithful"):
    shape = INPUT_SHAPES[shape_name]
    cfg = _dryrun_cfg(arch, shape_name)
    md = mreg.get_model(cfg)
    n = _n_clients(mesh)

    # protocol inputs (host-side, constants folded into the step)
    p = connectivity.heterogeneous_profile(n).p
    adj = topology.ring(n, k=2)
    A = opt_alpha.optimize(p, adj, sweeps=20).A.astype(np.float32)

    round_step = build_round_step(
        md.loss, n_clients=n, local_steps=1, A=A, relay_mode=relay_mode,
        client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
        server_opt=ServerOpt(),
    )

    # abstract params via eval_shape — no allocation
    params = jax.eval_shape(md.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = mreg.input_specs(cfg, shape)
    per_client = shape.global_batch // n
    batch = {
        k: jax.ShapeDtypeStruct((n, 1, per_client) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }
    tau = jax.ShapeDtypeStruct((n,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    mode = "fsdp_tp" if arch in FSDP_ARCHS else "tp"
    pspecs = rules.param_specs(params, mesh, mode)
    bspecs = rules.train_batch_specs(batch, mesh)
    ca = rules.client_axes(mesh)

    # stable blockwise-attention layout: q-chunks sequence-parallel over
    # "model" (batch/client dims are already pinned by in_shardings)
    with mesh, hints.axis_rules(mesh, {"qchunk": "model"}):
        jitted = jax.jit(
            round_step,
            in_shardings=(
                rules.to_shardings(pspecs, mesh),
                None,
                rules.to_shardings(bspecs, mesh),
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                None,
            ),
            out_shardings=(
                rules.to_shardings(pspecs, mesh),
                None,
                None,
            ),
        )
        lowered = jitted.lower(params, None, batch, tau, lr)
    return lowered, cfg, shape


def build_serve_lowering(arch: str, shape_name: str, mesh):
    shape = INPUT_SHAPES[shape_name]
    cfg = _dryrun_cfg(arch, shape_name)
    md = mreg.get_model(cfg)
    mode = "fsdp_tp" if arch in SERVE_FSDP_ARCHS else "tp"

    params = jax.eval_shape(md.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = rules.param_specs(params, mesh, mode)

    ca = rules.client_axes(mesh)
    with mesh, hints.axis_rules(mesh, {"batch": ca, "qchunk": "model"}):
        if shape.kind == "prefill":
            batch = mreg.input_specs(cfg, shape)
            bspecs = rules.serve_batch_specs(batch, mesh)
            jitted = jax.jit(
                md.prefill,
                in_shardings=(
                    rules.to_shardings(pspecs, mesh),
                    rules.to_shardings(bspecs, mesh),
                ),
            )
            lowered = jitted.lower(params, batch)
        else:  # decode: one token against a cache of seq_len
            cache = jax.eval_shape(
                lambda: md.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = rules.cache_specs(cache, mesh, shape.global_batch)
            tokens = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
            tspecs = rules.serve_batch_specs(tokens, mesh)
            jitted = jax.jit(
                md.decode,
                in_shardings=(
                    rules.to_shardings(pspecs, mesh),
                    rules.to_shardings(cspecs, mesh),
                    rules.to_shardings(tspecs, mesh)["tokens"],
                ),
                out_shardings=(None, rules.to_shardings(cspecs, mesh)),
            )
            lowered = jitted.lower(params, cache, tokens["tokens"])
    return lowered, cfg, shape


def model_flops(cfg: ModelConfig, shape) -> float:
    """Analytic MODEL_FLOPS per step: 6·N·D train, 2·N_active·D inference."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    return (6.0 if shape.kind == "train" else 2.0) * n_active * tokens


def run_one(arch: str, shape_name: str, *, multi_pod: bool, relay_mode: str = "faithful",
            out_dir: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    skip = creg.is_skipped(arch, shape_name)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "relay_mode": relay_mode, "status": "skipped", "skip_reason": skip,
    }
    if skip is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
        shape = INPUT_SHAPES[shape_name]
        t0 = time.time()
        try:
            if shape.kind == "train":
                lowered, cfg, shape = build_train_lowering(
                    arch, shape_name, mesh, relay_mode)
            else:
                lowered, cfg, shape = build_serve_lowering(arch, shape_name, mesh)
            compiled = lowered.compile()
            t1 = time.time()
            ca = compiled.cost_analysis() or {}
            ma = compiled.memory_analysis()
            hlo = compiled.as_text()
            # loop-aware corrected costs (XLA counts while bodies once —
            # scans over layers/chunks would be undercounted by trip count)
            corrected = hlo_cost.analyze(hlo)
            coll = {k: v for k, v in corrected["collectives"].items()}
            chips = int(np.prod(list(mesh.shape.values())))
            flops_dev = float(corrected["flops"])
            bytes_dev = float(corrected["hbm_bytes"])
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
            }
            mem["peak_bytes"] = (
                mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
                - mem["alias_bytes"]
            )
            mf = model_flops(cfg, shape)
            record.update({
                "status": "ok",
                "compile_seconds": round(t1 - t0, 1),
                "chips": chips,
                "xla_cost_analysis_raw": {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                },
                "per_device": {"flops": flops_dev, "bytes": bytes_dev, **mem},
                "collective_bytes_per_device": coll,
                "roofline_seconds": {
                    "compute": flops_dev / PEAK_FLOPS,
                    "memory": bytes_dev / HBM_BW,
                    "collective": coll.get("total", 0.0) / ICI_BW,
                },
                "model_flops_global": mf,
                "useful_flops_ratio": mf / (flops_dev * chips) if flops_dev else None,
                "n_params": cfg.param_count(),
                "n_params_active": cfg.active_param_count(),
            })
            r = record["roofline_seconds"]
            record["bottleneck"] = max(r, key=r.get)
        except Exception as e:  # noqa: BLE001 — record the failure, don't die
            record.update({
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(limit=8),
            })
    out_dir = out_dir or os.path.join(ARTIFACT_DIR, mesh_name)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if relay_mode == "faithful" else f"__{relay_mode}"
    path = os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(creg.ASSIGNED))
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--relay-mode", default="faithful", choices=["faithful", "fused"])
    ap.add_argument("--force", action="store_true", help="recompute cached artifacts")
    args = ap.parse_args()

    pairs = (
        [(a, s) for a in creg.ASSIGNED for s in INPUT_SHAPES]
        if args.all else [(args.arch, args.shape)]
    )
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    for arch, shape_name in pairs:
        suffix = "" if args.relay_mode == "faithful" else f"__{args.relay_mode}"
        path = os.path.join(ARTIFACT_DIR, mesh_name, f"{arch}__{shape_name}{suffix}.json")
        if not args.force and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[cached] {arch} {shape_name} {mesh_name}")
                    continue
        rec = run_one(arch, shape_name, multi_pod=args.multi_pod,
                      relay_mode=args.relay_mode)
        if rec["status"] == "ok":
            r = rec["roofline_seconds"]
            print(f"[ok] {arch} {shape_name} {mesh_name} "
                  f"compile={rec['compile_seconds']}s "
                  f"compute={r['compute']:.3e}s memory={r['memory']:.3e}s "
                  f"coll={r['collective']:.3e}s bottleneck={rec['bottleneck']}")
            print(f"     memory_analysis: {rec['per_device']}")
            print(f"     cost_analysis: flops/dev={rec['per_device']['flops']:.3e} "
                  f"useful_ratio={rec['useful_flops_ratio']}")
        elif rec["status"] == "skipped":
            print(f"[skip] {arch} {shape_name}: {rec['skip_reason']}")
        else:
            print(f"[ERROR] {arch} {shape_name} {mesh_name}: {rec['error']}")


if __name__ == "__main__":
    main()
