"""Pytree arithmetic helpers used across the FL engine.

All model parameters, updates and optimizer states in this framework are plain
pytrees; these helpers implement the handful of vector-space operations the
ColRel algebra needs (weighted sums, norms, dtype casts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, x, y):
    """y + s * x, elementwise over matching pytrees."""
    return jax.tree.map(lambda xe, ye: ye + s * xe, x, y)


def tree_dot(a, b):
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar parameters in the pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
