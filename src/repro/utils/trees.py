"""Pytree arithmetic helpers used across the FL engine.

All model parameters, updates and optimizer states in this framework are plain
pytrees; these helpers implement the handful of vector-space operations the
ColRel algebra needs (weighted sums, norms, dtype casts), plus the
raveled-view layer: :func:`tree_ravel` / :func:`tree_unravel` flatten a
pytree to one contiguous buffer (and back) under a static :class:`TreeSpec`,
and :func:`stacked_ravel` does the same for a stacked per-client tree —
giving the relay/aggregate hot spot a single ``(n, D)`` operand while the
clients' local SGD keeps the structured view.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, x, y):
    """y + s * x, elementwise over matching pytrees."""
    return jax.tree.map(lambda xe, ye: ye + s * xe, x, y)


def tree_dot(a, b):
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar parameters in the pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


# --------------------------------------------------------------------------
# Raveled view: pytree ⇄ one contiguous buffer under a static TreeSpec
# --------------------------------------------------------------------------

# leaf dtypes a float32 buffer represents exactly (f32 has strictly more
# mantissa/exponent bits than either half precision format, so the
# ravel→unravel round trip is bit-exact for these)
_F32_EXACT = ("float32", "bfloat16", "float16")


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Static description of a raveled pytree: everything needed to restore
    the structured view from the contiguous buffer.  Hashable (treedefs are),
    so a spec can ride through jit as a static argument."""

    treedef: object
    shapes: tuple  # per-leaf shapes, in flatten order
    dtypes: tuple  # per-leaf dtype names, in flatten order

    @property
    def sizes(self) -> tuple:
        return tuple(math.prod(s) for s in self.shapes)

    @property
    def total(self) -> int:
        """D — the total scalar count of the raveled buffer."""
        return sum(self.sizes)


def tree_spec(tree) -> TreeSpec:
    leaves, treedef = jax.tree.flatten(tree)
    return TreeSpec(
        treedef=treedef,
        shapes=tuple(tuple(x.shape) for x in leaves),
        dtypes=tuple(jnp.asarray(x).dtype.name for x in leaves),
    )


def _check_exact(spec: TreeSpec, dtype) -> None:
    buf = jnp.dtype(dtype).name
    for name in spec.dtypes:
        if name != buf and not (buf == "float32" and name in _F32_EXACT):
            raise TypeError(
                f"leaf dtype {name} is not exactly representable in a "
                f"{buf} buffer — the ravel round trip would not be bit-exact"
            )


def tree_ravel(tree, *, dtype=jnp.float32):
    """Flatten ``tree`` into one contiguous ``(D,)`` buffer.

    Returns ``(flat, spec)``.  The buffer dtype must represent every leaf
    dtype exactly (float32 covers f32/bf16/f16), so
    ``tree_unravel(spec, flat)`` restores the original leaves bit-for-bit.
    """
    leaves, _ = jax.tree.flatten(tree)
    spec = tree_spec(tree)
    _check_exact(spec, dtype)
    if not leaves:
        return jnp.zeros((0,), dtype), spec
    return jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves]), spec


def tree_unravel(spec: TreeSpec, flat, *, cast: bool = True):
    """Restore the structured view from a raveled ``(D,)`` buffer.

    ``cast=True`` returns each leaf in its original dtype (the bit-exact
    inverse of :func:`tree_ravel`); ``cast=False`` keeps the buffer dtype —
    the increment path, where aggregation math stays f32 and the server
    optimizer owns the final cast back to the parameter dtype.
    """
    if flat.shape != (spec.total,):
        raise ValueError(f"buffer shape {flat.shape} != ({spec.total},)")
    leaves = []
    offset = 0
    for shape, name, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        seg = jax.lax.slice_in_dim(flat, offset, offset + size).reshape(shape)
        leaves.append(seg.astype(name) if cast else seg)
        offset += size
    return jax.tree.unflatten(spec.treedef, leaves)


def stacked_ravel(stacked, *, dtype=jnp.float32):
    """Ravel a stacked per-client pytree (leaves ``(n, ...)``) into one
    contiguous ``(n, D)`` buffer.

    Returns ``(buf, spec)`` where ``spec`` describes one client's tree
    (leading dim stripped): ``buf[i]`` is exactly
    ``tree_ravel(client_i_tree)[0]``, and ``tree_unravel(spec, buf[i])``
    restores client i's structured view.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        return jnp.zeros((0, 0), dtype), TreeSpec(treedef, (), ())
    n = leaves[0].shape[0]
    for x in leaves:
        if x.shape[0] != n:
            raise ValueError(f"inconsistent leading (client) dim: {x.shape[0]} != {n}")
    spec = TreeSpec(
        treedef=treedef,
        shapes=tuple(tuple(x.shape[1:]) for x in leaves),
        dtypes=tuple(jnp.asarray(x).dtype.name for x in leaves),
    )
    _check_exact(spec, dtype)
    buf = jnp.concatenate([x.reshape(n, -1).astype(dtype) for x in leaves], axis=1)
    return buf, spec
