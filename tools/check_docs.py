"""Spot-check the docs against the live code (CI: ``make docs-check``).

Two checks, both cheap enough for the lint job:

1. **Runnable snippets** — every fenced ``bash`` block in the given docs
   whose command line carries ``--list`` is executed verbatim from the repo
   root, with ``PYTHONPATH`` stripped from the inherited environment so a
   snippet only works if it sets it itself (exactly what a reader
   copy-pasting it gets); a non-zero exit or empty output fails.
   ``--list`` commands are read-only by construction, so running them is
   safe anywhere.
2. **Scenario references** — every ``--scenario <name>`` occurrence and
   every ``BENCH_<name>.json`` mention in the docs must name a scenario
   that exists in the live ``repro.bench.scenarios`` registry (or be the
   documented ``<scenario>``/``<name>`` placeholder).

Usage::

    PYTHONPATH=src python tools/check_docs.py docs/benchmarks.md [more.md ...]
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SNIPPET_RE = re.compile(r"```bash\n(.*?)```", re.DOTALL)
SCENARIO_REF_RE = re.compile(r"(?:--scenario\s+|BENCH_)([A-Za-z0-9_<>]+)")


def _snippet_commands(text: str) -> list[str]:
    """Full (possibly line-continued) commands from bash blocks that carry
    --list — the read-only subset we can always execute."""
    commands = []
    for block in SNIPPET_RE.findall(text):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#") and "--list" in line:
                commands.append(line)
    return commands


def _scenario_refs(text: str) -> set[str]:
    refs = set()
    for m in SCENARIO_REF_RE.finditer(text):
        name = m.group(1)
        if name and not name.startswith("<"):  # skip <scenario>-style holes
            refs.add(name)
    return refs


def check_file(path: pathlib.Path, known: set[str]) -> list[str]:
    failures = []
    text = path.read_text()

    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    for cmd in _snippet_commands(text):
        proc = subprocess.run(
            cmd,
            shell=True,
            capture_output=True,
            text=True,
            cwd=REPO_ROOT,
            env=env,
        )
        if proc.returncode != 0:
            failures.append(
                f"{path}: snippet failed ({proc.returncode}): {cmd}\n"
                f"  stderr: {proc.stderr.strip()[:500]}"
            )
        elif not proc.stdout.strip():
            failures.append(f"{path}: snippet produced no output: {cmd}")
        else:
            print(f"ok: {cmd}  [{len(proc.stdout.splitlines())} lines]")

    for name in sorted(_scenario_refs(text)):
        if name not in known:
            failures.append(f"{path}: references scenario {name!r} not in registry")
    return failures


def main(argv: list[str]) -> int:
    from repro.bench import scenarios

    known = {s.name for s in scenarios.list_scenarios()}
    paths = [pathlib.Path(a) for a in argv] or [
        pathlib.Path("docs/benchmarks.md"),
        pathlib.Path("docs/architecture.md"),
    ]
    failures: list[str] = []
    for path in paths:
        if not path.exists():
            failures.append(f"missing docs file: {path}")
            continue
        failures.extend(check_file(path, known))
    for f in failures:
        print(f"DOCS CHECK FAIL: {f}", file=sys.stderr)
    if not failures:
        print(f"docs check: OK ({len(paths)} files)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
