# Tier-1 verification targets.  `test` is the canonical suite (ROADMAP.md);
# `test-fast` skips the @slow convergence tests for quick local iteration.
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest

.PHONY: test test-fast test-cov test-all bench bench-smoke trace-smoke lint docs-check

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -x -q -m "not slow"

# test-fast plus the coverage gate (CI's test-fast job): measured over
# src/repro per .coveragerc, failing below the checked-in floor.  The floor
# is a ratchet — raise it as coverage grows, never lower it to make CI pass.
# 81 = the PR-7 re-ratchet: the ravel layer / relay-backend / real-model
# test net lands near-complete coverage on its new code (trees 96%,
# kernels 98-100%), measured ≈ 83% overall — the remaining drag is the
# not-yet-wired seed modules (launch/, fl/ring.py, sharding/rules.py), so
# the floor moves up conservatively rather than to measured−5
# (previous floor: 80).
test-cov:
	$(PYTEST) -x -q -m "not slow" --cov --cov-config=.coveragerc \
	  --cov-report=term --cov-fail-under=81

# full suite without -x: runs past the known-failing slow convergence
# bounds so regressions in later files stay visible
test-all:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# CI perf gate: run the tiny bench scenario (loop vs scan engine), write
# BENCH_bench_smoke.json, fail on >2x rounds/sec regression vs the
# checked-in baseline (benchmarks/baselines/, regenerate by copying a fresh
# report over it when hardware or engine legitimately changes).  The second
# run is the kernel-parity smoke: relay_sweep_smoke carries check_backend,
# so the harness raises if the Pallas path drifts from the einsum reference
# (no --baseline — it gates on parity, not throughput).
bench-smoke:
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario bench_smoke \
	  --out-dir . --trace \
	  --baseline benchmarks/baselines/BENCH_bench_smoke.json \
	  --max-regression 2.0
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario relay_sweep_smoke \
	  --out-dir .

# telemetry demo: traced bench_smoke run (writes TRACE_*.json — load them in
# https://ui.perfetto.dev) + the per-phase attribution summary for the
# pipelined engine's trace (see docs/observability.md)
trace-smoke:
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario bench_smoke \
	  --out-dir . --trace
	PYTHONPATH=src $(PY) -m repro.obs.summary TRACE_bench_smoke_pipelined.json

lint:
	ruff check .
	ruff format --check src/repro/bench src/repro/channels src/repro/fl \
	  src/repro/kernels src/repro/obs src/repro/utils tests/test_bench.py \
	  tests/test_pipelined_engine.py tests/test_obs.py

# spot-check the docs against the live code: runs the --list snippets
# embedded in the listed docs and verifies every scenario the docs
# reference still exists in the registry
docs-check:
	PYTHONPATH=src $(PY) tools/check_docs.py docs/benchmarks.md \
	  docs/architecture.md docs/observability.md
