# Tier-1 verification targets.  `test` is the canonical suite (ROADMAP.md);
# `test-fast` skips the @slow convergence tests for quick local iteration.
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest

.PHONY: test test-fast test-cov test-all bench bench-smoke lint docs-check

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -x -q -m "not slow"

# test-fast plus the coverage gate (CI's test-fast job): measured over
# src/repro per .coveragerc, failing below the checked-in floor.  The floor
# is a ratchet — raise it as coverage grows, never lower it to make CI pass.
# 78 = the measured fast-suite line coverage (~83%) minus a 5-point margin
# (replacing the placeholder 60 it launched with).
test-cov:
	$(PYTEST) -x -q -m "not slow" --cov --cov-config=.coveragerc \
	  --cov-report=term --cov-fail-under=78

# full suite without -x: runs past the known-failing slow convergence
# bounds so regressions in later files stay visible
test-all:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# CI perf gate: run the tiny bench scenario (loop vs scan engine), write
# BENCH_bench_smoke.json, fail on >2x rounds/sec regression vs the
# checked-in baseline (benchmarks/baselines/, regenerate by copying a fresh
# report over it when hardware or engine legitimately changes)
bench-smoke:
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario bench_smoke \
	  --out-dir . \
	  --baseline benchmarks/baselines/BENCH_bench_smoke.json \
	  --max-regression 2.0

lint:
	ruff check .
	ruff format --check src/repro/bench src/repro/channels src/repro/fl \
	  tests/test_bench.py tests/test_pipelined_engine.py

# spot-check the docs against the live code: runs the --list snippets
# embedded in docs/benchmarks.md / docs/architecture.md and verifies every
# scenario the docs reference still exists in the registry
docs-check:
	PYTHONPATH=src $(PY) tools/check_docs.py docs/benchmarks.md \
	  docs/architecture.md
