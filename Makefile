# Tier-1 verification targets.  `test` is the canonical suite (ROADMAP.md);
# `test-fast` skips the @slow convergence tests for quick local iteration.
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest

.PHONY: test test-fast test-cov test-all bench bench-smoke trace-smoke lint docs-check

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -x -q -m "not slow"

# test-fast plus the coverage gate (CI's test-fast job): measured over
# src/repro per .coveragerc, failing below the checked-in floor.  The floor
# is a ratchet — raise it as coverage grows, never lower it to make CI pass.
# 82 = held through the async/continuous-training work: the async engine,
# delay processes, checkpoint layer, and launch services all land with
# in-process tests (test_async_engine / test_resume / test_launch), and the
# .coveragerc launch omits are gone, so the measured number covers the
# whole tree now.  A settrace/AST proxy (pytest-cov absent locally)
# measures ≈83.8% on the fast suite (was ≈83.6% pre-async); measured−5
# would sit *below* the standing floor, and the ratchet never moves down,
# so the floor holds until measured growth clears the next integer
# (previous floors: 80 → 81 → 82).
test-cov:
	$(PYTEST) -x -q -m "not slow" --cov --cov-config=.coveragerc \
	  --cov-report=term --cov-fail-under=82

# full suite without -x: runs past the known-failing slow convergence
# bounds so regressions in later files stay visible
test-all:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# CI perf gate: run the tiny bench scenario (loop vs scan engine), write
# BENCH_bench_smoke.json, fail on >2x rounds/sec regression vs the
# checked-in baseline (benchmarks/baselines/, regenerate by copying a fresh
# report over it when hardware or engine legitimately changes).  The second
# run is the kernel-parity smoke: relay_sweep_smoke carries check_backend,
# so the harness raises if the Pallas path drifts from the einsum reference
# (no --baseline — it gates on parity, not throughput).
bench-smoke:
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario bench_smoke \
	  --out-dir . --trace \
	  --baseline benchmarks/baselines/BENCH_bench_smoke.json \
	  --max-regression 2.0
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario relay_sweep_smoke \
	  --out-dir .
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
	  $(PY) -m repro.bench.run --scenario mesh8_smoke --out-dir . --trace \
	  --baseline benchmarks/baselines/BENCH_mesh8_smoke.json \
	  --max-regression 2.0
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario sample_sweep_smoke \
	  --out-dir .
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario async_smoke \
	  --out-dir . \
	  --baseline benchmarks/baselines/BENCH_async_smoke.json \
	  --max-regression 2.0

# telemetry demo: traced bench_smoke run (writes TRACE_*.json — load them in
# https://ui.perfetto.dev) + the per-phase attribution summary for the
# pipelined engine's trace (see docs/observability.md)
trace-smoke:
	PYTHONPATH=src $(PY) -m repro.bench.run --scenario bench_smoke \
	  --out-dir . --trace
	PYTHONPATH=src $(PY) -m repro.obs.summary TRACE_bench_smoke_pipelined.json

lint:
	ruff check .
	ruff format --check src/repro/bench src/repro/channels src/repro/core \
	  src/repro/fl src/repro/kernels src/repro/obs src/repro/utils \
	  src/repro/launch src/repro/checkpoint \
	  tests/test_bench.py tests/test_pipelined_engine.py tests/test_obs.py \
	  tests/test_async_engine.py tests/test_launch.py tests/test_resume.py

# spot-check the docs against the live code: runs the --list snippets
# embedded in the listed docs and verifies every scenario the docs
# reference still exists in the registry
docs-check:
	PYTHONPATH=src $(PY) tools/check_docs.py docs/benchmarks.md \
	  docs/architecture.md docs/observability.md docs/distributed.md \
	  docs/paper_map.md
