# Tier-1 verification targets.  `test` is the canonical suite (ROADMAP.md);
# `test-fast` skips the @slow convergence tests for quick local iteration.
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest

.PHONY: test test-fast test-all bench

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -x -q -m "not slow"

# full suite without -x: runs past the known-failing slow convergence
# bounds so regressions in later files stay visible
test-all:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
