"""Paper Fig. 4: non-IID (sort-and-partition) data + global momentum at the
PS, heterogeneous p, ring with 4 nearest neighbors.

Claim reproduced: blind/non-blind FedAvg-Dropout collapses (low-connectivity
clients own whole classes that never reach the PS), while ColRel stays close
to NoDropout."""
from __future__ import annotations

from benchmarks.common import print_figure_csv, run_figure
from repro.core import connectivity, opt_alpha, topology


def run(rounds: int = 30, model: str = "mlp"):
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(10, k=2)  # 4 nearest neighbors (paper Fig. 4)
    opt = opt_alpha.optimize(p, adj, sweeps=60)
    strategies = {
        "no_dropout": ("no_dropout", None),
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "fedavg_dropout_nonblind": ("fedavg_nonblind", None),
        "colrel_optimized": ("colrel_fused", opt.A),
    }
    results = run_figure(p=p, adj=adj, strategies=strategies, rounds=rounds,
                         model=model, non_iid=True, server_momentum=0.9)
    print_figure_csv("fig4", results)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    a = ap.parse_args()
    run(rounds=a.rounds, model=a.model)
