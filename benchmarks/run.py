"""Benchmark orchestrator — one function per paper figure/table plus the
framework benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default budgets are CPU-friendly (single core); ``--full`` uses paper-scale
round counts.  The roofline rows are read from the dry-run artifacts (run
``python -m repro.launch.dryrun --all [--multi-pod]`` first to refresh).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow on CPU)")
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    ap.add_argument("--skip-figures", action="store_true")
    args = ap.parse_args()

    rounds = 100 if args.full else 25
    print("name,us_per_call,derived")

    if not args.skip_figures:
        from benchmarks import (fig2_homogeneous, fig3_ring, fig4_noniid,
                                fig5_timevarying, fig6_churn)

        fig2_homogeneous.run(rounds=rounds, model=args.model)
        fig3_ring.run(rounds=rounds, model=args.model)
        fig4_noniid.run(rounds=rounds, model=args.model)
        fig5_timevarying.run(rounds=rounds, model=args.model)
        fig6_churn.run(rounds=rounds, model=args.model)

    from benchmarks import bench_opt_alpha, bench_relay_kernel, roofline

    bench_opt_alpha.run()
    bench_relay_kernel.run()
    roofline.run()


if __name__ == "__main__":
    main()
