"""Benchmark orchestrator — one function per paper figure/table plus the
framework benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--full] [--engine scan]
    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --bench bench_smoke

Default budgets are CPU-friendly (single core); ``--full`` uses paper-scale
round counts (pair it with ``--engine scan`` so Figs. 5/6 run epoch-fused).
``--bench`` runs registered ``repro.bench`` scenarios (loop vs scan engine,
writes ``BENCH_<name>.json``); ``--list`` shows everything runnable.  The
roofline rows are read from the dry-run artifacts (run
``python -m repro.launch.dryrun --all [--multi-pod]`` first to refresh).
"""
from __future__ import annotations

import argparse

FIGURES = {
    "fig2": "homogeneous p, fully-connected (paper Fig. 2)",
    "fig3": "ring + heterogeneous p (paper Fig. 3)",
    "fig4": "non-IID + server momentum (paper Fig. 4)",
    "fig5": "time-varying channel, adaptive vs stale OPT-α (beyond-paper)",
    "fig6": "client churn over a padded client dim (beyond-paper)",
    "fig_corr": "correlated shadowing + coupled uplink, ℓ sweep "
                "(beyond-paper)",
}


def run_bench_scenarios(
    names: list[str], out_dir: str = ".", trace: bool = False
) -> None:
    """Run registered bench scenarios and print their CSV rows.  ``trace``
    adds a traced pass per engine (TRACE_*.json in ``out_dir``)."""
    from repro.bench import harness, report as report_lib, scenarios

    for name in names:
        spec = scenarios.get_scenario(name)
        result = harness.run_scenario(spec, trace_dir=out_dir if trace else None)
        rep = report_lib.make_report(spec, result)
        path = report_lib.write_report(rep, out_dir)
        for eng, run in sorted(rep["engines"].items()):
            us = 1e6 * run["wall_s"] / spec.rounds
            row = (f"bench/{name}/{eng},{us:.0f},"
                   f"rounds_per_sec={run['rounds_per_sec']:.1f};"
                   f"trace_count={run['trace_count']};"
                   f"dispatches={run['dispatches']}")
            if run.get("overlap_fraction") is not None:
                row += f";overlap_fraction={run['overlap_fraction']:.2f}"
            print(row)
        speedups = ";".join(
            f"speedup_{eng}={ratio:.2f}x"
            for eng, ratio in sorted((rep.get("speedups_vs_loop") or {}).items()))
        print(f"bench/{name}/summary,0,{speedups};"
              f"bitwise_match={rep['bitwise_match']};report={path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow on CPU)")
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "scan", "pipelined"],
                    help="round engine for figs 5/6/corr (scan = "
                         "epoch-fused, pipelined = τ-fused chunks + "
                         "prefetched host work)")
    ap.add_argument("--skip-figures", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="list figure benchmarks and registered bench "
                         "scenarios, then exit")
    ap.add_argument("--bench", action="append", default=[],
                    help="also run a registered repro.bench scenario "
                         "(repeatable); writes BENCH_<name>.json")
    ap.add_argument("--trace", action="store_true",
                    help="with --bench: record traced passes "
                         "(TRACE_<scenario>_<engine>.json)")
    args = ap.parse_args()

    if args.list:
        from repro.bench import scenarios
        from repro.bench.run import format_scenario_line

        print("figure benchmarks:")
        for name, desc in FIGURES.items():
            print(f"  {name:>12}  {desc}")
        print("bench scenarios (--bench NAME / repro.bench.run):")
        for spec in scenarios.list_scenarios():
            print(f"  {format_scenario_line(spec)}")
        return

    rounds = 100 if args.full else 25
    print("name,us_per_call,derived")

    if not args.skip_figures:
        from benchmarks import (fig2_homogeneous, fig3_ring, fig4_noniid,
                                fig5_timevarying, fig6_churn, fig_correlated)

        fig2_homogeneous.run(rounds=rounds, model=args.model)
        fig3_ring.run(rounds=rounds, model=args.model)
        fig4_noniid.run(rounds=rounds, model=args.model)
        fig5_timevarying.run(rounds=rounds, model=args.model,
                             engine=args.engine)
        fig6_churn.run(rounds=rounds, model=args.model, engine=args.engine)
        fig_correlated.run(rounds=rounds, model=args.model,
                           engine=args.engine)

    if args.bench:
        run_bench_scenarios(args.bench, trace=args.trace)

    from benchmarks import bench_opt_alpha, bench_relay_kernel, roofline

    bench_opt_alpha.run()
    bench_relay_kernel.run()
    roofline.run()


if __name__ == "__main__":
    main()
