"""Relay-mix Pallas kernel vs jnp einsum oracle: us/call across model sizes.

On CPU the kernel runs in interpret mode (correctness harness, not speed);
the derived column reports the HBM-traffic model for the TPU target:
faithful relay reads+writes n·D elements, the fused path reads n·D and
writes D — an (n+1)/2-ish traffic reduction the §Perf log exploits."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels import relay_mix as k


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / reps


def run(full: bool = False):
    rows = []
    n = 16
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    tau = jnp.asarray(rng.random(n) < 0.5, jnp.float32)
    # interpret mode executes the kernel body in Python per grid step — keep
    # the default sweep CPU-friendly; --full adds the 2M-element block
    sizes = (1 << 14, 1 << 17) + ((1 << 21,) if full else ())
    for D in sizes:
        d = jnp.asarray(rng.standard_normal((n, D)), jnp.bfloat16)
        us_ref = _time(lambda d: ref.relay_mix_2d(A, d), d)
        us_ker = _time(lambda d: k.relay_mix_2d(A, d, interpret=True), d)
        c = (1.0 / n) * tau @ A
        us_fused = _time(lambda d: k.fused_aggregate_2d(c, d, interpret=True), d)
        bytes_faithful = 2 * n * D * 2  # read + write, bf16
        bytes_fused = (n + 1) * D * 2
        rows.append((f"relay_kernel/D{D}/einsum_ref", us_ref, f"bytes={bytes_faithful}"))
        rows.append((f"relay_kernel/D{D}/pallas_interp", us_ker,
                     f"bytes={bytes_faithful};tpu_est_us={bytes_faithful/819e3:.1f}"))
        rows.append((f"relay_kernel/D{D}/pallas_fused", us_fused,
                     f"bytes={bytes_fused};tpu_est_us={bytes_fused/819e3:.1f}"))
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
