"""Fig. 6 (beyond-paper): ColRel under client churn.

Clients join and leave mid-run over a padded client dimension: a
:class:`repro.channels.ChurnSchedule` composes rotating-cohort membership
(deterministic, reproducible) with bursty Markov link fading and
piecewise-constant drift of the uplink probabilities.  Three policies over
identical data/τ randomness:

  * ``colrel_adaptive`` — re-solves the *masked* OPT-α per epoch (the LRU
    cache keys on the membership mask; departed clients carry zero weight);
  * ``colrel_stale``    — the round-0 A forever (solved on the round-0
    channel *and* membership, so clients absent at solve time never get
    weights), projected onto the live topology and membership;
  * ``fedavg_dropout_blind`` — no relaying, blind 1/n_active averaging.

Claim: adaptive ColRel ≥ FedAvg-blind in final accuracy — relaying keeps
covering the low-p clients that remain, and masked re-optimization keeps the
estimate unbiased over whoever is actually present.  The jitted round step is
traced exactly once: A, p and the mask all enter by value every round.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import FigureResult, make_mlp, print_figure_csv
from repro import channels
from repro.core import connectivity, topology
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import cifar_like
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt


def make_schedule(n: int, *, seed: int = 0) -> channels.ChurnSchedule:
    """The fig-6 channel: ring(n, 2) base with Markov fading, p re-estimated
    every 5 rounds, and one of 5 cohorts offline per 4-round shift — every
    client periodically departs and rejoins."""
    link = channels.MarkovLinkProcess(
        topology.ring(n, 2), p_up_to_down=0.3, p_down_to_up=0.5, seed=seed)
    p_drift = channels.PiecewiseConstantDrift(
        connectivity.heterogeneous_profile(n).p, hold=5, low=0.1, high=0.9,
        seed=seed + 1)
    member = channels.RotatingCohorts(n, n_cohorts=5, hold=4)
    return channels.ChurnSchedule(
        membership=member, link_process=link, p_process=p_drift, adj_every=2)


def run(rounds: int = 30, model: str = "mlp", n: int = 10,
        local_steps: int = 8, local_batch: int = 64, lr: float = 0.1,
        n_train: int = 4000, seed: int = 0, eval_every: int = 2,
        engine: str = "loop"):
    if model != "mlp":
        # fig6 studies churn, not the architecture; see fig5's rationale
        print(f"fig6/skipped,0,reason=churn_study_is_mlp_only;model={model}")
        return {}
    ds = cifar_like(n_train, snr=0.5, seed=seed)
    test = cifar_like(1000, snr=0.5, seed=seed + 99)
    parts = iid_partition(ds, n, seed=seed)
    init, logits_fn, loss = make_mlp()
    test_x, test_y = jnp.asarray(test.inputs), jnp.asarray(test.labels)

    @jax.jit
    def accuracy(params):
        return (jnp.argmax(logits_fn(params, test_x), -1) == test_y).mean()

    policies = {
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "colrel_stale": ("colrel_fused",
                         lambda: channels.StaleOptAlpha(sweeps=40)),
        "colrel_adaptive": ("colrel_fused",
                            lambda: channels.AdaptiveOptAlpha(
                                sweeps=40, warm_sweeps=12)),
    }

    results = {}
    adaptive_stats = None
    for name, (strategy, make_policy) in policies.items():
        schedule = make_schedule(n, seed=seed + 7)  # same channel per policy
        policy = make_policy() if make_policy else None
        loader = FederatedLoader(ds, parts, seed=seed)
        sim = FLSimulator(
            loss, n_clients=n, strategy=strategy, p=None,
            local_steps=local_steps,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
        )
        params = init(jax.random.key(seed))
        ss = sim.init_server_state(params)
        key = jax.random.key(seed + 1)  # same τ stream per policy
        accs = []

        def next_batch():
            return loader.round_batch(local_steps, local_batch)

        t0 = time.time()
        if engine in ("scan", "pipelined"):
            # epoch-fused paper-scale path: membership changes bound the
            # segments, so A, p *and* the churn mask are loop-invariant
            # within each lax.scan; bit-identical to the loop.  chunk
            # matches the ~2-round coherence time (see fig5's rationale).
            # "pipelined" additionally fuses the τ draw into the chunk and
            # prefetches the masked OPT-α re-solves off the critical path.
            cls = EpochScanEngine if engine == "scan" else PipelinedScanEngine
            eng = cls(sim, chunk=2)

            def on_segment(seg, params_, _metrics):
                accs.append((seg.start_round + seg.n_rounds - 1,
                             float(accuracy(params_))))

            params, ss, metrics, _ = eng.run_schedule(
                key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=lr, policy=policy,
                on_segment=on_segment)
            assert eng.trace_count <= 2, \
                f"scan engine retraced: {eng.trace_count}"
        else:
            def on_round(r, params_):
                if r % eval_every == 0 or r == rounds - 1:
                    accs.append((r, float(accuracy(params_))))

            params, ss, metrics, _ = run_rounds_loop(
                sim, key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=lr, policy=policy,
                on_round=on_round)
            assert sim.trace_count == 1, \
                f"round step retraced: {sim.trace_count}"
        losses = [float(x) for x in metrics["loss"]]
        results[name] = FigureResult(name, losses, accs, time.time() - t0)
        if isinstance(policy, channels.AdaptiveOptAlpha):
            adaptive_stats = policy.stats
    print_figure_csv("fig6", results)
    if adaptive_stats is not None:
        s = adaptive_stats
        print(f"fig6/opt_alpha_scheduler,0,rounds={s.rounds};solves={s.solves};"
              f"cache_hits={s.cache_hits};warm_solves={s.warm_solves};"
              f"mean_sweeps={s.mean_sweeps:.1f}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "scan", "pipelined"],
                    help="per-round reference loop, the epoch-fused "
                         "lax.scan engine, or the pipelined engine "
                         "(τ-fused chunks + prefetched host work)")
    a = ap.parse_args()
    run(rounds=a.rounds, engine=a.engine)
