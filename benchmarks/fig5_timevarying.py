"""Fig. 5 (beyond-paper): ColRel under a time-varying channel.

Markov (Gilbert–Elliott) link churn on a ring D2D base graph plus
piecewise-constant drift of the uplink probabilities p(r).  Three policies
over identical data/τ randomness:

  * ``colrel_adaptive`` — re-runs OPT-α per channel epoch (LRU cache +
    warm start; `repro.channels.AdaptiveOptAlpha`);
  * ``colrel_stale``    — the round-0 A forever, projected onto the live
    topology (what a static-channel deployment would do);
  * ``fedavg_dropout_blind`` — no relaying at all.

Claim: adaptive ColRel beats both, because the stale A loses relay mass
(bias) whenever links fade and its weights are wrong for the drifted p.
The jitted round step is traced once — A and p enter by value every round.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import FigureResult, make_mlp, print_figure_csv
from repro import channels
from repro.core import connectivity, topology
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import cifar_like
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt


def make_schedule(n: int, *, seed: int = 0) -> channels.TimeVaryingChannel:
    """The fig-5 channel: ring(n, 2) base with bursty Markov fading and
    p re-estimated (piecewise-constant) every 5 rounds."""
    link = channels.MarkovLinkProcess(
        topology.ring(n, 2), p_up_to_down=0.3, p_down_to_up=0.5, seed=seed)
    p_drift = channels.PiecewiseConstantDrift(
        connectivity.heterogeneous_profile(n).p, hold=5, low=0.1, high=0.9,
        seed=seed + 1)
    # adj_every=2: a 2-round channel coherence time, so consecutive rounds
    # repeat an epoch and the scheduler's LRU cache is exercised.
    return channels.TimeVaryingChannel(link_process=link, p_process=p_drift,
                                       adj_every=2)


def run(rounds: int = 30, model: str = "mlp", n: int = 10,
        local_steps: int = 8, local_batch: int = 64, lr: float = 0.1,
        n_train: int = 4000, seed: int = 0, eval_every: int = 2,
        engine: str = "loop"):
    if model != "mlp":
        # fig5 studies the channel, not the architecture; don't burn minutes
        # re-running it per model in `benchmarks.run --model ...` sweeps.
        print(f"fig5/skipped,0,reason=channel_study_is_mlp_only;model={model}")
        return {}
    ds = cifar_like(n_train, snr=0.5, seed=seed)
    test = cifar_like(1000, snr=0.5, seed=seed + 99)
    parts = iid_partition(ds, n, seed=seed)
    init, logits_fn, loss = make_mlp()
    test_x, test_y = jnp.asarray(test.inputs), jnp.asarray(test.labels)

    @jax.jit
    def accuracy(params):
        return (jnp.argmax(logits_fn(params, test_x), -1) == test_y).mean()

    policies = {
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "colrel_stale": ("colrel_fused",
                         lambda: channels.StaleOptAlpha(sweeps=40)),
        "colrel_adaptive": ("colrel_fused",
                            lambda: channels.AdaptiveOptAlpha(
                                sweeps=40, warm_sweeps=12)),
    }

    results = {}
    adaptive_stats = None
    for name, (strategy, make_policy) in policies.items():
        schedule = make_schedule(n, seed=seed + 7)  # same channel per policy
        policy = make_policy() if make_policy else None
        loader = FederatedLoader(ds, parts, seed=seed)
        sim = FLSimulator(
            loss, n_clients=n, strategy=strategy, p=None,
            local_steps=local_steps,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(),
        )
        params = init(jax.random.key(seed))
        ss = sim.init_server_state(params)
        key = jax.random.key(seed + 1)  # same τ stream per policy
        accs = []

        def next_batch():
            return loader.round_batch(local_steps, local_batch)

        t0 = time.time()
        if engine in ("scan", "pipelined"):
            # epoch-fused paper-scale path: one lax.scan per channel epoch,
            # bit-identical to the loop; accuracy sampled at epoch boundaries.
            # chunk matches the ~2-round coherence time (adj_every=2): a
            # padded chunk computes `chunk` rounds regardless, so chunk >>
            # epoch length would burn compute on masked-out rounds.
            # "pipelined" additionally fuses the τ draw into the chunk and
            # overlaps OPT-α/batch staging with device compute.
            cls = EpochScanEngine if engine == "scan" else PipelinedScanEngine
            eng = cls(sim, chunk=2)

            def on_segment(seg, params_, _metrics):
                accs.append((seg.start_round + seg.n_rounds - 1,
                             float(accuracy(params_))))

            params, ss, metrics, _ = eng.run_schedule(
                key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=lr, policy=policy,
                on_segment=on_segment)
            assert eng.trace_count <= 2, \
                f"scan engine retraced: {eng.trace_count}"
        else:
            def on_round(r, params_):
                if r % eval_every == 0 or r == rounds - 1:
                    accs.append((r, float(accuracy(params_))))

            params, ss, metrics, _ = run_rounds_loop(
                sim, key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=lr, policy=policy,
                on_round=on_round)
            assert sim.trace_count == 1, \
                f"round step retraced: {sim.trace_count}"
        losses = [float(x) for x in metrics["loss"]]
        results[name] = FigureResult(name, losses, accs, time.time() - t0)
        if isinstance(policy, channels.AdaptiveOptAlpha):
            adaptive_stats = policy.stats
    print_figure_csv("fig5", results)
    if adaptive_stats is not None:
        s = adaptive_stats
        print(f"fig5/opt_alpha_scheduler,0,rounds={s.rounds};solves={s.solves};"
              f"cache_hits={s.cache_hits};warm_solves={s.warm_solves};"
              f"mean_sweeps={s.mean_sweeps:.1f}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "scan", "pipelined"],
                    help="per-round reference loop, the epoch-fused "
                         "lax.scan engine, or the pipelined engine "
                         "(τ-fused chunks + prefetched host work)")
    a = ap.parse_args()
    run(rounds=a.rounds, engine=a.engine)
