"""Paper Fig. 3: heterogeneous p = [.1,.2,.3,.1,.1,.5,.8,.1,.2,.9], ring
topology.  Optimized vs unoptimized relay weights are distinguished (the
paper's point: with heterogeneous connectivity, Alg. 3 matters)."""
from __future__ import annotations

from benchmarks.common import print_figure_csv, run_figure
from repro.core import connectivity, opt_alpha, topology


def run(rounds: int = 30, model: str = "mlp"):
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(10, k=1)
    opt = opt_alpha.optimize(p, adj, sweeps=60)
    A0 = opt_alpha.initial_weights(p, adj)
    s0, s1 = opt_alpha.variance_proxy(p, A0), opt.S_history[-1]
    print(f"# fig3 S(p,A): init={s0:.3f} optimized={s1:.3f}")
    strategies = {
        "no_dropout": ("no_dropout", None),
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "fedavg_dropout_nonblind": ("fedavg_nonblind", None),
        "colrel_unoptimized": ("colrel_fused", A0),
        "colrel_optimized": ("colrel_fused", opt.A),
    }
    results = run_figure(p=p, adj=adj, strategies=strategies, rounds=rounds,
                         model=model)
    print_figure_csv("fig3", results)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    a = ap.parse_args()
    run(rounds=a.rounds, model=a.model)
