"""OPT-α (Alg. 3) runtime and variance-reduction benchmark.

Complexity claim: O(L · (n² + K)) per the paper §IV — measured us/sweep
across client counts and topologies, plus the achieved S reduction."""
from __future__ import annotations

import time


from repro.core import connectivity, opt_alpha, topology


def run():
    rows = []
    for n in (10, 32, 64, 128):
        p = connectivity.heterogeneous_profile(n).p
        for topo_name, adj in (("ring2", topology.ring(n, 2)),
                               ("fct", topology.fully_connected(n)),
                               ("er.3", topology.erdos_renyi(n, 0.3, seed=1))):
            A0 = opt_alpha.initial_weights(p, adj)
            s0 = opt_alpha.variance_proxy(p, A0)
            t0 = time.perf_counter()
            res = opt_alpha.optimize(p, adj, sweeps=30)
            dt = time.perf_counter() - t0
            us_per_sweep = 1e6 * dt / max(1, res.sweeps)
            rows.append((f"opt_alpha/n{n}/{topo_name}", us_per_sweep,
                         f"S_init={s0:.3f};S_opt={res.S_history[-1]:.3f};"
                         f"sweeps={res.sweeps};bisect={res.bisection_iters_total}"))
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
