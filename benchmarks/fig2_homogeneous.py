"""Paper Fig. 2: homogeneous connectivity p_i = 0.2, fully-connected topology.

Claims reproduced: (i) ColRel ≈ FedAvg-NoDropout; (ii) both beat
FedAvg-Dropout (blind and non-blind); (iii) Alg. 3's initial weights are
already optimal here, so optimized == unoptimized.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_figure_csv, run_figure
from repro.core import opt_alpha, topology


def run(rounds: int = 30, model: str = "mlp", n: int = 10, p_val: float = 0.2):
    p = np.full(n, p_val)
    adj = topology.fully_connected(n)
    res = opt_alpha.optimize(p, adj, sweeps=40)
    strategies = {
        "no_dropout": ("no_dropout", None),
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "fedavg_dropout_nonblind": ("fedavg_nonblind", None),
        "colrel": ("colrel_fused", res.A),
    }
    results = run_figure(p=p, adj=adj, strategies=strategies, rounds=rounds,
                         model=model)
    print_figure_csv("fig2", results)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    a = ap.parse_args()
    run(rounds=a.rounds, model=a.model)
