"""Fig. corr (beyond-paper): ColRel under *correlated* connectivity.

One latent shadowing field jointly drives node blockage on the D2D graph and
the uplink marginals (``repro.channels.CorrelatedChannel``), and the spatial
correlation length ℓ sweeps the failure regime from independent per-node
fades (ℓ = 0) through neighborhood bursts to one common obstacle that blocks
the whole mesh at once (ℓ = ∞).  The per-node fade statistics are identical
at every ℓ — only the *co-occurrence* of failures changes, which is exactly
the regime where the paper's independent-failure variance analysis is
stressed (journal version arXiv:2202.11850; Parasnis et al. 2303.08988).

Three policies over identical data/τ randomness at every ℓ:

  * ``colrel_adaptive`` — re-solves OPT-α per joint channel epoch;
  * ``colrel_stale``    — the round-0 A forever, projected onto whatever
    edges the blockage leaves standing;
  * ``fedavg_dropout_blind`` — no relaying at all.

Claim (the PR's acceptance bar): mean accuracy over the sweep orders
adaptive ≥ stale ≥ fedavg, and mean final loss orders strictly the other way
— relaying pays even when failures correlate, and re-optimizing for the
current blockage pattern pays on top of that.  (Under *coupled* fading the
stale policy's bias partially self-corrects — a blocked relay's uplink p is
dragged down by the same fade, so its lost stale weight was cheap anyway —
which is why adaptive vs stale separates strictly in loss while their
accuracies can tie at test-set resolution; accuracy is estimated on 1000
samples, so the ordering is asserted at that 1e-3 granularity.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FigureResult, make_mlp, print_figure_csv
from repro import channels
from repro.core import connectivity, topology
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import cifar_like
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

SWEEP = (0.0, 0.2, 0.5, np.inf)  # independent → bursts → fully blocked
HOLD = 2  # channel coherence time in rounds (matches figs. 5/6)


def ell_label(ell: float) -> str:
    return "inf" if np.isinf(ell) else f"{ell:g}"


def make_schedule(n: int, ell: float, *, seed: int = 0):
    """The swept channel: ring(n, 2) base on circle positions, blockage and
    the coupled uplink refreshed jointly every HOLD rounds."""
    return channels.CorrelatedChannel(
        topology.ring(n, 2),
        connectivity.heterogeneous_profile(n).p,
        corr_length=ell,
        rho=0.9,
        blockage_threshold=1.0,
        couple_uplink=True,
        uplink_gain=2.0,
        hold=HOLD,
        seed=seed,
    )


def run(rounds: int = 30, model: str = "mlp", n: int = 10,
        local_steps: int = 8, local_batch: int = 64, lr: float = 0.1,
        n_train: int = 4000, seed: int = 0, engine: str = "loop"):
    if model != "mlp":
        # the sweep studies the channel, not the architecture (fig5 rationale)
        print(f"fig_corr/skipped,0,reason=channel_study_is_mlp_only;"
              f"model={model}")
        return {}
    ds = cifar_like(n_train, snr=0.5, seed=seed)
    test = cifar_like(1000, snr=0.5, seed=seed + 99)
    parts = iid_partition(ds, n, seed=seed)
    init, logits_fn, loss = make_mlp()
    test_x, test_y = jnp.asarray(test.inputs), jnp.asarray(test.labels)

    @jax.jit
    def accuracy(params):
        return (jnp.argmax(logits_fn(params, test_x), -1) == test_y).mean()

    policies = {
        "fedavg_dropout_blind": ("fedavg_blind", None),
        "colrel_stale": ("colrel_fused",
                         lambda: channels.StaleOptAlpha(sweeps=40)),
        "colrel_adaptive": ("colrel_fused",
                            lambda: channels.AdaptiveOptAlpha(
                                sweeps=40, warm_sweeps=12)),
    }

    results = {}
    mean_accs: dict[str, list[float]] = {name: [] for name in policies}
    final_losses: dict[str, list[float]] = {name: [] for name in policies}
    for ell in SWEEP:
        for name, (strategy, make_policy) in policies.items():
            # same channel realization and data/τ stream per policy at this ℓ
            schedule = make_schedule(n, ell, seed=seed + 7)
            policy = make_policy() if make_policy else None
            loader = FederatedLoader(ds, parts, seed=seed)
            sim = FLSimulator(
                loss, n_clients=n, strategy=strategy, p=None,
                local_steps=local_steps,
                client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
                server_opt=ServerOpt(),
            )
            params = init(jax.random.key(seed))
            ss = sim.init_server_state(params)
            key = jax.random.key(seed + 1)
            accs = []

            def next_batch():
                return loader.round_batch(local_steps, local_batch)

            t0 = time.time()
            if engine in ("scan", "pipelined"):
                cls = (EpochScanEngine if engine == "scan"
                       else PipelinedScanEngine)
                eng = cls(sim, chunk=HOLD)

                def on_segment(seg, params_, _metrics):
                    accs.append((seg.start_round + seg.n_rounds - 1,
                                 float(accuracy(params_))))

                params, ss, metrics, _ = eng.run_schedule(
                    key, params, ss, schedule=schedule, rounds=rounds,
                    next_batch=next_batch, lr=lr, policy=policy,
                    on_segment=on_segment)
                assert eng.trace_count <= 2, \
                    f"scan engine retraced: {eng.trace_count}"
            else:
                # evaluate at coherence-interval ends (r = 1, 3, ... for
                # HOLD=2) — the same grid the scan path's segment-end hook
                # uses, so the sweep-mean accuracies are engine-comparable
                def on_round(r, params_):
                    if r % HOLD == HOLD - 1 or r == rounds - 1:
                        accs.append((r, float(accuracy(params_))))

                params, ss, metrics, _ = run_rounds_loop(
                    sim, key, params, ss, schedule=schedule, rounds=rounds,
                    next_batch=next_batch, lr=lr, policy=policy,
                    on_round=on_round)
                assert sim.trace_count == 1, \
                    f"round step retraced: {sim.trace_count}"
            losses = [float(x) for x in metrics["loss"]]
            tag = f"{name}@ell={ell_label(ell)}"
            results[tag] = FigureResult(tag, losses, accs, time.time() - t0)
            mean_accs[name].append(float(np.mean([a for _, a in accs])))
            final_losses[name].append(losses[-1])
    print_figure_csv("fig_corr", results)
    acc_m = {k: float(np.mean(v)) for k, v in mean_accs.items()}
    loss_m = {k: float(np.mean(v)) for k, v in final_losses.items()}
    tol = 1e-3  # accuracy is a 1000-sample estimate: 1e-3 is its resolution
    acc_ordered = (
        acc_m["colrel_adaptive"] >= acc_m["colrel_stale"] - tol
        and acc_m["colrel_stale"] >= acc_m["fedavg_dropout_blind"] - tol
    )
    loss_ordered = (loss_m["colrel_adaptive"] <= loss_m["colrel_stale"]
                    <= loss_m["fedavg_dropout_blind"])
    print("fig_corr/sweep_mean,0,"
          + ";".join(f"acc_{k}={v:.4f}" for k, v in sorted(acc_m.items()))
          + ";"
          + ";".join(f"loss_{k}={v:.4f}" for k, v in sorted(loss_m.items()))
          + f";adaptive_ge_stale_ge_fedavg_acc={acc_ordered}"
          + f";adaptive_le_stale_le_fedavg_loss={loss_ordered}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "scan", "pipelined"],
                    help="per-round reference loop, the epoch-fused "
                         "lax.scan engine, or the pipelined engine "
                         "(τ-fused chunks + prefetched host work)")
    a = ap.parse_args()
    run(rounds=a.rounds, engine=a.engine)
