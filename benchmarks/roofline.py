"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads benchmarks/artifacts/dryrun/<mesh>/*.json and prints, per
(arch × shape × mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO ratio, and peak per-device bytes vs the 16 GB
v5e HBM.  This is the §Roofline source of record; EXPERIMENTS.md embeds its
output.

:func:`relay_table` adds the measured companion: it reads the
``BENCH_relay_sweep_*.json`` reports (repo root; see
``repro.bench.scenarios``) and prints, per model size D, the engine
throughputs, the relay hot spot's bytes/round and arithmetic intensity, and
whether the scenario sits in the dispatch-bound or bandwidth-bound regime —
the measured compute-vs-memory crossover of Δ̃ = A·Δ as D sweeps 10⁴ → 10⁷.
"""
from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HBM_PER_CHIP = 16e9  # v5e


def load(mesh: str) -> list[dict]:
    d = os.path.join(ARTIFACT_DIR, mesh)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def table(mesh: str = "pod16x16", *, csv: bool = True) -> list[str]:
    lines = []
    for rec in load(mesh):
        name = f"roofline/{mesh}/{rec['arch']}/{rec['shape']}"
        if rec.get("relay_mode", "faithful") != "faithful":
            name += f"/{rec['relay_mode']}"
        if rec["status"] == "skipped":
            lines.append(f"{name},0,SKIP:{rec['skip_reason']}")
            continue
        if rec["status"] != "ok":
            lines.append(f"{name},0,ERROR:{rec['error'][:80]}")
            continue
        r = rec["roofline_seconds"]
        dom = rec["bottleneck"]
        step_us = 1e6 * max(r.values())
        peak = rec["per_device"]["peak_bytes"]
        fits = "fits" if peak <= HBM_PER_CHIP else f"OVER_HBM_x{peak / HBM_PER_CHIP:.1f}"
        ratio = rec.get("useful_flops_ratio")
        lines.append(
            f"{name},{step_us:.0f},"
            f"compute={r['compute']:.3e};memory={r['memory']:.3e};"
            f"collective={r['collective']:.3e};bottleneck={dom};"
            f"useful_ratio={ratio if ratio is None else round(ratio, 3)};"
            f"peak_gb={peak / 1e9:.2f};{fits}"
        )
    if csv:
        for line in lines:
            print(line)
    return lines


def load_relay_reports(root: str = REPO_ROOT) -> list[dict]:
    reports = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_relay_sweep_*.json"))):
        with open(path) as fh:
            reports.append(json.load(fh))
    return sorted(reports, key=lambda r: r.get("model_params") or 0)


def relay_table(root: str = REPO_ROOT, *, csv: bool = True) -> list[str]:
    """Measured relay-sweep roofline: one row per recorded D point.

    Per row: D, n, the engine rounds/sec (reference backend + the kernel
    check's backend), the fused reduction's HBM traffic per round
    (read n·D·4 + write D·4 bytes — coeffs and A are noise at these shapes),
    its arithmetic intensity (2·n·D flops over those bytes — the constant
    ≈ 0.5 flop/byte that makes the reduction memory-bound at every D), the
    achieved GB/s implied by the kernel pass, and the regime: rows whose
    per-round time tracks the smallest-D row's are **dispatch-bound** (fixed
    overhead dominates); rows whose time scales with D are
    **traffic-bound** — the crossover is where the regime flips.
    """
    reports = load_relay_reports(root)
    lines = []
    base_round_s = None
    for rep in reports:
        spec = rep.get("spec", {})
        n = spec.get("n_clients", 0)
        D = rep.get("model_params") or 0
        engines = rep.get("engines", {})
        check = rep.get("kernel_check") or {}
        kname = f"scan_{check['backend']}" if check else None
        krps = engines.get(kname, {}).get("rounds_per_sec") if kname else None
        scan_rps = engines.get("scan", {}).get("rounds_per_sec")
        loop_rps = engines.get("loop", {}).get("rounds_per_sec")
        rps = krps or scan_rps or loop_rps
        if not rps or not D or not n:
            continue
        round_s = 1.0 / rps
        if base_round_s is None:
            base_round_s = round_s
        bytes_round = 4.0 * (n * D + D)  # fused reduce: read Δ, write u
        flops_round = 2.0 * n * D
        intensity = flops_round / bytes_round
        gbs = bytes_round * rps / 1e9
        regime = (
            "dispatch-bound" if round_s < 3.0 * base_round_s else "traffic-bound"
        )
        lines.append(
            f"relay/{rep['scenario']},D={D},n={n},"
            f"loop_rps={0.0 if loop_rps is None else loop_rps:.1f},"
            f"scan_rps={0.0 if scan_rps is None else scan_rps:.1f},"
            f"kernel_rps={0.0 if krps is None else krps:.1f},"
            f"bytes_per_round={bytes_round:.3e},"
            f"intensity_flop_per_byte={intensity:.3f},"
            f"achieved_gbs={gbs:.2f},"
            f"max_abs_diff={check.get('max_abs_diff', 0.0):.2e},"
            f"{regime}"
        )
    if csv:
        for line in lines:
            print(line)
    return lines


def run():
    out = []
    for mesh in ("pod16x16", "pod2x16x16"):
        out += table(mesh)
    out += relay_table()
    return out


if __name__ == "__main__":
    run()
