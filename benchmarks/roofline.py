"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads benchmarks/artifacts/dryrun/<mesh>/*.json and prints, per
(arch × shape × mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO ratio, and peak per-device bytes vs the 16 GB
v5e HBM.  This is the §Roofline source of record; EXPERIMENTS.md embeds its
output."""
from __future__ import annotations

import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
HBM_PER_CHIP = 16e9  # v5e


def load(mesh: str) -> list[dict]:
    d = os.path.join(ARTIFACT_DIR, mesh)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def table(mesh: str = "pod16x16", *, csv: bool = True) -> list[str]:
    lines = []
    for rec in load(mesh):
        name = f"roofline/{mesh}/{rec['arch']}/{rec['shape']}"
        if rec.get("relay_mode", "faithful") != "faithful":
            name += f"/{rec['relay_mode']}"
        if rec["status"] == "skipped":
            lines.append(f"{name},0,SKIP:{rec['skip_reason']}")
            continue
        if rec["status"] != "ok":
            lines.append(f"{name},0,ERROR:{rec['error'][:80]}")
            continue
        r = rec["roofline_seconds"]
        dom = rec["bottleneck"]
        step_us = 1e6 * max(r.values())
        peak = rec["per_device"]["peak_bytes"]
        fits = "fits" if peak <= HBM_PER_CHIP else f"OVER_HBM_x{peak / HBM_PER_CHIP:.1f}"
        ratio = rec.get("useful_flops_ratio")
        lines.append(
            f"{name},{step_us:.0f},"
            f"compute={r['compute']:.3e};memory={r['memory']:.3e};"
            f"collective={r['collective']:.3e};bottleneck={dom};"
            f"useful_ratio={ratio if ratio is None else round(ratio, 3)};"
            f"peak_gb={peak / 1e9:.2f};{fits}"
        )
    if csv:
        for line in lines:
            print(line)
    return lines


def run():
    out = []
    for mesh in ("pod16x16", "pod2x16x16"):
        out += table(mesh)
    return out


if __name__ == "__main__":
    run()
