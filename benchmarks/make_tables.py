"""Emit the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run artifacts (optimized current state + v0 baselines)."""
from __future__ import annotations

import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    recs = {}
    d = os.path.join(ART, mesh)
    for f in sorted(os.listdir(d)):
        if f.endswith(".json") and "__fused" not in f:
            r = json.load(open(os.path.join(d, f)))
            recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_sec(x):
    return f"{x:.2e}"


def roofline_table(mesh, title):
    recs = load(mesh)
    print(f"\n#### {title}\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck | "
          "MODEL_FLOPS/HLO | peak GB/dev | fits 16 GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    archs = sorted({a for a, _ in recs})
    for a in archs:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | — | — | — | skip (documented) |")
                continue
            t = r["roofline_seconds"]
            ratio = r.get("useful_flops_ratio")
            peak = r["per_device"]["peak_bytes"] / 1e9
            fits = "yes" if peak <= 16 else f"no ({peak/16:.1f}×)"
            print(f"| {a} | {s} | {fmt_sec(t['compute'])} | {fmt_sec(t['memory'])} | "
                  f"{fmt_sec(t['collective'])} | {t and r['bottleneck']} | "
                  f"{ratio:.3f} | {peak:.2f} | {fits} |")


def dryrun_summary():
    print("\n#### Status matrix (lower+compile)\n")
    print("| arch | " + " | ".join(
        f"{s} 1pod / 2pod" for s in SHAPE_ORDER) + " |")
    print("|---|" + "---|" * len(SHAPE_ORDER))
    one, two = load("pod16x16"), load("pod2x16x16")
    archs = sorted({a for a, _ in one})
    for a in archs:
        cells = []
        for s in SHAPE_ORDER:
            r1, r2 = one.get((a, s)), two.get((a, s))
            def st(r):
                if r is None:
                    return "—"
                return {"ok": "✓", "skipped": "skip", "error": "✗"}[r["status"]]
            cells.append(f"{st(r1)} / {st(r2)}")
        print(f"| {a} | " + " | ".join(cells) + " |")
    n_ok = sum(r["status"] == "ok" for r in list(one.values()) + list(two.values()))
    n_skip = sum(r["status"] == "skipped" for r in list(one.values()) + list(two.values()))
    print(f"\n80 combinations: **{n_ok} compile green, {n_skip} documented skips, "
          f"{80 - n_ok - n_skip} errors**.")


def baseline_vs_opt():
    base = load("pod16x16_baseline_v0")
    cur = load("pod16x16")
    print("\n#### Baseline → optimized (all 40 pairs, single pod)\n")
    print("| arch | shape | coll s (v0→opt) | memory s (v0→opt) | peak GB (v0→opt) |")
    print("|---|---|---|---|---|")
    for (a, s), r0 in sorted(base.items()):
        r1 = cur.get((a, s))
        if r0["status"] != "ok" or r1 is None or r1["status"] != "ok":
            continue
        t0, t1 = r0["roofline_seconds"], r1["roofline_seconds"]
        p0 = r0["per_device"]["peak_bytes"] / 1e9
        p1 = r1["per_device"]["peak_bytes"] / 1e9
        print(f"| {a} | {s} | {t0['collective']:.1f} → {t1['collective']:.1f} | "
              f"{t0['memory']:.1f} → {t1['memory']:.1f} | {p0:.1f} → {p1:.1f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "status"):
        dryrun_summary()
    if which in ("all", "roofline"):
        roofline_table("pod16x16", "Single pod 16×16 (roofline of record, optimized)")
    if which in ("all", "baseline"):
        roofline_table("pod16x16_baseline_v0", "Single pod 16×16 — paper-faithful baseline (v0)")
        baseline_vs_opt()
    if which in ("all", "multipod"):
        roofline_table("pod2x16x16", "Multi-pod 2×16×16")
