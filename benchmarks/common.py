"""Shared harness for the paper-figure benchmarks (Figs. 2-4).

Each figure benchmark trains the same model under several aggregation
strategies over identical data/τ randomness and reports final losses and
accuracies.  Models: ``resnet20`` (paper-faithful, slow on CPU) or ``mlp``
(CIFAR-shaped data flattened; fast, same protocol behaviour).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as creg
from repro.core.aggregation import ServerOpt
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition, sort_and_partition
from repro.data.synthetic import cifar_like
from repro.fl.simulator import FLSimulator
from repro.models import registry as mreg
from repro.optim.sgd import ClientOpt


@dataclasses.dataclass
class FigureResult:
    strategy: str
    losses: list
    accs: list
    seconds: float


def make_mlp(dim=3072, width=256, n_classes=10):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (dim, width)) * dim**-0.5,
            "b1": jnp.zeros((width,)),
            "w2": jax.random.normal(k2, (width, n_classes)) * width**-0.5,
            "b2": jnp.zeros((n_classes,)),
        }

    def logits(params, images):
        x = images.reshape(images.shape[0], -1)
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(params, batch):
        lg = logits(params, batch["images"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    return init, logits, loss


def run_figure(
    *,
    p: np.ndarray,
    adj: np.ndarray,
    strategies: dict,
    non_iid: bool = False,
    server_momentum: float = 0.0,
    model: str = "mlp",
    rounds: int = 30,
    local_steps: int = 8,
    local_batch: int = 64,
    lr: float = 0.1,
    n_train: int = 4000,
    seed: int = 0,
    eval_every: int = 2,
) -> dict[str, FigureResult]:
    n = len(p)
    ds = cifar_like(n_train, snr=0.5, seed=seed)
    test = cifar_like(1000, snr=0.5, seed=seed + 99)
    parts = (sort_and_partition(ds, n, shards_per_client=1, seed=seed)
             if non_iid else iid_partition(ds, n, seed=seed))

    if model == "resnet20":
        cfg = creg.get_config("resnet20-cifar")
        md = mreg.get_model(cfg)
        init, loss = md.init, md.loss
        from repro.models.resnet import resnet20_logits

        def logits_fn(params, images):
            return resnet20_logits(params, cfg, images)
    else:
        init, logits_fn, loss = make_mlp()

    test_x, test_y = jnp.asarray(test.inputs), jnp.asarray(test.labels)

    @jax.jit
    def accuracy(params):
        return (jnp.argmax(logits_fn(params, test_x), -1) == test_y).mean()

    results = {}
    for name, (strategy, A) in strategies.items():
        loader = FederatedLoader(ds, parts, seed=seed)  # same data order per strategy
        sim = FLSimulator(
            loss, n_clients=n, strategy=strategy, A=A, p=p,
            local_steps=local_steps,
            client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
            server_opt=ServerOpt(momentum=server_momentum),
        )
        params = init(jax.random.key(seed))
        ss = sim.init_server_state(params)
        key = jax.random.key(seed + 1)  # same τ stream per strategy
        losses, accs = [], []
        t0 = time.time()
        for r in range(rounds):
            key, sub = jax.random.split(key)
            batch = loader.round_batch(local_steps, local_batch)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, ss, m = sim.run_round(sub, params, ss, batch, lr)
            losses.append(float(m["loss"]))
            if r % eval_every == 0 or r == rounds - 1:
                accs.append((r, float(accuracy(params))))
        results[name] = FigureResult(name, losses, accs, time.time() - t0)
    return results


def rounds_to(res: FigureResult, threshold: float):
    for r, a in res.accs:
        if a >= threshold:
            return r
    return None


def print_figure_csv(figure: str, results: dict[str, FigureResult]):
    """The paper's Figs. 2-4 are accuracy-vs-round curves; the derived column
    carries the curve summary (early accuracy, rounds-to-90%, final loss —
    convergence *rate* is the claim under test)."""
    for name, res in results.items():
        final_acc = res.accs[-1][1]
        early = res.accs[1][1] if len(res.accs) > 1 else res.accs[0][1]
        r90 = rounds_to(res, 0.90)
        us = 1e6 * res.seconds / max(1, len(res.losses))
        print(f"{figure}/{name},{us:.0f},acc_early={early:.3f};"
              f"rounds_to_90pct={r90};final_acc={final_acc:.3f};"
              f"final_loss={res.losses[-1]:.4f}")
