"""Quickstart: the ColRel protocol in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Ten clients with intermittent uplinks (the paper's heterogeneous p-vector),
a ring D2D graph, OPT-α relay weights, and 30 federated rounds of a linear
classifier — ColRel vs blind FedAvg-with-dropout vs the no-dropout upper
bound."""
import jax
import jax.numpy as jnp

from repro.core import connectivity, opt_alpha, topology
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import gaussian_classification
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

N_CLIENTS, DIM, CLASSES, ROUNDS = 10, 64, 10, 12

# 1. Connectivity model + D2D topology (paper Fig. 3 setting)
conn = connectivity.paper_heterogeneous()
adj = topology.ring(N_CLIENTS, k=1)

# 2. OPT-α: minimize the variance proxy S(p, A) s.t. unbiasedness (Alg. 3)
res = opt_alpha.optimize(conn.p, adj, sweeps=50)
print(f"OPT-α: S {res.S_history[0]:.2f} -> {res.S_history[-1]:.2f} "
      f"in {res.sweeps} Gauss-Seidel sweeps")

# 3. Data: IID synthetic classification, partitioned over clients
ds = gaussian_classification(4000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=0)
test = gaussian_classification(1000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=1)


def loss_fn(params, batch):
    logits = batch["inputs"] @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params):
    logits = jnp.asarray(test.inputs) @ params["w"] + params["b"]
    return float((jnp.argmax(logits, -1) == jnp.asarray(test.labels)).mean())


# 4. Run the protocol under three aggregation strategies
for strategy, A in [("no_dropout", None), ("fedavg_blind", None),
                    ("colrel", res.A)]:
    sim = FLSimulator(loss_fn, n_clients=N_CLIENTS, strategy=strategy, A=A,
                      p=conn.p, local_steps=4,
                      client_opt=ClientOpt(kind="sgd", weight_decay=1e-4))
    loader = FederatedLoader(ds, iid_partition(ds, N_CLIENTS, seed=0), seed=0)
    params = {"w": jnp.zeros((DIM, CLASSES)), "b": jnp.zeros((CLASSES,))}
    state = sim.init_server_state(params)
    key = jax.random.key(42)
    acc5 = None
    for r in range(ROUNDS):
        key, sub = jax.random.split(key)
        batch = loader.round_batch(4, 16)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, m = sim.run_round(sub, params, state, batch, lr=0.5)
        if r == 4:
            acc5 = accuracy(params)
    print(f"{strategy:14s} acc@5={acc5:.3f} acc@{ROUNDS}={accuracy(params):.3f} "
          f"final_train_loss={float(m['loss']):.4f}")
