"""Correlated-connectivity quickstart: when failures come in bursts.

    PYTHONPATH=src python examples/correlated_shadowing.py

Ten clients on a ring, embedded on a circle.  One latent shadowing field
(AR(1) in time, Gaussian-process over the positions in space) drives the
whole channel: a node in deep shadow loses *all* its D2D edges at once, and
— because the uplink rides the same fade — its p_i collapses in the same
round.  ``(adj, p)`` are jointly sampled, unlike the independent per-edge
chains of `examples/timevarying_channel.py`.

The adaptive OPT-α scheduler re-solves only at joint epoch boundaries (LRU
cache on the full (adj, p) value + warm starts), and the jitted round step
never retraces: the correlated channel is still value-only traffic.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import channels
from repro.core import connectivity, topology
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import gaussian_classification
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

N_CLIENTS, DIM, CLASSES, ROUNDS = 10, 64, 10, 24

# 1. The channel: one latent field → blockage + coupled uplink.
#    corr_length=0.4 on the unit-square circle embedding couples each node
#    to ~2 neighbors a side; try 0.0 (independent) or np.inf (one obstacle
#    blocks the whole mesh at once) to move along the sweep of
#    benchmarks/fig_correlated.py.
schedule = channels.CorrelatedChannel(
    topology.ring(N_CLIENTS, 2),
    connectivity.paper_heterogeneous().p,
    corr_length=0.4,
    rho=0.9,
    blockage_threshold=1.0,
    couple_uplink=True,
    uplink_gain=2.0,
    hold=3,  # 3-round coherence time → 3-round epochs for the scheduler
    seed=3,
)
policy = channels.AdaptiveOptAlpha(sweeps=40, warm_sweeps=12)

# 2. Data + model (same linear classifier as quickstart.py)
ds = gaussian_classification(4000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=0)
test = gaussian_classification(1000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=1)


def loss_fn(params, batch):
    logits = batch["inputs"] @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params):
    logits = jnp.asarray(test.inputs) @ params["w"] + params["b"]
    return float((jnp.argmax(logits, -1) == jnp.asarray(test.labels)).mean())


# 3. Run: blocked nodes lose their edges *and* their uplink together; the
#    compiled step sees only fresh (A, p) values every round.
sim = FLSimulator(loss_fn, n_clients=N_CLIENTS, strategy="colrel_fused",
                  local_steps=4,
                  client_opt=ClientOpt(kind="sgd", weight_decay=1e-4))
loader = FederatedLoader(ds, iid_partition(ds, N_CLIENTS, seed=0), seed=0)
params = {"w": jnp.zeros((DIM, CLASSES)), "b": jnp.zeros((CLASSES,))}
state = sim.init_server_state(params)
key = jax.random.key(42)
last_epoch = -1
for r, ch in enumerate(schedule.rounds(ROUNDS)):
    A = policy.relay_matrix(ch)
    key, sub = jax.random.split(key)
    batch = loader.round_batch(4, 16)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, state, m = sim.run_round(sub, params, state, batch, 0.5,
                                     A=A, p=ch.p)
    if ch.epoch_id != last_epoch:
        last_epoch = ch.epoch_id
        blocked = np.nonzero(schedule.blocked)[0].tolist()
        print(f"round {r:3d}  epoch {ch.epoch_id:2d}  "
              f"links={int(ch.adj.sum()) // 2:2d}  "
              f"blocked={list(blocked)!s:12s}  "
              f"mean_p={float(ch.p.mean()):.2f}  "
              f"loss={float(m['loss']):.4f}")

s = policy.stats
print(f"\nacc@{ROUNDS}={accuracy(params):.3f}  "
      f"epochs={last_epoch + 1}  opt_alpha_solves={s.solves} "
      f"(cache_hits={s.cache_hits}, warm={s.warm_solves}, "
      f"mean_sweeps={s.mean_sweeps:.1f})  traces={sim.trace_count}")
assert sim.trace_count == 1  # joint channel dynamics are values, not shapes
