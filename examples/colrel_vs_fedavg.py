"""Paper Fig. 3/4 style comparison with full accuracy curves (CSV out).

    PYTHONPATH=src python examples/colrel_vs_fedavg.py --rounds 30 [--non-iid]

Writes round-by-round test accuracy per strategy to stdout and (optionally)
a CSV file — the data behind the paper's accuracy-vs-round figures."""
import argparse
import sys


sys.path.insert(0, ".")  # allow `python examples/...` from repo root
from benchmarks.common import run_figure  # noqa: E402
from repro.core import connectivity, opt_alpha, topology  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet20"])
    ap.add_argument("--csv", default="")
    args = ap.parse_args()

    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(10, k=2 if args.non_iid else 1)
    opt = opt_alpha.optimize(p, adj, sweeps=60)
    A0 = opt_alpha.initial_weights(p, adj)
    print(f"S(p,A): init {opt_alpha.variance_proxy(p, A0):.3f} -> "
          f"optimized {opt.S_history[-1]:.3f}")

    results = run_figure(
        p=p, adj=adj,
        strategies={
            "no_dropout": ("no_dropout", None),
            "fedavg_blind": ("fedavg_blind", None),
            "fedavg_nonblind": ("fedavg_nonblind", None),
            "colrel_unopt": ("colrel_fused", A0),
            "colrel_opt": ("colrel_fused", opt.A),
        },
        rounds=args.rounds, non_iid=args.non_iid,
        server_momentum=0.9 if args.non_iid else 0.0, model=args.model,
    )

    names = list(results)
    rows = ["round," + ",".join(names)]
    n_evals = len(results[names[0]].accs)
    for i in range(n_evals):
        r = results[names[0]].accs[i][0]
        rows.append(f"{r}," + ",".join(f"{results[nm].accs[i][1]:.4f}" for nm in names))
    out = "\n".join(rows)
    print(out)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(out + "\n")
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
