"""Serving example: batched prefill + sampled decode with the KV-cache /
SSM-state machinery (the same serve_step the dry-run lowers at 32k/500k).

    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b \
        --restore checkpoints/train_lm.npz   # serve a ColRel-trained model
"""
import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")
from repro import checkpoint  # noqa: E402
from repro.configs import registry as creg  # noqa: E402
from repro.models import registry as mreg  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=sorted(creg.ASSIGNED))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--restore", default="")
    args = ap.parse_args()

    cfg = creg.get_config(args.arch, reduced=True)
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    if args.restore:
        params = checkpoint.restore(args.restore, params)

    B, S = args.batch, args.prompt_len
    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model))

    prefill = jax.jit(md.prefill)
    decode = jax.jit(md.decode)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(key, logits):
        return jax.random.categorical(key, logits[:, -1] / args.temperature)[:, None]

    key, sub = jax.random.split(key)
    tok = sample(sub, logits)
    outs = [np.asarray(tok)]
    t1 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        key, sub = jax.random.split(key)
        tok = sample(sub, logits)
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.concatenate(outs, axis=1)
    print(f"{args.arch}: prefill {B}x{S} in {t_prefill:.2f}s | "
          f"{args.new_tokens} decode steps in {t_decode:.2f}s "
          f"({B * args.new_tokens / max(t_decode, 1e-9):.1f} tok/s aggregate)")
    for b in range(min(B, 4)):
        print(f"  request {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
