"""Time-varying channel quickstart: ColRel when the network won't sit still.

    PYTHONPATH=src python examples/timevarying_channel.py

Ten clients on random-waypoint trajectories (D2D neighbors = within radio
range), uplink probabilities drifting as a reflected random walk.  A
`ChannelSchedule` streams one (adj, p, epoch) per round; the adaptive OPT-α
scheduler re-optimizes the relay matrix only on epoch changes, warm-started
from the previous optimum — and the jitted round step never retraces because
A and p enter by value.
"""
import jax
import jax.numpy as jnp

from repro import channels
from repro.core import connectivity
from repro.data.loader import FederatedLoader
from repro.data.partition import iid_partition
from repro.data.synthetic import gaussian_classification
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

N_CLIENTS, DIM, CLASSES, ROUNDS = 10, 64, 10, 20

# 1. The channel: mobility-driven topology + drifting uplink probabilities
mobility = channels.RandomWaypointMobility(
    N_CLIENTS, radius=0.45, speed=0.08, seed=3)
drift = channels.RandomWalkDrift(
    connectivity.paper_heterogeneous().p, sigma=0.03, seed=4)
schedule = channels.TimeVaryingChannel(link_process=mobility, p_process=drift)
policy = channels.AdaptiveOptAlpha(sweeps=40, warm_sweeps=12)

# 2. Data + model (same linear classifier as quickstart.py)
ds = gaussian_classification(4000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=0)
test = gaussian_classification(1000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=1)


def loss_fn(params, batch):
    logits = batch["inputs"] @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params):
    logits = jnp.asarray(test.inputs) @ params["w"] + params["b"]
    return float((jnp.argmax(logits, -1) == jnp.asarray(test.labels)).mean())


# 3. Run: the channel stream drives per-round (A, p); one compiled step
sim = FLSimulator(loss_fn, n_clients=N_CLIENTS, strategy="colrel_fused",
                  local_steps=4,
                  client_opt=ClientOpt(kind="sgd", weight_decay=1e-4))
loader = FederatedLoader(ds, iid_partition(ds, N_CLIENTS, seed=0), seed=0)
params = {"w": jnp.zeros((DIM, CLASSES)), "b": jnp.zeros((CLASSES,))}
state = sim.init_server_state(params)
key = jax.random.key(42)
last_epoch = -1
for r, ch in enumerate(schedule.rounds(ROUNDS)):
    A = policy.relay_matrix(ch)
    key, sub = jax.random.split(key)
    batch = loader.round_batch(4, 16)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, state, m = sim.run_round(sub, params, state, batch, 0.5,
                                     A=A, p=ch.p)
    if ch.epoch_id != last_epoch:
        last_epoch = ch.epoch_id
        print(f"round {r:3d}  epoch {ch.epoch_id:3d}  "
              f"links={int(ch.adj.sum()) // 2:2d}  "
              f"mean_p={float(ch.p.mean()):.2f}  "
              f"loss={float(m['loss']):.4f}")

s = policy.stats
print(f"\nacc@{ROUNDS}={accuracy(params):.3f}  "
      f"epochs={last_epoch + 1}  opt_alpha_solves={s.solves} "
      f"(warm={s.warm_solves}, mean_sweeps={s.mean_sweeps:.1f})  "
      f"traces={sim.trace_count}")
assert sim.trace_count == 1
