"""End-to-end driver: federally train a transformer LM with ColRel.

    PYTHONPATH=src python examples/train_lm.py --rounds 200          # ~25M
    PYTHONPATH=src python examples/train_lm.py --preset 100m --rounds 300

The model is the qwen3 family (GQA + qk-norm) scaled to the requested
parameter budget; data is the synthetic affine-recurrence token stream
(per-client stream skew = non-IID); the protocol is the full paper stack:
OPT-α weights → T local steps → D2D relay → blind τ-masked PS aggregation →
global momentum.  Checkpoints + perplexity eval included."""
import argparse
import functools
print = functools.partial(print, flush=True)
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro import checkpoint  # noqa: E402
from repro.configs import registry as creg  # noqa: E402
from repro.core import connectivity, opt_alpha, topology  # noqa: E402
from repro.core.aggregation import ServerOpt  # noqa: E402
from repro.data.loader import FederatedLoader  # noqa: E402
from repro.data.partition import sort_and_partition  # noqa: E402
from repro.data.synthetic import lm_tokens  # noqa: E402
from repro.fl.simulator import FLSimulator  # noqa: E402
from repro.models import registry as mreg  # noqa: E402
from repro.optim.sgd import ClientOpt  # noqa: E402

PRESETS = {
    # name: (n_layers, d_model, n_heads, n_kv, d_ff, vocab) ≈ params
    "3m": (4, 192, 4, 2, 512, 2048),       # fast CI-scale
    "25m": (8, 448, 8, 4, 1536, 8192),     # default: minutes on CPU
    "100m": (12, 768, 12, 4, 2688, 16384), # the "~100M for a few hundred steps" driver
}


def build_cfg(preset: str):
    L, d, h, kv, f, v = PRESETS[preset]
    base = creg.get_config("qwen3-14b")
    return dataclasses.replace(
        base, name=f"qwen3-{preset}", n_layers=L, d_model=d, n_heads=h,
        n_kv=kv, head_dim=d // h, d_ff=f, vocab=v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=sorted(PRESETS))
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--strategy", default="colrel_fused")
    ap.add_argument("--checkpoint", default="checkpoints/train_lm.npz")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    n = args.clients
    conn = connectivity.heterogeneous_profile(n)
    adj = topology.ring(n, k=2)
    res = opt_alpha.optimize(conn.p, adj, sweeps=50)
    print(f"OPT-α: S {res.S_history[0]:.2f} -> {res.S_history[-1]:.2f}")

    # one draw of stream coefficients; the last 64 sequences are held out
    from repro.data.synthetic import ArrayDataset
    full = lm_tokens(2048 + 64, args.seq_len, vocab=cfg.vocab, n_streams=n, seed=0)
    ds = ArrayDataset(full.inputs[:2048], full.labels[:2048])
    held = ArrayDataset(full.inputs[2048:], full.labels[2048:])
    parts = sort_and_partition(ds, n, shards_per_client=2, seed=0)
    loader = FederatedLoader(ds, parts, seed=0)

    @jax.jit
    def eval_loss(params):
        b = {"tokens": jnp.asarray(held.inputs[:, :-1]),
             "labels": jnp.asarray(held.inputs[:, 1:])}
        return md.loss(params, b)

    sim = FLSimulator(
        md.loss, n_clients=n, strategy=args.strategy,
        A=res.A if args.strategy.startswith("colrel") else None, p=conn.p,
        local_steps=args.local_steps,
        client_opt=ClientOpt(kind="sgd", weight_decay=1e-4),
        server_opt=ServerOpt(momentum=0.9))
    state = sim.init_server_state(params)
    key = jax.random.key(1)
    t0 = time.time()
    for r in range(args.rounds):
        key, sub = jax.random.split(key)
        batch = loader.round_batch(args.local_steps, args.local_batch, lm=True)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, m = sim.run_round(sub, params, state, batch, args.lr)
        if r % args.log_every == 0 or r == args.rounds - 1:
            ev = float(eval_loss(params))
            print(f"round {r:4d} train_loss={float(m['loss']):.4f} "
                  f"eval_loss={ev:.4f} ppl={np.exp(min(ev, 20)):.1f} "
                  f"tau_up={int(np.asarray(m['tau']).sum())}/{n} "
                  f"({time.time()-t0:.0f}s)")
    if args.checkpoint:
        checkpoint.save(args.checkpoint, params,
                        metadata={"preset": args.preset, "rounds": args.rounds,
                                  "strategy": args.strategy})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
