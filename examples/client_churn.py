"""Client churn quickstart: ColRel when clients come and go mid-run.

    PYTHONPATH=src python examples/client_churn.py

Ten padded client slots; every few rounds one cohort departs and another
rejoins (rotating shifts), while D2D links fade on a Markov chain.  A
`ChurnSchedule` streams one (adj, p, active, epoch) per round; the adaptive
OPT-α scheduler re-solves the *masked* relay problem per epoch (departed
clients carry zero weight, unbiasedness holds over whoever is present), and
the jitted round step never retraces — A, p and the membership mask all
enter by value.  Compare against blind FedAvg on the identical channel: the
data is non-IID (one class shard per client), so a departing or
badly-connected client takes its classes with it — unless its neighbors
relay its update to the PS.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import channels
from repro.core import connectivity, topology
from repro.data.loader import FederatedLoader
from repro.data.partition import sort_and_partition
from repro.data.synthetic import gaussian_classification
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

N_MAX, DIM, CLASSES, ROUNDS = 10, 32, 10, 12


def make_schedule():
    """Markov-fading ring + one of 5 cohorts offline per 3-round shift."""
    link = channels.MarkovLinkProcess(
        topology.ring(N_MAX, 2), p_up_to_down=0.3, p_down_to_up=0.5, seed=7)
    return channels.ChurnSchedule(
        membership=channels.RotatingCohorts(N_MAX, n_cohorts=5, hold=3),
        link_process=link,
        p=connectivity.paper_heterogeneous().p,
        adj_every=2)


# Data + model (same linear classifier as quickstart.py)
ds = gaussian_classification(4000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=0)
test = gaussian_classification(1000, dim=DIM, n_classes=CLASSES, snr=0.8, seed=1)


def loss_fn(params, batch):
    logits = batch["inputs"] @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params):
    logits = jnp.asarray(test.inputs) @ params["w"] + params["b"]
    return float((jnp.argmax(logits, -1) == jnp.asarray(test.labels)).mean())


def train(strategy: str, policy=None) -> float:
    schedule = make_schedule()  # identical channel for both runs
    sim = FLSimulator(loss_fn, n_clients=N_MAX, strategy=strategy,
                      local_steps=4,
                      client_opt=ClientOpt(kind="sgd", weight_decay=1e-4))
    loader = FederatedLoader(
        ds, sort_and_partition(ds, N_MAX, shards_per_client=1, seed=0), seed=0)
    params = {"w": jnp.zeros((DIM, CLASSES)), "b": jnp.zeros((CLASSES,))}
    state = sim.init_server_state(params)
    key = jax.random.key(42)
    last_epoch = -1
    for r, ch in enumerate(schedule.rounds(ROUNDS)):
        A = policy.relay_matrix(ch) if policy else None
        key, sub = jax.random.split(key)
        batch = loader.round_batch(4, 16)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, m = sim.run_round(sub, params, state, batch, 0.5,
                                         A=A, p=ch.p, active=ch.active)
        if policy and ch.epoch_id != last_epoch:
            last_epoch = ch.epoch_id
            away = np.nonzero(~ch.active)[0].tolist()
            print(f"round {r:3d}  epoch {ch.epoch_id:3d}  "
                  f"away={away}  links={int(ch.adj.sum()) // 2:2d}  "
                  f"loss={float(m['loss']):.4f}")
    assert sim.trace_count == 1, "membership changes must not retrace"
    return accuracy(params)


print("=== adaptive ColRel under churn ===")
policy = channels.AdaptiveOptAlpha(sweeps=40, warm_sweeps=12)
acc_colrel = train("colrel_fused", policy)
s = policy.stats
print(f"\n=== blind FedAvg on the identical channel ===")
acc_fedavg = train("fedavg_blind")

print(f"\nacc@{ROUNDS}: adaptive_colrel={acc_colrel:.3f}  "
      f"fedavg_blind={acc_fedavg:.3f}")
print(f"opt_alpha_solves={s.solves} (warm={s.warm_solves}, "
      f"cache_hits={s.cache_hits}, mean_sweeps={s.mean_sweeps:.1f})")
assert acc_colrel >= acc_fedavg, (acc_colrel, acc_fedavg)
print("adaptive ColRel ≥ FedAvg-blind under churn ✓")
