import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, connectivity, opt_alpha, relay, topology


@pytest.fixture()
def setting():
    n = 10
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(n, 1)
    A = opt_alpha.optimize(p, adj, sweeps=30).A
    rng = np.random.default_rng(0)
    upd = {
        "w": jnp.asarray(rng.standard_normal((n, 6, 4)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
    }
    tau = jnp.asarray(rng.random(n) < p, jnp.float32)
    return n, p, adj, A, upd, tau


def test_relay_matches_manual_sum(setting):
    n, p, adj, A, upd, tau = setting
    out = relay.relay(A, upd)
    for r in range(n):
        want = sum(A[r, o] * np.asarray(upd["w"][o]) for o in range(n))
        np.testing.assert_allclose(np.asarray(out["w"][r]), want, rtol=1e-5)


def test_fused_equals_faithful(setting):
    n, p, adj, A, upd, tau = setting
    faithful = aggregation.colrel_increment(A, tau, upd, n=n, fused=False)
    fused = aggregation.colrel_increment(A, tau, upd, n=n, fused=True)
    for k in upd:
        np.testing.assert_allclose(
            np.asarray(faithful[k]), np.asarray(fused[k]), rtol=1e-5, atol=1e-6
        )


def test_fedavg_is_identity_relay_special_case(setting):
    """Paper: standard FL = ColRel with A = I (uncompensated)."""
    n, p, adj, A, upd, tau = setting
    I = np.eye(n)
    got = aggregation.colrel_increment(I, tau, upd, n=n, fused=True)
    want = aggregation.fedavg_blind_increment(tau, upd, n=n)
    for k in upd:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]), rtol=1e-6)


def test_no_dropout_equals_full_tau(setting):
    n, p, adj, A, upd, tau = setting
    ones = jnp.ones((n,), jnp.float32)
    got = aggregation.fedavg_blind_increment(ones, upd, n=n)
    want = aggregation.no_dropout_increment(upd, n=n)
    for k in upd:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want[k]), rtol=1e-5, atol=1e-7
        )


def test_nonblind_divides_by_successes(setting):
    n, p, adj, A, upd, tau = setting
    got = aggregation.fedavg_nonblind_increment(tau, upd)
    k = float(np.asarray(tau).sum())
    want = aggregation.fedavg_blind_increment(tau, upd, n=n)
    for key in upd:
        np.testing.assert_allclose(
            np.asarray(got[key]), np.asarray(want[key]) * n / k, rtol=1e-5
        )


def test_increment_unbiasedness_monte_carlo(setting):
    """E_τ[PS increment] = (1/n) Σ_i Δx_i under Lemma-1 weights."""
    n, p, adj, A, upd, tau = setting
    cm = connectivity.ConnectivityModel(p)
    taus = np.asarray(cm.sample_rounds(jax.random.key(3), 40_000))
    coeff = taus @ np.asarray(A) / n  # (R, n) per-origin realized weights
    mean_coeff = coeff.mean(0)
    np.testing.assert_allclose(mean_coeff, 1.0 / n, atol=3e-3)


def test_relay_linearity(setting):
    n, p, adj, A, upd, tau = setting
    upd2 = jax.tree.map(lambda x: 2.0 * x, upd)
    out1 = relay.relay(A, upd)
    out2 = relay.relay(A, upd2)
    for k in upd:
        np.testing.assert_allclose(
            np.asarray(out2[k]), 2.0 * np.asarray(out1[k]), rtol=1e-5
        )


def test_server_momentum():
    from repro.core.aggregation import ServerOpt

    opt = ServerOpt(momentum=0.9, lr=1.0)
    params = {"x": jnp.zeros((3,))}
    state = opt.init(params)
    inc = {"x": jnp.ones((3,))}
    p1, s1 = opt.apply(params, state, inc)
    p2, s2 = opt.apply(p1, s1, inc)
    np.testing.assert_allclose(np.asarray(p2["x"]), 1.0 + 1.9, rtol=1e-6)
