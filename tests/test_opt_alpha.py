import numpy as np

from repro.core import connectivity, opt_alpha, relay, topology


def _setting(n=10):
    return connectivity.paper_heterogeneous().p, topology.ring(n, 1)


def test_initial_weights_satisfy_unbiasedness():
    p, adj = _setting()
    A0 = opt_alpha.initial_weights(p, adj)
    assert np.abs(opt_alpha.unbiasedness_residual(p, A0)).max() < 1e-9


def test_optimize_keeps_unbiasedness_and_nonnegativity():
    p, adj = _setting()
    res = opt_alpha.optimize(p, adj, sweeps=60)
    assert res.feasible_columns.all()
    assert np.abs(opt_alpha.unbiasedness_residual(p, res.A)).max() < 1e-8
    assert (res.A >= -1e-12).all()
    assert relay.neighbor_support(res.A, adj)


def test_S_monotone_nonincreasing():
    p, adj = _setting()
    res = opt_alpha.optimize(p, adj, sweeps=60)
    assert np.all(np.diff(res.S_history) <= 1e-9)
    assert res.S_history[-1] < res.S_history[0] * 0.5  # substantial gain


def test_S_literal_equals_collapsed():
    p, adj = _setting()
    res = opt_alpha.optimize(p, adj, sweeps=10)
    lit = opt_alpha.variance_proxy_literal(p, res.A, adj)
    col = opt_alpha.variance_proxy(p, res.A)
    assert np.isclose(lit, col, rtol=1e-10)


def test_fct_homogeneous_init_already_optimal():
    """Paper remark (Fig. 2): Alg. 3's init is optimal for FCT + equal p."""
    n, pval = 10, 0.2
    p = np.full(n, pval)
    adj = topology.fully_connected(n)
    A0 = opt_alpha.initial_weights(p, adj)
    res = opt_alpha.optimize(p, adj, sweeps=40)
    assert np.isclose(res.S_history[-1], opt_alpha.variance_proxy(p, A0), rtol=1e-6)


def test_perfect_relay_gets_all_mass():
    """eq. (9) case 2: a p_j = 1 neighbor carries everything (zero variance)."""
    p = np.array([0.3, 1.0, 0.5])
    res = opt_alpha.optimize(p, topology.fully_connected(3), sweeps=20)
    assert np.allclose(res.A[1], 1.0)
    assert np.isclose(res.S_history[-1], 0.0, atol=1e-12)


def test_infeasible_column_flagged():
    p = np.array([0.0, 0.0, 0.5])
    adj = topology.from_edges(3, [(0, 1)])  # client 0,1 isolated from 2
    res = opt_alpha.optimize(p, adj, sweeps=5)
    assert not res.feasible_columns[0] and not res.feasible_columns[1]
    assert res.feasible_columns[2]


def test_disconnected_reduces_to_inverse_p():
    """No D2D links: the only unbiased choice is α_ii = 1/p_i."""
    p = np.array([0.2, 0.5, 0.8])
    res = opt_alpha.optimize(p, topology.disconnected(3), sweeps=5)
    assert np.allclose(np.diag(res.A), 1.0 / p)
    assert np.allclose(res.A - np.diag(np.diag(res.A)), 0.0)


def test_monte_carlo_unbiasedness():
    """Lemma 1: E[Σ_j τ_j α_ji] = 1 per origin, over realized τ."""
    import jax

    p, adj = _setting()
    res = opt_alpha.optimize(p, adj, sweeps=50)
    cm = connectivity.ConnectivityModel(p)
    taus = np.asarray(cm.sample_rounds(jax.random.key(0), 100_000))
    eff = taus @ res.A
    assert np.abs(eff.mean(0) - 1.0).max() < 0.02


def test_optimized_beats_init_on_heterogeneous_ring():
    p, adj = _setting()
    A0 = opt_alpha.initial_weights(p, adj)
    res = opt_alpha.optimize(p, adj, sweeps=60)
    assert opt_alpha.variance_proxy(p, res.A) < opt_alpha.variance_proxy(p, A0) * 0.6


def test_coverage_diagnostic():
    p, adj = _setting()
    cov = opt_alpha.colrel_expected_coverage(p, adj)
    solo = p  # without relaying, coverage is p_i itself
    assert (cov >= solo - 1e-12).all()
    assert (cov > solo).any()


def test_exact_column_solver_matches_bisection():
    """The closed-form piecewise-linear λ solve agrees with the paper's
    bisection to its tolerance, on random channels and random row masses."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(3, 12))
        p = rng.uniform(0.05, 0.95, n)
        adj = topology.ring(n, int(rng.integers(1, max(2, n // 2))))
        rb = opt_alpha.optimize(p, adj, sweeps=25, method="bisect")
        rx = opt_alpha.optimize(p, adj, sweeps=25, method="exact")
        assert np.max(np.abs(rb.A - rx.A)) < 1e-8
        assert np.abs(opt_alpha.unbiasedness_residual(p, rx.A)).max() < 1e-9
        assert (rx.A >= -1e-12).all()
        assert relay.neighbor_support(rx.A, adj)


def test_exact_solver_masked_matches_bisection():
    rng = np.random.default_rng(1)
    n = 8
    p = rng.uniform(0.1, 0.9, n)
    adj = topology.ring(n, 2)
    active = np.array([1, 1, 0, 1, 1, 0, 1, 1], dtype=bool)
    rb = opt_alpha.optimize_masked(p, adj, active, sweeps=25, method="bisect")
    rx = opt_alpha.optimize_masked(p, adj, active, sweeps=25, method="exact")
    assert np.max(np.abs(rb.A - rx.A)) < 1e-8
    assert np.all(rx.A[:, ~active] == 0.0)
    assert np.all(rx.A[~active, :] == 0.0)


def test_exact_solver_reaches_the_same_optimum():
    p, adj = _setting()
    rb = opt_alpha.optimize(p, adj, sweeps=60, method="bisect")
    rx = opt_alpha.optimize(p, adj, sweeps=60, method="exact")
    assert np.isclose(opt_alpha.variance_proxy(p, rb.A),
                      opt_alpha.variance_proxy(p, rx.A), rtol=1e-8)


def test_unknown_column_solver_rejected_fast():
    import pytest

    p, adj = _setting()
    with pytest.raises(ValueError, match="unknown column solver"):
        opt_alpha.optimize(p, adj, sweeps=1, method="exat")


def test_warm_start_near_departed_relay_falls_back():
    """Regression (ISSUE 9 satellite): a column whose only surviving relays
    are near-departed clients (p_j ≈ ε) used to clear the absolute 1e-12
    mass floor and get rescaled by ~1/mass into enormous α entries.  The
    relative rule must instead fall back to the Alg. 3 initial weights."""
    n = 4
    adj = topology.fully_connected(n)
    p_old = np.array([0.5, 0.6, 0.7, 0.8])
    A_prev = opt_alpha.optimize(p_old, adj, sweeps=40).A
    # Client 0's relays all but vanish: every p_j carrying column 0's mass
    # collapses to 1e-9 except client 0 itself, whose A_prev entry we zero.
    p_new = np.array([0.5, 1e-9, 1e-9, 1e-9])
    A_mod = A_prev.copy()
    A_mod[0, 3] = 0.0  # column 3's carried mass now rides only on p ≈ 1e-9
    A = opt_alpha.warm_start_weights(p_new, adj, A_mod)
    A_init = opt_alpha.initial_weights(p_new, adj)
    # The carried mass (≈1e-9) clears the old absolute 1e-12 floor but not
    # the relative threshold (rtol · col_max ≈ 4e-7): column 3 must fall
    # back to the init column, not the 1/mass rescale of the carried one —
    # the rescale strands the healthy client 0 at weight zero.
    np.testing.assert_allclose(A[:, 3], A_init[:, 3])
    assert A[0, 3] > 0  # fallback re-engages the healthy relay
    sup = p_new > 0
    col = np.where(sup, A_mod[:, 3], 0.0)
    rescaled = col / float(p_new @ col)
    assert not np.allclose(A[:, 3], rescaled)
    # ... and every column still satisfies Lemma 1.
    assert np.abs(opt_alpha.unbiasedness_residual(p_new, A)).max() < 1e-9


def test_warm_start_healthy_columns_are_rescaled_not_reset():
    p_old = np.array([0.3, 0.5, 0.7, 0.9])
    adj = topology.ring(4, 1)
    A_prev = opt_alpha.optimize(p_old, adj, sweeps=40).A
    p_new = p_old * np.array([1.1, 0.9, 1.05, 0.95])
    A = opt_alpha.warm_start_weights(p_new, adj, A_prev)
    A_init = opt_alpha.initial_weights(p_new, adj)
    assert np.abs(opt_alpha.unbiasedness_residual(p_new, A)).max() < 1e-9
    # structure carried over from A_prev, not replaced by the init
    assert not np.allclose(A, A_init)
    np.testing.assert_allclose(A > 0, A_prev > 0)


def test_optimize_masked_inactive_columns_report_infeasible():
    """Regression (ISSUE 9 satellite): ``feasible_columns`` was initialized
    all-True, so padded/departed columns that were never solved read as
    feasible and ``feasible_columns.all()`` lied under churn."""
    rng = np.random.default_rng(2)
    n = 10
    p = rng.uniform(0.2, 0.9, n)
    adj = topology.ring(n, 2)
    active = np.ones(n, dtype=bool)
    active[[2, 5, 6]] = False
    res = opt_alpha.optimize_masked(p, adj, active, sweeps=30)
    assert not res.feasible_columns[~active].any()
    assert res.feasible_columns[active].all()
    assert not res.feasible_columns.all()  # the historical lie
    # all-inactive: nothing is feasible, nothing blows up
    res0 = opt_alpha.optimize_masked(p, adj, np.zeros(n, dtype=bool), sweeps=5)
    assert not res0.feasible_columns.any()
    assert np.all(res0.A == 0.0)


def test_initial_weights_vectorized_matches_loop_reference():
    """The einsum/broadcast ``initial_weights`` equals the literal Alg. 3
    double loop (with the documented p=0 renormalization) on random graphs."""
    rng = np.random.default_rng(3)
    for _ in range(10):
        n = int(rng.integers(3, 14))
        p = rng.uniform(0.0, 1.0, n)
        p[rng.random(n) < 0.2] = 0.0  # hard-disconnected clients
        adj = topology.erdos_renyi(n, 0.4, seed=int(rng.integers(1 << 30)))
        m = topology.closed_mask(adj)
        ref = np.zeros((n, n))
        for i in range(n):
            deg = int(m[:, i].sum())
            for j in range(n):
                if m[j, i] and p[j] > 0:
                    ref[j, i] = 1.0 / (deg * p[j])
            mass = float(p @ ref[:, i])
            if mass > 0 and not np.isclose(mass, 1.0):
                ref[:, i] /= mass
        got = opt_alpha.initial_weights(p, adj)
        np.testing.assert_allclose(got, ref, atol=1e-12)
