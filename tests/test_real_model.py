"""Real-model FL (ISSUE 7): ResNet-20 pytrees through all three engines.

The quad-model engine tests pin the bitwise contracts on a toy tree; this
file holds the same bars on the paper's §V model — a deep nested pytree
(conv/GN/fc leaves, D ≈ 270k) flowing through the ravel layer:

  * loop == scan == pipelined, bit for bit (params, server state, per-round
    metrics, final RNG key) on the einsum reference backend, under churn +
    fading + p-drift, with trace_count ≤ 2 per scan engine;
  * the Pallas mix kernel on the hot path (relay_backend="pallas") matches
    the einsum reference to 1e-6 over multiple accumulated rounds of churn.

Images are 16×16 CIFAR-shaped tensors (the model is size-agnostic): same
pytree, same D, a quarter of the conv compute — this file stays in the
fast suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.configs.resnet20_cifar import CONFIG
from repro.core import opt_alpha, topology
from repro.core.aggregation import ServerOpt
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.models.resnet import init_resnet20, resnet20_loss
from repro.utils import tree_size

N = 4  # padded client dim; RotatingCohorts churns membership below


def _loss_fn(params, batch):
    return resnet20_loss(params, CONFIG, batch)


def _init_params(seed=0):
    return init_resnet20(jax.random.key(seed), CONFIG, num_classes=10)


def _batch_stream(n=N, T=1, b=2, hw=16, seed=0):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {
            "images": rng.standard_normal((n, T, b, hw, hw, 3)).astype(np.float32),
            "labels": rng.integers(0, 10, size=(n, T, b)).astype(np.int32),
        }

    return next_batch


def _churn_schedule(n=N, seed=0):
    """Fading + p-drift + rotating churn with misaligned periods, scaled to
    the short horizon: every engine sees several epochs and a membership
    change."""
    link = channels.MarkovLinkProcess(
        topology.ring(n, 1), p_up_to_down=0.3, p_down_to_up=0.7, seed=seed
    )
    drift = channels.PiecewiseConstantDrift(
        np.linspace(0.4, 0.9, n), hold=1, low=0.2, high=0.95, seed=seed + 1
    )
    member = channels.RotatingCohorts(n, n_cohorts=2, hold=2)
    return channels.ChurnSchedule(
        membership=member, link_process=link, p_process=drift,
        adj_every=2, p_every=3,
    )


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def test_resnet20_engines_bitwise_identical_under_churn():
    """The tentpole bar: the real model's nested pytree rides the ravel
    layer through all three engines and lands bit-identically."""
    rounds = 6
    params0 = _init_params()
    assert tree_size(params0) > 200_000  # genuinely the deep model
    runs = {}
    traces = {}
    for engine_name in ("loop", "scan", "pipelined"):
        sim = FLSimulator(
            _loss_fn, n_clients=N, strategy="colrel", local_steps=1,
            server_opt=ServerOpt(momentum=0.5),  # nontrivial carried state
        )
        ss = sim.init_server_state(params0)
        key = jax.random.key(7)
        schedule = _churn_schedule(seed=3)
        policy = channels.AdaptiveOptAlpha(sweeps=10, warm_sweeps=4)
        next_batch = _batch_stream(seed=42)
        kw = dict(
            schedule=schedule, rounds=rounds, next_batch=next_batch,
            lr=0.05, policy=policy,
        )
        if engine_name == "loop":
            runs[engine_name] = run_rounds_loop(sim, key, params0, ss, **kw)
            traces[engine_name] = sim.trace_count
        else:
            cls = EpochScanEngine if engine_name == "scan" else PipelinedScanEngine
            eng = cls(sim, chunk=2)
            runs[engine_name] = eng.run_schedule(key, params0, ss, **kw)
            traces[engine_name] = eng.trace_count

    lp, ls, lm, lk = runs["loop"]
    for other in ("scan", "pipelined"):
        op, os_, om, ok = runs[other]
        assert _tree_equal(lp, op), other
        assert _tree_equal(ls, os_), other
        assert _tree_equal(lm, om), other  # per-round loss/tau/delta_norm
        assert np.array_equal(
            jax.random.key_data(lk), jax.random.key_data(ok)
        ), other
        assert traces[other] <= 2, (other, traces[other])


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_resnet20_kernel_backend_matches_einsum(backend):
    """The relay kernel on the real model's raveled (n, D≈270k) buffer:
    accumulated over rounds of churn, einsum vs kernel stays within 1e-6."""
    rounds = 3
    params0 = _init_params(1)
    p = np.linspace(0.5, 0.9, N)
    A = opt_alpha.optimize(p, topology.ring(N, 1), sweeps=15).A
    rng = np.random.default_rng(8)
    batches = [_batch_stream(seed=100 + r)() for r in range(rounds)]
    actives = []
    for _ in range(rounds):
        act = rng.random(N) < 0.75
        act[rng.integers(N)] = True  # at least one live client per round
        actives.append(jnp.asarray(act, jnp.float32))
    finals = {}
    for be in ("einsum", backend):
        sim = FLSimulator(
            _loss_fn, n_clients=N, strategy="colrel", A=A, p=p,
            local_steps=1, relay_backend=be,
            block_d=65536, interpret=True,
        )
        params, ss = params0, sim.init_server_state(params0)
        for r in range(rounds):
            key = jax.random.key(200 + r)
            params, ss, _ = sim.run_round(
                key, params, ss, jax.tree.map(jnp.asarray, batches[r]),
                0.05, active=actives[r],
            )
        finals[be] = params
    for leaf_e, leaf_k in zip(
        jax.tree.leaves(finals["einsum"]), jax.tree.leaves(finals[backend])
    ):
        np.testing.assert_allclose(
            np.asarray(leaf_e, np.float32), np.asarray(leaf_k, np.float32),
            atol=1e-6, rtol=1e-6,
        )
