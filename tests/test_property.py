"""Hypothesis property tests for the ColRel invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, opt_alpha, relay, topology
from repro.fl import async_engine
from repro.utils import stacked_ravel, tree_dot, tree_norm, tree_ravel, tree_unravel

MAX_N = 12


@st.composite
def fl_setting(draw):
    n = draw(st.integers(3, MAX_N))
    p = np.asarray(draw(st.lists(
        st.floats(0.05, 1.0), min_size=n, max_size=n)))
    kind = draw(st.sampled_from(["ring", "fct", "er", "clusters"]))
    if kind == "ring":
        adj = topology.ring(n, draw(st.integers(1, max(1, n // 2 - 1))))
    elif kind == "fct":
        adj = topology.fully_connected(n)
    elif kind == "er":
        adj = topology.erdos_renyi(n, draw(st.floats(0.1, 0.9)), seed=draw(st.integers(0, 100)))
    else:
        adj = topology.clusters(n, draw(st.integers(1, 3)))
    return p, adj


@given(fl_setting())
@settings(max_examples=30, deadline=None)
def test_opt_alpha_invariants(setting):
    p, adj = setting
    res = opt_alpha.optimize(p, adj, sweeps=25)
    # unbiasedness on feasible columns (Lemma 1)
    resid = opt_alpha.unbiasedness_residual(p, res.A)
    assert np.abs(resid[res.feasible_columns]).max() < 1e-7
    # nonnegativity and support
    assert (res.A >= -1e-10).all()
    assert relay.neighbor_support(res.A, adj)
    # Gauss-Seidel never increases the objective
    assert np.all(np.diff(res.S_history) <= 1e-7 * max(1.0, res.S_history[0]))


@given(fl_setting())
@settings(max_examples=20, deadline=None)
def test_optimized_no_worse_than_init(setting):
    p, adj = setting
    A0 = opt_alpha.initial_weights(p, adj)
    res = opt_alpha.optimize(p, adj, sweeps=25)
    s0 = opt_alpha.variance_proxy(p, A0)
    assert opt_alpha.variance_proxy(p, res.A) <= s0 + 1e-7 * max(1.0, s0)


@given(fl_setting())
@settings(max_examples=15, deadline=None)
def test_S_convexity_along_segments(setting):
    """S(p, ·) is convex (paper §IV): check along random feasible segments."""
    p, adj = setting
    rng = np.random.default_rng(0)
    A0 = opt_alpha.initial_weights(p, adj)
    res = opt_alpha.optimize(p, adj, sweeps=5)
    A1 = res.A
    for lam in (0.25, 0.5, 0.75):
        mid = lam * A0 + (1 - lam) * A1
        s_mid = opt_alpha.variance_proxy(p, mid)
        bound = lam * opt_alpha.variance_proxy(p, A0) + (1 - lam) * opt_alpha.variance_proxy(p, A1)
        assert s_mid <= bound + 1e-8 * max(1.0, bound)


@st.composite
def masked_setting(draw):
    """Random (p, adj, active) draw: a channel plus a churn mask with at
    least one live client."""
    n = draw(st.integers(3, MAX_N))
    p = np.asarray(draw(st.lists(
        st.floats(0.05, 0.95), min_size=n, max_size=n)))
    kind = draw(st.sampled_from(["ring", "fct", "er", "clusters"]))
    if kind == "ring":
        adj = topology.ring(n, draw(st.integers(1, max(1, n // 2 - 1))))
    elif kind == "fct":
        adj = topology.fully_connected(n)
    elif kind == "er":
        adj = topology.erdos_renyi(
            n, draw(st.floats(0.1, 0.9)), seed=draw(st.integers(0, 100)))
    else:
        adj = topology.clusters(n, draw(st.integers(1, 3)))
    active = np.asarray(draw(st.lists(
        st.booleans(), min_size=n, max_size=n)))
    if not active.any():
        active[draw(st.integers(0, n - 1))] = True
    return p, adj, active


@given(masked_setting())
@settings(max_examples=25, deadline=None)
def test_exact_solver_agrees_with_bisection_on_masked_draws(setting):
    """The closed-form piecewise-linear λ solve vs the paper's bisection on
    random (p, adj, active) draws: identical column solutions on identical
    input, and the same reached optimum S — to 1e-8.  (The full matrices may
    differ when the optimum is non-unique; the minimum value never does.)"""
    p, adj, active = setting
    adj_m = adj & active[:, None] & active[None, :]
    p_eff = np.where(active, p, 0.0)
    m = topology.closed_mask(adj_m) & active[:, None] & active[None, :]
    A0 = opt_alpha.initial_weights(p_eff, adj_m)
    for i in np.nonzero(active)[0]:
        beta = A0.sum(axis=1) - A0[:, i]
        col_b, ok_b, _ = opt_alpha.solve_column(
            p_eff, m[:, i], beta, method="bisect")
        col_x, ok_x, _ = opt_alpha.solve_column(
            p_eff, m[:, i], beta, method="exact")
        assert ok_b == ok_x
        assert np.max(np.abs(col_b - col_x)) < 1e-8
    rb = opt_alpha.optimize_masked(p, adj, active, sweeps=25, method="bisect")
    rx = opt_alpha.optimize_masked(p, adj, active, sweeps=25, method="exact")
    S_b = opt_alpha.variance_proxy(p_eff, rb.A)
    S_x = opt_alpha.variance_proxy(p_eff, rx.A)
    assert abs(S_b - S_x) <= 1e-8 * max(1.0, S_b)


@given(masked_setting())
@settings(max_examples=25, deadline=None)
def test_masked_relay_weights_unbiased_and_on_support(setting):
    """Under a random churn mask the masked OPT-α weights keep every ColRel
    invariant: nonnegative, exactly zero on departed rows/columns, supported
    on the live closed neighborhoods, and unbiased in expectation over the
    live set — each feasible origin's update carries total expected mass 1
    (Lemma 1, the column-wise stochasticity the PS relies on)."""
    p, adj, active = setting
    res = opt_alpha.optimize_masked(p, adj, active, sweeps=25)
    A = res.A
    assert (A >= -1e-10).all()
    assert np.all(A[~active, :] == 0.0)
    assert np.all(A[:, ~active] == 0.0)
    adj_m = adj & active[:, None] & active[None, :]
    assert relay.neighbor_support(A, adj_m)
    p_eff = np.where(active, p, 0.0)
    cols = active & res.feasible_columns
    if cols.any():
        np.testing.assert_allclose((p_eff @ A)[cols], 1.0, atol=1e-7)


@given(
    st.integers(3, 10),
    st.integers(0, 10_000),
)
@settings(max_examples=20, deadline=None)
def test_relay_preserves_total_mass_expectation(n, seed):
    """p @ A = 1 ⇒ Σ_o E[coeff_o] = n·w = 1 for w = 1/n."""
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.1, 1.0, n)
    adj = topology.ring(n, 1)
    res = opt_alpha.optimize(p, adj, sweeps=15)
    if not res.feasible_columns.all():
        return
    expected_coeff = p @ res.A  # E[τ] @ A
    np.testing.assert_allclose(expected_coeff, 1.0, atol=1e-7)


# ------------------------------------------------------------------------
# Raveled-view layer (ISSUE 7): random pytrees through tree_ravel/unravel
# ------------------------------------------------------------------------

_LEAF_DTYPES = ("float32", "bfloat16")


@st.composite
def random_pytree(draw):
    """A random nested pytree: 1-6 leaves of rank ≤ 3 (scalars included),
    f32/bf16 dtypes, folded into a random mix of dict/list/tuple containers."""
    n_leaves = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    nodes = []
    for _ in range(n_leaves):
        shape = tuple(draw(st.lists(st.integers(1, 4), min_size=0, max_size=3)))
        dtype = draw(st.sampled_from(_LEAF_DTYPES))
        nodes.append(jnp.asarray(rng.standard_normal(shape), dtype))
    while len(nodes) > 1:
        kind = draw(st.sampled_from(["dict", "list", "tuple"]))
        a, b = nodes[0], nodes[1]
        merged = {"a": a, "b": b} if kind == "dict" else (
            [a, b] if kind == "list" else (a, b))
        nodes = [merged] + nodes[2:]
    return nodes[0]


def _leaves_bit_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        x.dtype == y.dtype
        and x.shape == y.shape
        # the f32 view of an f32/bf16 leaf is exact, so f32 equality on
        # finite draws is bit equality
        and np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
        for x, y in zip(la, lb)
    )


@given(random_pytree())
@settings(max_examples=50, deadline=None)
def test_tree_ravel_round_trip_bit_exact(tree):
    """tree_unravel ∘ tree_ravel = id, bit for bit, for any nesting, any
    mix of f32/bf16 leaves, any leaf rank — the contract the flat (n, D)
    aggregation path rests on."""
    flat, spec = tree_ravel(tree)
    assert flat.dtype == jnp.float32
    assert flat.shape == (spec.total,)
    back = tree_unravel(spec, flat)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    assert _leaves_bit_equal(tree, back)


@given(random_pytree(), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_stacked_ravel_rows_are_per_client_ravels(tree, n):
    """stacked_ravel of a stacked tree is row-for-row tree_ravel of each
    client's slice, under one shared spec."""
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * jnp.asarray(i + 1.0, x.dtype) for i in range(n)]),
        tree,
    )
    buf, spec = stacked_ravel(stacked)
    assert buf.shape == (n, spec.total)
    for i in range(n):
        client = jax.tree.map(lambda x: x[i], stacked)
        row, client_spec = tree_ravel(client)
        assert client_spec == spec
        assert np.array_equal(np.asarray(buf[i]), np.asarray(row))
        assert _leaves_bit_equal(client, tree_unravel(spec, buf[i]))


# ------------------------------------------------------------------------
# Async staleness weighting (ISSUE 10): the pure weight math of
# repro.fl.async_engine, over random channels, churn masks and delays
# ------------------------------------------------------------------------


@st.composite
def staleness_setting(draw):
    """A masked channel draw plus per-slot staleness, a decay and a seed."""
    p, adj, active = draw(masked_setting())
    n = p.shape[0]
    staleness = np.asarray(draw(st.lists(
        st.integers(0, 12), min_size=n, max_size=n)))
    decay = draw(st.floats(0.05, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return p, adj, active, staleness, decay, seed


@given(staleness_setting())
@settings(max_examples=30, deadline=None)
def test_staleness_discounts_bounded_monotone_and_exact_at_zero(setting):
    """decay**s stays in (0, 1], never increases with staleness, and a fresh
    slot (s=0) gets *exactly* the 1.0 identity weight — the bit the delay-0
    parity contract rests on."""
    _, _, _, staleness, decay, _ = setting
    d = async_engine.staleness_discounts(staleness, decay=decay)
    assert d.dtype == np.float32
    assert (d > 0.0).all() and (d <= 1.0).all()
    assert np.all(d[staleness == 0] == np.float32(1.0))
    order = np.argsort(staleness, kind="stable")
    assert np.all(np.diff(d[order]) <= 1e-7)


@given(staleness_setting())
@settings(max_examples=30, deadline=None)
def test_staleness_weights_form_a_simplex_over_selected_slots(setting):
    """The renormalized weights are nonnegative, exactly zero wherever the
    discount-mask vector is zero, and sum to one whenever any slot is
    selected (the all-zero vector maps to all-zero weights)."""
    _, _, active, staleness, decay, seed = setting
    rng = np.random.default_rng(seed)
    selected = rng.random(active.shape[0]) < 0.7
    m = async_engine.staleness_discounts(staleness, decay=decay)
    m = m * (selected & active)
    w = np.asarray(async_engine.staleness_weights(m))
    assert (w >= 0.0).all()
    assert np.all(w[m == 0.0] == 0.0)
    if m.sum() > 0:
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    else:
        assert np.all(w == 0.0)


@given(masked_setting())
@settings(max_examples=25, deadline=None)
def test_staleness_weights_reduce_to_active_weight_at_delay0(setting):
    """At delay 0 every live slot carries discount exactly 1.0, so the
    renormalized weights are bit-equal to the synchronous blind weight
    1/n_active of ``aggregation.active_weight`` on the live slots and
    exactly zero on departed ones."""
    _, _, active = setting
    m = active.astype(np.float32)
    w = np.asarray(async_engine.staleness_weights(m))
    w_sync = np.float32(aggregation.active_weight(jnp.asarray(active), n=len(active)))
    assert np.all(w[active] == w_sync)
    assert np.all(w[~active] == 0.0)


@given(masked_setting(), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_async_coefficients_match_sync_fused_at_delay0(setting, seed):
    """With every slot fresh (m == active) the async coefficient vector is
    bitwise the synchronous fused path's w·(τᵀA) under the same churn
    masking — on the dense and the sparse edge-list operand alike."""
    p, adj, active = setting
    n = len(p)
    rng = np.random.default_rng(seed)
    tau = (rng.random(n) < p).astype(np.float32) * active
    A = opt_alpha.optimize_masked(p, adj, active, sweeps=15).A
    rows, cols = np.nonzero(A)
    operands = {
        "einsum": jnp.asarray(A, jnp.float32),
        "segment": relay.EdgeRelay(
            rows=jnp.asarray(rows, jnp.int32),
            cols=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(A[rows, cols], jnp.float32),
        ),
    }
    m = active.astype(np.float32)
    a = jnp.asarray(active, jnp.float32)
    for backend, op in operands.items():
        got = async_engine.async_coefficients(
            op, tau, m, n=n, active=a, backend=backend)
        base = relay.fused_coefficients(
            relay.mask_relay_matrix(op, a), jnp.asarray(tau) * a)
        want = aggregation.active_weight(a, n=n) * base
        assert np.array_equal(np.asarray(got), np.asarray(want))


@given(staleness_setting())
@settings(max_examples=10, deadline=None)
def test_zero_mass_slots_contribute_exactly_zero_on_all_backends(setting):
    """A departed or never-arrived slot (m == 0) must contribute *exactly*
    zero to the aggregate: its buffer row is poisoned with huge finite
    values, and the increment is bit-identical to the one computed with the
    row zeroed — on all four relay backends."""
    p, adj, active, staleness, decay, seed = setting
    n = len(p)
    rng = np.random.default_rng(seed)
    arrived = rng.random(n) < 0.6
    m = async_engine.staleness_discounts(staleness, decay=decay)
    m = m * (arrived & active)
    tau = (rng.random(n) < p).astype(np.float32)
    A = opt_alpha.optimize_masked(p, adj, active, sweeps=10).A
    rows, cols = np.nonzero(A)
    edge = relay.EdgeRelay(
        rows=jnp.asarray(rows, jnp.int32),
        cols=jnp.asarray(cols, jnp.int32),
        vals=jnp.asarray(A[rows, cols], jnp.float32),
    )
    buf = rng.standard_normal((n, 32)).astype(np.float32)
    poisoned = buf.copy()
    poisoned[m == 0.0] = 1e30
    clean = buf.copy()
    clean[m == 0.0] = 0.0
    a = jnp.asarray(active, jnp.float32)
    for backend in ("einsum", "segment", "pallas", "pallas_fused"):
        op = edge if backend == "segment" else jnp.asarray(A, jnp.float32)
        got = async_engine.async_increment_flat(
            op, tau, m, jnp.asarray(poisoned), n=n, active=a, backend=backend)
        want = async_engine.async_increment_flat(
            op, tau, m, jnp.asarray(clean), n=n, active=a, backend=backend)
        assert np.isfinite(np.asarray(got)).all(), backend
        assert np.array_equal(np.asarray(got), np.asarray(want)), backend


@given(random_pytree(), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_tree_dot_and_norm_agree_with_raveled(tree, seed):
    """The structured reductions and their raveled counterparts are the same
    f32 quantity (summation order differs, so: to f32 precision)."""
    rng = np.random.default_rng(seed)
    other = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape), x.dtype), tree
    )
    fa, _ = tree_ravel(tree)
    fb, _ = tree_ravel(other)
    np.testing.assert_allclose(
        float(tree_dot(tree, other)), float(jnp.vdot(fa, fb)),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(
        float(tree_norm(tree)), float(jnp.linalg.norm(fa)),
        rtol=1e-5, atol=1e-6,
    )
