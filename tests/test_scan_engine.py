"""Epoch-segmented scan engine (ISSUE 3): the two load-bearing invariants.

  * the scan path is **bit-identical** to the per-round reference path —
    same params, same server state, same per-round metrics, same final key —
    across a multi-epoch schedule with churn, fading and p-drift;
  * compile discipline: the engine stays within 2 compiles across epochs of
    a fixed client dimension (fixed-size chunk scans + masked padding, never
    a per-epoch-length retrace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import opt_alpha, topology
from repro.core.aggregation import ServerOpt
from repro.fl.distributed import build_round_step, build_scan_round_step
from repro.fl.engine import EpochScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator


def _quad_setting(n=6, dim=4, T=2, b=4, seed=0):
    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))

    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((n, T, b, dim)).astype(np.float32)}

    params = {"x": jnp.ones((dim,))}
    return loss_fn, next_batch, params


def _churn_drift_schedule(n=6, seed=0):
    """Multi-epoch: Markov fading + piecewise p-drift + rotating churn, with
    misaligned periods so segment lengths vary."""
    link = channels.MarkovLinkProcess(
        topology.ring(n, 2), p_up_to_down=0.4, p_down_to_up=0.6, seed=seed)
    drift = channels.PiecewiseConstantDrift(
        np.linspace(0.2, 0.9, n), hold=1, low=0.1, high=0.9, seed=seed + 1)
    member = channels.RotatingCohorts(n, n_cohorts=3, hold=5)
    return channels.ChurnSchedule(
        membership=member, link_process=link, p_process=drift,
        adj_every=3, p_every=4)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ------------------------------------------------------------- segments()


def test_segments_partition_the_round_stream():
    rounds = 23
    states = list(_churn_drift_schedule().rounds(rounds))
    segs = list(_churn_drift_schedule().segments(rounds))
    # same stream, regrouped
    flat = [s for seg in segs for s in seg.states]
    assert len(flat) == rounds
    for got, want in zip(flat, states):
        assert got.round == want.round
        assert got.epoch_id == want.epoch_id
        assert got.key() == want.key()
    assert len(segs) > 3  # genuinely multi-epoch
    # within a segment the channel value is constant...
    for seg in segs:
        assert seg.n_rounds == len(seg.states)
        for s in seg.states:
            assert s.key() == seg.state.key()
            assert s.epoch_id == seg.epoch_id
    # ...and consecutive segments differ (epochs are maximal runs)
    for a, b in zip(segs, segs[1:]):
        assert a.state.key() != b.state.key()
        assert b.start_round == a.start_round + a.n_rounds


def test_segment_value_properties():
    seg = next(_churn_drift_schedule().segments(5))
    assert np.array_equal(seg.adj, seg.state.adj)
    assert np.array_equal(seg.p, seg.state.p)
    assert np.array_equal(seg.active, seg.state.active)


# ------------------------------------------------- τ-stream bit-equivalence


def test_sample_taus_matches_sequential_sampling():
    loss_fn, _, _ = _quad_setting()
    sim = FLSimulator(loss_fn, n_clients=6, strategy="fedavg_blind")
    engine = EpochScanEngine(sim, chunk=4)
    p = np.linspace(0.2, 0.9, 6).astype(np.float32)
    # R=10 with chunk 4 exercises both full and padded tau chunks
    key = jax.random.key(3)
    got_key, got = engine.sample_taus(key, p, 10)
    ref_key, ref = key, []
    for _ in range(10):
        ref_key, sub = jax.random.split(ref_key)
        ref.append(sim.sample_tau(sub, p))
    assert np.array_equal(np.asarray(got), np.asarray(jnp.stack(ref)))
    assert np.array_equal(jax.random.key_data(got_key),
                          jax.random.key_data(ref_key))


def test_sample_taus_no_dropout_is_all_ones():
    loss_fn, _, _ = _quad_setting()
    sim = FLSimulator(loss_fn, n_clients=6, strategy="no_dropout")
    engine = EpochScanEngine(sim, chunk=4)
    _, taus = engine.sample_taus(jax.random.key(0), np.full(6, 0.3), 6)
    assert np.array_equal(np.asarray(taus), np.ones((6, 6)))


# ------------------------------------------- run_segment vs run_round calls


def test_run_segment_matches_sequential_run_round():
    n = 6
    loss_fn, next_batch, params0 = _quad_setting(n=n)
    p = np.linspace(0.3, 0.9, n)
    A = opt_alpha.optimize(p, topology.ring(n, 2), sweeps=20).A
    rng = np.random.default_rng(5)
    R = 7  # chunk=4: one full chunk + one padded chunk
    batches = [next_batch() for _ in range(R)]
    taus = rng.random((R, n)) < p[None, :]
    active = (rng.random(n) < 0.8).astype(np.float32)

    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                      A=A, p=p)
    ref_params, ref_ss = params0, sim.init_server_state(params0)
    ref_metrics = []
    for r in range(R):
        ref_params, ref_ss, m = sim._round(
            ref_params, ref_ss, jax.tree.map(jnp.asarray, batches[r]),
            jnp.asarray(taus[r], jnp.float32), jnp.asarray(A, jnp.float32),
            0.1, jnp.asarray(active))
        ref_metrics.append(m)
    ref_metrics = jax.tree.map(lambda *ms: jnp.stack(ms), *ref_metrics)

    sim2 = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                       A=A, p=p)
    engine = EpochScanEngine(sim2, chunk=4)
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    got_params, got_ss, got_metrics = engine.run_segment(
        params0, sim2.init_server_state(params0), stacked,
        jnp.asarray(taus, jnp.float32), 0.1, A=A, active=active)

    assert _tree_equal(ref_params, got_params)
    assert _tree_equal(ref_ss, got_ss)
    assert _tree_equal(ref_metrics, got_metrics)


# --------------------------- full-schedule bit-equivalence (churn + drift)


@pytest.mark.parametrize("strategy", ["colrel_fused", "fedavg_blind"])
def test_scan_bit_identical_to_loop_over_multi_epoch_churn_drift(strategy):
    """The tentpole invariant: run_schedule == per-round loop, bit for bit,
    over a schedule where adjacency, p and membership all change."""
    n, rounds = 6, 17
    loss_fn, _, params0 = _quad_setting(n=n, seed=11)

    def make_sim():
        return FLSimulator(
            loss_fn, n_clients=n, strategy=strategy,
            server_opt=ServerOpt(momentum=0.5),  # nontrivial carried state
        )

    def make_policy():
        if strategy == "fedavg_blind":
            return None
        return channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)

    runs = {}
    for engine_name in ("loop", "scan"):
        rng = np.random.default_rng(42)  # identical batch stream

        def next_batch():
            return {"c": rng.standard_normal((n, 2, 4, 4)).astype(np.float32)}

        sim = make_sim()
        params = params0
        ss = sim.init_server_state(params)
        key = jax.random.key(7)
        schedule = _churn_drift_schedule(n=n, seed=3)
        policy = make_policy()
        if engine_name == "loop":
            out = run_rounds_loop(
                sim, key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=0.1, policy=policy)
        else:
            eng = EpochScanEngine(sim, chunk=4)
            out = eng.run_schedule(
                key, params, ss, schedule=schedule, rounds=rounds,
                next_batch=next_batch, lr=0.1, policy=policy)
        runs[engine_name] = out

    (lp, ls, lm, lk), (sp, ss_, sm, sk) = runs["loop"], runs["scan"]
    assert _tree_equal(lp, sp)
    assert _tree_equal(ls, ss_)
    assert _tree_equal(lm, sm)  # per-round loss/tau/delta_norm streams
    assert np.array_equal(jax.random.key_data(lk), jax.random.key_data(sk))


def test_scan_engine_trace_count_bound():
    """≤ 2 compiles across many epochs of fixed n: one for the chunk scan,
    at most one more for a remainder — never one per epoch length."""
    n, rounds = 6, 29
    loss_fn, _, params0 = _quad_setting(n=n, seed=2)
    rng = np.random.default_rng(0)

    def next_batch():
        return {"c": rng.standard_normal((n, 2, 4, 4)).astype(np.float32)}

    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused")
    engine = EpochScanEngine(sim, chunk=4)
    schedule = _churn_drift_schedule(n=n, seed=9)
    assert len(list(_churn_drift_schedule(n=n, seed=9).segments(rounds))) > 4
    engine.run_schedule(
        jax.random.key(0), params0, sim.init_server_state(params0),
        schedule=schedule, rounds=rounds, next_batch=next_batch, lr=0.1,
        policy=channels.AdaptiveOptAlpha(sweeps=10))
    assert engine.trace_count <= 2


# ------------------------------------------------- distributed scan wrapper


def test_distributed_scan_step_matches_sequential_rounds():
    n, T, R = 4, 2, 6
    loss_fn, next_batch, params0 = _quad_setting(n=n, T=T, seed=8)
    p = np.linspace(0.4, 0.9, n)
    A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=20).A
    kw = dict(n_clients=n, local_steps=T, relay_mode="fused")
    round_fn = jax.jit(build_round_step(loss_fn, **kw))
    scan_fn = jax.jit(build_scan_round_step(loss_fn, **kw))

    rng = np.random.default_rng(1)
    batches = [next_batch() for _ in range(R)]
    taus = jnp.asarray(rng.random((R, n)) < p[None, :], jnp.float32)
    A_j = jnp.asarray(A, jnp.float32)

    ref_params, ref_ss = params0, None
    ref_losses = []
    for r in range(R):
        ref_params, ref_ss, loss = round_fn(
            ref_params, ref_ss, jax.tree.map(jnp.asarray, batches[r]),
            taus[r], 0.1, A_j)
        ref_losses.append(loss)

    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    got_params, got_ss, got_losses = scan_fn(
        params0, None, stacked, taus, 0.1, A_j)

    assert _tree_equal(ref_params, got_params)
    assert np.array_equal(np.asarray(jnp.stack(ref_losses)),
                          np.asarray(got_losses))
