"""Client-churn subsystem: padded client dimension + active-mask invariants.

The three load-bearing invariants (ISSUE 2):
  * an inactive client contributes exactly zero to the PS increment, for
    every aggregation strategy;
  * OPT-α on the active block (``optimize_masked``) matches solving the
    dense subproblem restricted to the active clients;
  * ``trace_count`` stays 1 while membership changes every round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import aggregation, opt_alpha, relay as relay_lib, topology
from repro.fl.simulator import FLSimulator
from repro.kernels import ops as kops
from repro.optim.sgd import ClientOpt

STRATEGIES = ["colrel", "colrel_fused", "fedavg_blind", "fedavg_nonblind",
              "no_dropout"]


def _quad_setting(n=8, dim=4, T=2, seed=0):
    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))

    rng = np.random.default_rng(seed)
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, 8, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    return loss_fn, batch, params


def _channel(n=8, seed=0):
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.2, 0.9, n)
    adj = topology.ring(n, 2)
    A = opt_alpha.optimize(p, adj, sweeps=30).A
    return p, adj, A


# ------------------------------------------------- invariant 1: exact zeros


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_inactive_clients_contribute_exactly_zero(strategy):
    """Poisoning an inactive client's update must not move the increment by
    a single bit — its contribution is exactly zero, not merely small."""
    n = 8
    p, adj, A = _channel(n)
    active = jnp.asarray([1, 1, 0, 1, 0, 1, 1, 1], jnp.float32)
    tau = jnp.asarray([1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
    rng = np.random.default_rng(1)
    upd = {"x": jnp.asarray(rng.standard_normal((n, 5)), jnp.float32),
           "y": jnp.asarray(rng.standard_normal((n, 3, 2)), jnp.float32)}
    poisoned = jax.tree.map(
        lambda l: l.at[jnp.asarray([2, 4])].set(1e9), upd)

    agg = aggregation.make_aggregator(strategy, n=n, A=A)
    inc = agg.fn(tau, upd, None, active)
    inc_poisoned = agg.fn(tau, poisoned, None, active)
    for a, b in zip(jax.tree.leaves(inc), jax.tree.leaves(inc_poisoned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.all(np.isfinite(np.asarray(a)))


def test_simulator_round_independent_of_inactive_client_data():
    """End-to-end: garbage batches on inactive clients leave the new global
    model bit-identical (their whole local run is dead compute)."""
    n, T = 8, 2
    loss_fn, batch, params = _quad_setting(n=n, T=T)
    p, adj, A = _channel(n)
    active = np.array([1, 0, 1, 1, 1, 0, 1, 1], np.float32)
    garbage = {"c": batch["c"].at[jnp.asarray([1, 5])].set(1e6)}

    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused", A=A, p=p,
                      local_steps=T,
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    key = jax.random.key(3)
    out1, _, m1 = sim.run_round(key, params, None, batch, 0.1, active=active)
    out2, _, m2 = sim.run_round(key, params, None, garbage, 0.1, active=active)
    np.testing.assert_array_equal(np.asarray(out1["x"]), np.asarray(out2["x"]))
    # masked metrics ignore the poisoned slots too
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]))
    assert float(m1["delta_norm"]) == pytest.approx(float(m2["delta_norm"]))


def test_full_membership_mask_matches_maskless_path():
    """active = all-ones computes the same round as active = None (the
    static path), so churn code costs nothing when unused."""
    n, T = 8, 2
    loss_fn, batch, params = _quad_setting(n=n, T=T)
    p, adj, A = _channel(n)
    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel", A=A, p=p,
                      local_steps=T,
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    key = jax.random.key(0)
    out_none, _, _ = sim.run_round(key, params, None, batch, 0.1)
    out_ones, _, _ = sim.run_round(key, params, None, batch, 0.1,
                                   active=np.ones(n, np.float32))
    np.testing.assert_allclose(np.asarray(out_none["x"]),
                               np.asarray(out_ones["x"]), rtol=1e-6)


def test_masked_weight_renormalizes_over_active_set():
    """fedavg_blind under a mask uses w = 1/n_active, not 1/n_max: with all
    active clients connected, the increment is the plain mean over them."""
    n = 6
    active = jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32)
    tau = jnp.ones((n,), jnp.float32)
    upd = {"x": jnp.asarray(np.arange(n * 2, dtype=np.float32).reshape(n, 2))}
    agg = aggregation.make_aggregator("fedavg_blind", n=n)
    inc = agg.fn(tau, upd, None, active)
    np.testing.assert_allclose(
        np.asarray(inc["x"]), np.asarray(upd["x"][:3]).mean(axis=0), rtol=1e-6)


# --------------------------------- invariant 2: masked OPT-α = dense sub-solve


@pytest.mark.parametrize("seed", [0, 1])
def test_optimize_masked_matches_dense_subproblem(seed):
    n = 10
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.1, 0.9, n)
    adj = topology.ring(n, 2)
    active = np.ones(n, bool)
    active[rng.choice(n, size=3, replace=False)] = False
    idx = np.nonzero(active)[0]

    full = opt_alpha.optimize_masked(p, adj, active, sweeps=40)
    sub = opt_alpha.optimize(p[idx], adj[np.ix_(idx, idx)], sweeps=40)

    # inactive rows and columns are exactly zero
    assert np.all(full.A[~active, :] == 0.0)
    assert np.all(full.A[:, ~active] == 0.0)
    # the active block is the dense solve of the restricted subproblem
    np.testing.assert_allclose(full.A[np.ix_(idx, idx)], sub.A, atol=1e-12)
    assert full.S_history[-1] == pytest.approx(sub.S_history[-1])
    # unbiasedness over the active set (Lemma 1 on the subproblem)
    np.testing.assert_allclose(
        opt_alpha.unbiasedness_residual(p[idx], full.A[np.ix_(idx, idx)]),
        0.0, atol=1e-9)


def test_optimize_masked_all_active_matches_dense():
    p, adj, _ = _channel(9, seed=2)
    full = opt_alpha.optimize(p, adj, sweeps=30)
    masked = opt_alpha.optimize_masked(p, adj, np.ones(9, bool), sweeps=30)
    np.testing.assert_allclose(masked.A, full.A, atol=1e-12)


def test_adaptive_scheduler_cache_keys_on_mask():
    """Same (adj, p), different membership ⇒ different cache entries and a
    masked solve; revisiting a mask is a pure cache hit."""
    n = 8
    p = np.full(n, 0.5, np.float32)
    adj = topology.ring(n, 2)
    m1 = np.array([1, 1, 1, 1, 1, 1, 0, 0], bool)
    m2 = np.ones(n, bool)
    s1 = channels.ChannelState(0, 0, adj, p, m1)
    s2 = channels.ChannelState(1, 1, adj, p, m2)
    pol = channels.AdaptiveOptAlpha(sweeps=30, warm_sweeps=10)
    A1 = pol.relay_matrix(s1)
    A2 = pol.relay_matrix(s2)
    A1_again = pol.relay_matrix(channels.ChannelState(2, 0, adj, p, m1))
    assert pol.stats.solves == 2 and pol.stats.cache_hits == 1
    np.testing.assert_array_equal(A1, A1_again)
    assert np.all(A1[~m1, :] == 0.0) and np.all(A1[:, ~m1] == 0.0)
    assert not np.array_equal(A1, A2)


def test_stale_policy_projects_out_departed_clients():
    n = 8
    p, adj, _ = _channel(n, seed=3)
    pol = channels.StaleOptAlpha(sweeps=20)
    A_full = pol.relay_matrix(channels.ChannelState(0, 0, adj, p.astype(np.float32)))
    mask = np.array([1, 1, 0, 1, 1, 1, 0, 1], bool)
    A_churn = pol.relay_matrix(
        channels.ChannelState(1, 1, adj, p.astype(np.float32), mask))
    assert np.all(A_churn[~mask, :] == 0.0) and np.all(A_churn[:, ~mask] == 0.0)
    assert A_churn.sum() < A_full.sum()  # lost mass = the staleness penalty


# -------------------------------------------------- membership processes


def test_markov_churn_respects_min_active_floor():
    proc = channels.MarkovChurn(10, p_leave=0.9, p_join=0.05, min_active=3,
                                seed=0)
    masks = set()
    for _ in range(200):
        a = proc.step()
        assert a.sum() >= 3
        masks.add(a.tobytes())
    assert len(masks) > 1  # churn actually happens


def test_rotating_cohorts_rotation_and_determinism():
    proc = channels.RotatingCohorts(8, n_cohorts=4, hold=2)
    seen = [proc.value().copy()]
    for _ in range(7):
        seen.append(proc.step().copy())
    # hold=2: each mask repeats twice, cohorts go offline round-robin
    np.testing.assert_array_equal(seen[0], seen[1])
    assert not np.array_equal(seen[1], seen[2])
    assert all(a.sum() == 6 for a in seen)  # always exactly one cohort out
    offline = [tuple(np.nonzero(~a)[0]) for a in seen[::2]]
    assert offline == [(0, 1), (2, 3), (4, 5), (6, 7)]
    proc2 = channels.RotatingCohorts(8, n_cohorts=4, hold=2)
    np.testing.assert_array_equal(proc2.value(), seen[0])


def test_churn_schedule_epoch_increments_on_membership_change():
    """Static (adj, p): the membership mask alone drives the epochs."""
    n = 6
    sched = channels.ChurnSchedule(
        membership=channels.RotatingCohorts(n, n_cohorts=3, hold=2),
        adj=topology.ring(n, 1), p=np.full(n, 0.5))
    states = list(sched.rounds(8))
    assert [s.epoch_id for s in states] == [0, 0, 1, 1, 2, 2, 3, 3]
    assert all(s.active is not None and s.n_active == 4 for s in states)


def test_churn_schedule_composes_with_fading_and_drift():
    n = 8
    link = channels.MarkovLinkProcess(
        topology.fully_connected(n), p_up_to_down=0.3, p_down_to_up=0.4,
        seed=0)
    drift = channels.RandomWalkDrift(np.full(n, 0.5), sigma=0.05, seed=1)
    sched = channels.ChurnSchedule(
        membership=channels.MarkovChurn(n, p_leave=0.3, p_join=0.5,
                                        min_active=2, seed=2),
        link_process=link, p_process=drift)
    prev = None
    for s in sched.rounds(12):
        topology._validate(s.adj.copy())
        assert s.active.shape == (n,) and s.active.sum() >= 2
        if prev is not None:
            assert (s.epoch_id == prev.epoch_id) == (s.key() == prev.key())
        prev = s


# ----------------------------------- invariant 3: one trace across churn


def test_trace_count_one_across_membership_changes():
    """Acceptance: clients join/leave every round (n_active varies within
    n_max) and the jitted round step still compiles exactly once."""
    n, T = 8, 2
    loss_fn, batch, params = _quad_setting(n=n, T=T)
    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                      local_steps=T,
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    ss = sim.init_server_state(params)
    link = channels.MarkovLinkProcess(
        topology.fully_connected(n), p_up_to_down=0.3, p_down_to_up=0.5,
        seed=0)
    drift = channels.RandomWalkDrift(np.full(n, 0.6), sigma=0.05, seed=1)
    sched = channels.ChurnSchedule(
        membership=channels.MarkovChurn(n, p_leave=0.35, p_join=0.5,
                                        min_active=2, seed=3),
        link_process=link, p_process=drift)
    pol = channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)
    key = jax.random.key(0)
    n_actives = set()
    for ch in sched.rounds(8):
        n_actives.add(ch.n_active)
        key, sub = jax.random.split(key)
        params, ss, m = sim.run_round(sub, params, ss, batch, 0.1,
                                      A=pol.relay_matrix(ch), p=ch.p,
                                      active=ch.active)
        assert np.isfinite(float(m["loss"]))
    assert len(n_actives) > 1   # membership genuinely varied
    assert sim.trace_count == 1  # ... within one compiled step


# ------------------------------------------------------- kernel path parity


def test_kernel_fused_aggregate_masked_matches_reference():
    n = 8
    p, adj, A = _channel(n, seed=4)
    active = jnp.asarray([1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
    tau = jnp.asarray(np.random.default_rng(5).random(n) < 0.7, jnp.float32)
    upd = {"x": jnp.asarray(
        np.random.default_rng(6).standard_normal((n, 257)), jnp.float32)}
    w = 1.0 / jnp.maximum(jnp.sum(active), 1.0)
    got = kops.fused_aggregate(jnp.asarray(A, jnp.float32), tau, upd, w=w,
                               active=active, interpret=True)
    want = aggregation.colrel_increment(
        jnp.asarray(A, jnp.float32), tau, upd, n=n, fused=True, active=active)
    np.testing.assert_allclose(np.asarray(got["x"]), np.asarray(want["x"]),
                               rtol=1e-5, atol=1e-5)


def test_kernel_relay_mix_masked_zeroes_inactive_rows():
    n = 8
    _, _, A = _channel(n, seed=7)
    active = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1], jnp.float32)
    upd = {"x": jnp.asarray(
        np.random.default_rng(8).standard_normal((n, 130)), jnp.float32)}
    out = kops.relay_mix(jnp.asarray(A, jnp.float32), upd, active=active,
                         interpret=True)
    got = np.asarray(out["x"])
    assert np.all(got[2] == 0.0) and np.all(got[6] == 0.0)
    want = relay_lib.relay(relay_lib.mask_relay_matrix(A, active), upd)
    np.testing.assert_allclose(got, np.asarray(want["x"]), rtol=1e-5,
                               atol=1e-6)
