"""ShardedScanEngine: the multi-device schedule driver.

In-process (single local device): constructor/spec validation, the backend
dispatch rules under sharding, and the prefetcher ``place`` hook that
carries the sharded batch placement.  Subprocess (forced 8-device host
mesh, same pattern as `test_ring_relay.py`): the engine regression — both
exchange modes × both staging modes against the single-device fused scan
reference under rotating-cohort churn + correlated shadowing, at the shard
gate's tolerance (gather additionally bitwise across staging modes)."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import channels
from repro.bench.scenarios import ScenarioSpec
from repro.channels.scheduler import SegmentPrefetcher
from repro.core import topology
from repro.fl.engine import ShardedScanEngine
from repro.kernels.ops import validate_sharded_backend

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code, devices=8):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ------------------------------------------------- backend dispatch rules


def test_sharded_backend_gather_allows_kernels():
    assert validate_sharded_backend(
        "pallas_fused", shard="clients", exchange="gather"
    ) == "pallas_fused"
    assert validate_sharded_backend("einsum", shard="d") == "einsum"
    assert validate_sharded_backend(
        "einsum", shard="clients", exchange="ring"
    ) == "einsum"


def test_sharded_backend_ring_refuses_kernels():
    with pytest.raises(ValueError, match="ppermute"):
        validate_sharded_backend("pallas", shard="clients", exchange="ring")


def test_sharded_backend_dshard_refuses_kernels():
    with pytest.raises(ValueError, match="GSPMD"):
        validate_sharded_backend("pallas_fused", shard="d")


# ------------------------------------------------------- spec validation


def _shard_spec(**kw):
    base = dict(name="t", n_clients=8, rounds=8, step="shard", devices=8)
    base.update(kw)
    return ScenarioSpec(**base)


def test_shard_spec_valid_cases():
    assert _shard_spec().devices == 8
    assert _shard_spec(exchange="ring").exchange == "ring"
    assert _shard_spec(check_backend="pallas_fused").check_backend == "pallas_fused"
    assert _shard_spec(devices=2, shard="d").shard == "d"


def test_shard_spec_rejects_bad_configs():
    with pytest.raises(ValueError, match="devices >= 2"):
        _shard_spec(devices=1)
    with pytest.raises(ValueError, match="divide"):
        _shard_spec(n_clients=10)
    with pytest.raises(ValueError, match="relay policy"):
        _shard_spec(policy="none")
    with pytest.raises(ValueError, match="fused"):
        _shard_spec(strategy="colrel")
    with pytest.raises(ValueError, match="unknown exchange"):
        _shard_spec(exchange="butterfly")
    with pytest.raises(ValueError, match="ppermute"):
        _shard_spec(exchange="ring", relay_backend="pallas_fused")
    with pytest.raises(ValueError, match="ppermute"):
        _shard_spec(exchange="ring", check_backend="pallas_fused")
    with pytest.raises(ValueError, match="GSPMD"):
        _shard_spec(devices=2, shard="d", relay_backend="pallas")


def test_engine_rejects_bad_modes():
    with pytest.raises(ValueError, match="prefetch"):
        ShardedScanEngine(lambda *a, **k: None, mesh=None, prefetch="eager")
    with pytest.raises(ValueError, match="shard"):
        ShardedScanEngine(lambda *a, **k: None, mesh=None, shard="rows")


def test_engine_requires_policy():
    eng = ShardedScanEngine(lambda *a, **k: None, mesh=None, prefetch="serial")
    schedule = channels.StaticChannel(topology.ring(4, 1), np.full(4, 0.9))
    with pytest.raises(ValueError, match="policy"):
        eng.run_schedule(
            jax.random.key(0), {}, None, schedule=schedule, rounds=4,
            next_batch=lambda: {}, lr=0.1,
        )


# -------------------------------------------------- prefetcher place hook


def test_prefetcher_place_hook_replaces_default_transfer():
    """`place` substitutes the H2D transfer: the staged chunks must carry
    exactly its output (this is how the sharded engine device_puts each
    chunk under the mesh's NamedSharding)."""
    n, rounds, chunk = 4, 6, 3
    schedule = channels.StaticChannel(
        topology.ring(n, 1), np.full(n, 0.9, np.float32)
    )
    counter = iter(range(rounds))

    placed = []

    def place(host):
        placed.append(host)
        return jax.tree.map(lambda x: jnp.asarray(x) + 100.0, host)

    pf = SegmentPrefetcher(
        schedule, rounds, chunk=chunk,
        next_batch=lambda: {"c": np.full((n, 1), float(next(counter)), np.float32)},
        place=place,
    )
    items = list(pf)
    assert len(items) == rounds // chunk
    assert len(placed) == len(items)
    got = np.concatenate(
        [np.asarray(it.batches["c"])[: it.n_rounds] for it in items]
    )
    assert np.array_equal(got[:, 0, 0], 100.0 + np.arange(rounds))


# ------------------------------- in-process run on a single-device mesh


def test_engine_single_device_mesh_matches_reference():
    """The sharded step and engine are well-defined at k = 1 (shard_map
    over a 1-device clients mesh: the gather is an identity, the ring has
    no rotations) — and must match the single-device fused scan walk.
    This is the in-process leg of the regression; the real 8-device run is
    the subprocess test below."""
    from repro.bench.scenarios import build
    from repro.fl.distributed import (
        build_fused_scan_round_step,
        build_sharded_scan_round_step,
    )
    from repro.launch.mesh import make_client_mesh

    spec = ScenarioSpec(
        name="t", n_clients=4, rounds=8, local_steps=2, local_batch=2,
        dim=8, width=8, n_train=64, adj_every=4, p_every=4, drift_hold=4,
        churn="rotating", n_cohorts=2, churn_hold=4,
    )
    bundle = build(spec)
    loader = bundle.make_loader()
    batches = [loader.round_batch(spec.local_steps, spec.local_batch)
               for _ in range(spec.rounds)]
    mesh = make_client_mesh(1)
    kw = dict(n_clients=spec.n_clients, local_steps=spec.local_steps)
    ref_fn = jax.jit(build_fused_scan_round_step(bundle.loss_fn, **kw))

    schedule, policy = bundle.make_schedule(), bundle.make_policy()
    p_ref = bundle.init_fn(jax.random.key(spec.seed))
    ss, k_ref, stream = None, jax.random.key(spec.seed + 1), iter(batches)
    n_segments = 0
    for seg in schedule.segments(spec.rounds):
        n_segments += 1
        A = jnp.asarray(policy.relay_matrix(seg.state), jnp.float32)
        act = None if seg.active is None else jnp.asarray(seg.active, jnp.float32)
        stacked = jax.tree.map(
            lambda *xs: jnp.asarray(np.stack(xs)),
            *[next(stream) for _ in range(seg.n_rounds)],
        )
        k_ref, p_ref, ss, _ = ref_fn(
            k_ref, p_ref, ss, stacked, jnp.asarray(seg.p, jnp.float32),
            spec.lr, A, act,
        )

    for exchange in ("gather", "ring"):
        for prefetch in ("serial", "inline"):
            step = build_sharded_scan_round_step(
                bundle.loss_fn, mesh=mesh, exchange=exchange, **kw)
            eng = ShardedScanEngine(step, mesh=mesh, prefetch=prefetch)
            stream = iter(batches)
            p_s, _, metrics, k_s = eng.run_schedule(
                jax.random.key(spec.seed + 1),
                bundle.init_fn(jax.random.key(spec.seed)), None,
                schedule=bundle.make_schedule(), rounds=spec.rounds,
                next_batch=lambda: next(stream), lr=spec.lr,
                policy=bundle.make_policy(),
            )
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
                    err_msg=f"{exchange}/{prefetch}",
                )
            assert metrics["loss"].shape == (spec.rounds,)
            assert bool(jnp.all(
                jax.random.key_data(k_ref) == jax.random.key_data(k_s)))
            assert eng.trace_count == 1, (exchange, prefetch)
            assert eng.dispatches == n_segments, (exchange, prefetch)


# -------------------------------------- 8-device engine regression (slow)


@pytest.mark.slow
def test_sharded_engine_matches_single_device_reference():
    """Both exchanges × both staging modes vs the single-device fused scan
    walk, under rotating churn + correlated shadowing: params within the
    shard gate's 1e-5, identical key chain, one trace, one dispatch per
    epoch; gather staging modes bitwise-identical to each other."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.bench.scenarios import ScenarioSpec, build
from repro.fl.distributed import (
    build_fused_scan_round_step, build_sharded_scan_round_step)
from repro.fl.engine import ShardedScanEngine
from repro.launch.mesh import make_client_mesh

spec = ScenarioSpec(
    name="t", n_clients=8, rounds=16, local_steps=2, local_batch=4,
    dim=16, width=8, n_train=128, fading="corr_shadow", drift="static",
    adj_every=8, p_every=8, churn="rotating", n_cohorts=4, churn_hold=8,
)
bundle = build(spec)
loader = bundle.make_loader()
batches = [loader.round_batch(spec.local_steps, spec.local_batch)
           for _ in range(spec.rounds)]
mesh = make_client_mesh(8)
kw = dict(n_clients=spec.n_clients, local_steps=spec.local_steps)
ref_fn = jax.jit(build_fused_scan_round_step(bundle.loss_fn, **kw))

def run_ref():
    schedule, policy = bundle.make_schedule(), bundle.make_policy()
    params = bundle.init_fn(jax.random.key(spec.seed))
    ss, key, stream, losses = None, jax.random.key(spec.seed + 1), iter(batches), []
    n_segments = 0
    for seg in schedule.segments(spec.rounds):
        n_segments += 1
        A = jnp.asarray(policy.relay_matrix(seg.state), jnp.float32)
        act = None if seg.active is None else jnp.asarray(seg.active, jnp.float32)
        stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)),
                               *[next(stream) for _ in range(seg.n_rounds)])
        key, params, ss, ls = ref_fn(
            key, params, ss, stacked, jnp.asarray(seg.p, jnp.float32),
            spec.lr, A, act)
        losses.append(ls)
    return params, jnp.concatenate(losses), key, n_segments

def run_sharded(exchange, prefetch):
    step = build_sharded_scan_round_step(
        bundle.loss_fn, mesh=mesh, exchange=exchange, **kw)
    eng = ShardedScanEngine(step, mesh=mesh, prefetch=prefetch)
    stream = iter(batches)
    params, ss, metrics, key = eng.run_schedule(
        jax.random.key(spec.seed + 1), bundle.init_fn(jax.random.key(spec.seed)),
        None, schedule=bundle.make_schedule(), rounds=spec.rounds,
        next_batch=lambda: next(stream), lr=spec.lr,
        policy=bundle.make_policy())
    return params, metrics["loss"], key, eng

p_ref, l_ref, k_ref, n_segments = run_ref()
finals = {}
for exchange in ("gather", "ring"):
    for prefetch in ("serial", "inline"):
        p_s, l_s, k_s, eng = run_sharded(exchange, prefetch)
        finals[exchange, prefetch] = p_s
        mad = max(float(np.max(np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))))
                  for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)))
        assert mad < 1e-5, (exchange, prefetch, mad)
        lmad = float(np.max(np.abs(np.asarray(l_ref) - np.asarray(l_s))))
        assert lmad < 1e-4, (exchange, prefetch, lmad)
        assert bool(jnp.all(jax.random.key_data(k_ref) == jax.random.key_data(k_s))), (
            exchange, prefetch)
        assert eng.trace_count == 1, (exchange, prefetch, eng.trace_count)
        assert eng.dispatches == n_segments, (exchange, prefetch, eng.dispatches)

for exchange in ("gather", "ring"):
    pa, pb = finals[exchange, "serial"], finals[exchange, "inline"]
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb))), exchange
print("OK")
""")
    assert "OK" in out
