"""Time-varying channel subsystem: processes, schedules, the adaptive OPT-α
scheduler, and the A-as-traced-input contract of the round steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import connectivity, opt_alpha, relay as relay_lib, topology
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt


# ---------------------------------------------------------------- link state

def test_markov_transition_matrix_rows_stochastic():
    proc = channels.MarkovLinkProcess(
        topology.fully_connected(6), p_up_to_down=0.3, p_down_to_up=0.1)
    P = proc.transition_matrix()
    np.testing.assert_allclose(P.sum(axis=1), 1.0)
    assert P[1, 0] == 0.3 and P[0, 1] == 0.1  # up→down, down→up


def test_markov_stationary_distribution_matches_transition_matrix():
    """Empirical per-edge up-fraction ≈ π = q_du / (q_ud + q_du), and π is a
    left eigenvector of the transition matrix."""
    q_ud, q_du = 0.3, 0.1
    proc = channels.MarkovLinkProcess(
        topology.fully_connected(8), p_up_to_down=q_ud, p_down_to_up=q_du,
        init="stationary", seed=0)
    pi = proc.stationary_up_prob
    assert pi == pytest.approx(q_du / (q_ud + q_du))
    stat = np.array([1.0 - pi, pi])
    np.testing.assert_allclose(stat @ proc.transition_matrix(), stat)

    rounds, frac = 3000, 0.0
    for _ in range(rounds):
        frac += proc.step().sum() / proc.base.sum()
    assert frac / rounds == pytest.approx(pi, abs=0.02)


def test_markov_adjacency_on_base_support_and_valid():
    base = topology.ring(10, 2)
    proc = channels.gilbert_elliott(base, stay_up=0.7, stay_down=0.6, seed=1)
    for _ in range(50):
        adj = proc.step()
        topology._validate(adj)          # symmetric, zero diagonal
        assert not np.any(adj & ~base)   # never an edge outside the envelope


# ------------------------------------------------------------------ mobility

def test_geometric_adjacency_symmetric_zero_diagonal():
    mob = channels.RandomWaypointMobility(12, radius=0.4, speed=0.1, seed=0)
    seen = set()
    for _ in range(40):
        adj = mob.step()
        out = topology._validate(adj.copy())
        np.testing.assert_array_equal(out, adj)
        assert not adj.diagonal().any()
        seen.add(adj.tobytes())
    assert len(seen) > 1  # the graph actually moves
    assert np.all(mob.positions >= 0) and np.all(mob.positions <= mob.area)


# --------------------------------------------------------------------- drift

def test_piecewise_constant_drift_holds_then_jumps():
    p0 = connectivity.paper_heterogeneous().p
    d = channels.PiecewiseConstantDrift(p0, hold=4, seed=0)
    sched = channels.TimeVaryingChannel(
        adj=topology.ring(10, 1), p_process=d)
    states = list(sched.rounds(12))
    # epochs change exactly at the hold boundary: rounds 0-3, 4-7, 8-11
    assert [s.epoch_id for s in states] == [0] * 4 + [1] * 4 + [2] * 4
    np.testing.assert_array_equal(states[0].p, states[3].p)
    assert not np.array_equal(states[3].p, states[4].p)


def test_random_walk_drift_stays_in_bounds():
    d = channels.RandomWalkDrift(
        np.full(8, 0.5), sigma=0.3, low=0.1, high=0.9, seed=0)
    for _ in range(200):
        p = d.step()
        assert np.all(p >= 0.1) and np.all(p <= 0.9)


# ----------------------------------------------------------------- schedules

def test_static_channel_single_epoch():
    sched = channels.StaticChannel(
        topology.ring(6, 1), np.full(6, 0.4))
    states = list(sched.rounds(5))
    assert [s.epoch_id for s in states] == [0] * 5
    assert [s.round for s in states] == list(range(5))


def test_timevarying_epoch_increments_only_on_change():
    link = channels.MarkovLinkProcess(
        topology.fully_connected(8), p_up_to_down=0.5, p_down_to_up=0.5,
        seed=2)
    sched = channels.TimeVaryingChannel(
        link_process=link, p=np.full(8, 0.3), adj_every=3)
    states = list(sched.rounds(9))
    for a, b in zip(states, states[1:]):
        same = a.key() == b.key()
        assert (b.epoch_id == a.epoch_id) == same
    # within a coherence block the state is value-identical
    assert states[0].key() == states[1].key() == states[2].key()


# ------------------------------------------------- warm start / scheduler

def test_warm_start_weights_feasible_on_new_channel():
    rng = np.random.default_rng(0)
    p1, p2 = rng.uniform(0.1, 0.9, 10), rng.uniform(0.1, 0.9, 10)
    adj1, adj2 = topology.ring(10, 2), topology.ring(10, 1)  # support shrinks
    A1 = opt_alpha.optimize(p1, adj1, sweeps=30).A
    A0 = opt_alpha.warm_start_weights(p2, adj2, A1)
    assert relay_lib.neighbor_support(A0, adj2)
    np.testing.assert_allclose(
        opt_alpha.unbiasedness_residual(p2, A0), 0.0, atol=1e-9)


def test_warm_start_matches_cold_start_S_on_perturbed_channel():
    p = connectivity.paper_heterogeneous().p.astype(np.float64)
    adj = topology.ring(10, 2)
    A_prev = opt_alpha.optimize(p, adj, sweeps=60).A
    # perturb: p drifts and one link fades
    p2 = np.clip(p + np.random.default_rng(1).normal(0, 0.05, 10), 0.05, 0.95)
    adj2 = adj.copy()
    adj2[0, 2] = adj2[2, 0] = False
    cold = opt_alpha.optimize(p2, adj2, sweeps=60)
    warm = opt_alpha.optimize(
        p2, adj2, sweeps=60, A0=opt_alpha.warm_start_weights(p2, adj2, A_prev))
    S_cold, S_warm = cold.S_history[-1], warm.S_history[-1]
    assert S_warm == pytest.approx(S_cold, rel=1e-6)
    assert warm.sweeps <= cold.sweeps  # the whole point of warm starting


def test_adaptive_scheduler_lru_cache_and_warm_stats():
    p = np.full(8, 0.5, dtype=np.float32)
    s1 = channels.ChannelState(0, 0, topology.ring(8, 1), p)
    s2 = channels.ChannelState(1, 1, topology.ring(8, 2), p)
    pol = channels.AdaptiveOptAlpha(sweeps=30, warm_sweeps=10, cache_size=4)
    A1 = pol.relay_matrix(s1)
    A2 = pol.relay_matrix(s2)
    A1_again = pol.relay_matrix(s1)
    np.testing.assert_array_equal(A1, A1_again)  # served from cache
    assert pol.stats.solves == 2 and pol.stats.cache_hits == 1
    assert pol.stats.cache_misses == 2  # every solve was a miss
    assert pol.stats.cache_hits + pol.stats.cache_misses == pol.stats.rounds
    assert pol.stats.evictions == 0  # cache_size=4 holds both entries
    assert pol.stats.warm_solves == 1  # second solve warm-started off A1
    assert not np.array_equal(A1, A2)


def test_adaptive_scheduler_cache_eviction():
    p = np.full(6, 0.5, dtype=np.float32)
    pol = channels.AdaptiveOptAlpha(sweeps=10, cache_size=2)
    states = [channels.ChannelState(i, i, topology.ring(6, 1 + i % 3), p + i / 100)
              for i in range(3)]
    for s in states:
        pol.relay_matrix(s)
    pol.relay_matrix(states[0])  # evicted by the 2-deep LRU → re-solved
    assert pol.stats.solves == 4 and pol.stats.cache_hits == 0
    assert pol.stats.cache_misses == 4  # misses count exactly the solves
    # 4 inserts into a 2-deep cache ⇒ 2 evictions (states[0] then states[1])
    assert pol.stats.evictions == 2


def test_stale_policy_projects_onto_live_topology():
    p = connectivity.paper_heterogeneous().p
    rich, poor = topology.ring(10, 2), topology.ring(10, 1)
    pol = channels.StaleOptAlpha(sweeps=30)
    A_full = pol.relay_matrix(channels.ChannelState(0, 0, rich, p))
    A_proj = pol.relay_matrix(channels.ChannelState(1, 1, poor, p))
    assert relay_lib.neighbor_support(A_proj, poor)
    # projection loses relay mass (the staleness penalty is real)
    assert A_proj.sum() < A_full.sum()


# ------------------------------------- A as traced input in the round steps

def _quad_setting(n=6, dim=4, T=2):
    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))

    rng = np.random.default_rng(0)
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, 8, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    return loss_fn, batch, params


def test_A_as_argument_bit_identical_to_A_as_constant():
    """Static channel: passing A by value computes bit-for-bit the same round
    as the seed's closure-constant formulation."""
    n, T = 6, 2
    loss_fn, batch, params = _quad_setting(n=n, dim=4, T=T)
    p = np.linspace(0.2, 0.9, n)
    A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=20).A
    tau = jnp.asarray([1, 0, 1, 1, 0, 1], jnp.float32)

    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel", A=A, p=p,
                      local_steps=T,
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    by_value = sim._round(params, None, batch, tau, sim.A, 0.1, None)

    A_const = sim.A  # closure constant, folded at trace time

    @jax.jit
    def const_round(params, server_state, batch, tau, lr):
        return sim._round_impl(params, server_state, batch, tau, A_const, lr,
                               None)

    by_constant = const_round(params, None, batch, tau, 0.1)

    for leaf_v, leaf_c in zip(jax.tree.leaves(by_value),
                              jax.tree.leaves(by_constant)):
        np.testing.assert_array_equal(np.asarray(leaf_v), np.asarray(leaf_c))


def test_simulator_not_retraced_across_channel_epochs():
    """Acceptance: trace count == 1 while (A, p, τ) values change per round."""
    n, T = 6, 2
    loss_fn, batch, params = _quad_setting(n=n, dim=4, T=T)
    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                      local_steps=T,
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    ss = sim.init_server_state(params)
    link = channels.MarkovLinkProcess(
        topology.fully_connected(n), p_up_to_down=0.4, p_down_to_up=0.4,
        seed=0)
    drift = channels.RandomWalkDrift(np.full(n, 0.5), sigma=0.1, seed=1)
    sched = channels.TimeVaryingChannel(link_process=link, p_process=drift)
    pol = channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)
    key = jax.random.key(0)
    epochs = set()
    for ch in sched.rounds(6):
        epochs.add(ch.epoch_id)
        key, sub = jax.random.split(key)
        params, ss, _ = sim.run_round(sub, params, ss, batch, 0.1,
                                      A=pol.relay_matrix(ch), p=ch.p)
    assert len(epochs) > 1          # the channel genuinely changed
    assert sim.trace_count == 1     # ... and the step still compiled once


def test_distributed_round_step_A_argument_no_retrace_and_matches():
    """build_round_step: call-time A equals build-time A numerically, and
    swapping A values does not retrace the jitted step."""
    from repro.fl.distributed import build_round_step

    n = 6
    loss_fn, batch, params = _quad_setting(n=n, dim=4, T=1)
    batch = {"c": batch["c"][:, :1]}
    p = np.linspace(0.2, 0.9, n)
    A1 = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=20).A
    A2 = opt_alpha.optimize(p, topology.ring(n, 2), sweeps=20).A
    tau = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    opt = ClientOpt(kind="sgd", weight_decay=0.0)

    for mode in ("faithful", "fused"):
        static = build_round_step(loss_fn, n_clients=n, local_steps=1, A=A1,
                                  relay_mode=mode, client_opt=opt)
        dynamic = build_round_step(loss_fn, n_clients=n, local_steps=1,
                                   relay_mode=mode, client_opt=opt)
        traces = []

        def counted(params, ss, batch, tau, lr, A):
            traces.append(1)
            return dynamic(params, ss, batch, tau, lr, A)

        jitted = jax.jit(counted)
        want, _, _ = jax.jit(static)(params, None, batch, tau, 0.1)
        got1, _, _ = jitted(params, None, batch, tau, 0.1,
                            jnp.asarray(A1, jnp.float32))
        got2, _, _ = jitted(params, None, batch, tau, 0.1,
                            jnp.asarray(A2, jnp.float32))
        np.testing.assert_allclose(np.asarray(got1["x"]),
                                   np.asarray(want["x"]), atol=1e-6)
        assert len(traces) == 1, f"retraced in mode {mode}"
        assert not np.allclose(np.asarray(got1["x"]), np.asarray(got2["x"]))
