"""Ring ppermute relay == einsum relay, on real meshes (subprocess: device
count must be forced before jax init)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code, devices=8):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ring_equals_einsum_single_axis():
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import topology, opt_alpha, connectivity, relay as relay_lib
from repro.fl.ring import make_ring_round_mixer
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(8, 1)
n = 8
p = connectivity.heterogeneous_profile(n).p
A = opt_alpha.optimize(p, topology.ring(n, 2), sweeps=10).A
rng = np.random.default_rng(0)
deltas = {"w": jnp.asarray(rng.standard_normal((n, 12, 5)), jnp.float32),
          "b": jnp.asarray(rng.standard_normal((n, 7)), jnp.float32)}
tau = jnp.asarray(rng.random(n) < p, jnp.float32)
w = 1.0 / n
want = relay_lib.masked_aggregate(tau, relay_lib.relay(A, deltas), w=w)
with mesh:
    mixer = make_ring_round_mixer(A, w=w, mesh=mesh, client_axes=("data",))
    got = jax.jit(mixer)(tau, deltas)
for k in deltas:
    err = float(jnp.abs(got[k] - want[k]).max())
    assert err < 1e-5, (k, err)
print("OK")
""")
    assert "OK" in out


def test_block_ring_flat_equals_einsum():
    """Block-ring on the raveled (n, D) buffer — m = n/k clients per device
    — matches `aggregation.colrel_increment_flat` for k ∈ {4, 8} devices,
    with and without a churn mask (masking is the caller's job, mirroring
    the sharded round step's ring branch)."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import topology, opt_alpha, connectivity, aggregation
from repro.core import relay as relay_lib
from repro.fl.ring import ring_colrel_increment_flat
from repro.launch.mesh import make_client_mesh

n, D = 8, 48
p = connectivity.heterogeneous_profile(n).p
A = opt_alpha.optimize(p, topology.ring(n, 2), sweeps=10).A
rng = np.random.default_rng(3)
buf = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
tau = jnp.asarray(rng.random(n) < p, jnp.float32)
churn = jnp.asarray(rng.random(n) < 0.7, jnp.float32)
for k in (4, 8):
    mesh = make_client_mesh(k)
    for label, active in (("full", None), ("churn", churn)):
        want = aggregation.colrel_increment_flat(A, tau, buf, n=n, active=active)
        w = aggregation.active_weight(active, n=n)
        A_eff, tau_eff = (A, tau) if active is None else (
            relay_lib.mask_relay_matrix(A, active), tau * active)

        def local(A_, t_, w_, b_):
            return ring_colrel_increment_flat(
                A_, t_, b_, w=w_, axis_name="clients", n_shards=k)

        got = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P(None, None), P(None), P(), P("clients", None)),
            out_specs=P(None), check_rep=False,
        ))(jnp.asarray(A_eff, jnp.float32), tau_eff, jnp.asarray(w, jnp.float32), buf)
        err = float(jnp.abs(got - want).max())
        assert err < 1e-5, (k, label, err)
print("OK")
""")
    assert "OK" in out


def test_ring_equals_einsum_multi_axis():
    """Client axis spans ("pod","data") — the multi-pod layout."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import topology, opt_alpha, connectivity, relay as relay_lib
from repro.fl.ring import make_ring_round_mixer
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(4, 1, pod=2)
n = 8
p = connectivity.heterogeneous_profile(n).p
A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=10).A
rng = np.random.default_rng(1)
deltas = {"w": jnp.asarray(rng.standard_normal((n, 6)), jnp.float32)}
tau = jnp.ones((n,), jnp.float32)
w = 1.0 / n
want = relay_lib.masked_aggregate(tau, relay_lib.relay(A, deltas), w=w)
with mesh:
    mixer = make_ring_round_mixer(A, w=w, mesh=mesh, client_axes=("pod", "data"))
    got = jax.jit(mixer)(tau, deltas)
err = float(jnp.abs(got["w"] - want["w"]).max())
assert err < 1e-5, err
print("OK")
""")
    assert "OK" in out
