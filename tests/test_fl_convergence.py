"""Integration: the paper's convergence claims on a strongly-convex ERM where
Theorem 1's assumptions hold exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity, opt_alpha, topology
from repro.data.synthetic import quadratic_problem
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt


@pytest.fixture(scope="module")
def quad():
    n, dim, T = 10, 20, 4
    H, centers, x_star = quadratic_problem(dim, n, seed=0)
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(10, 1)
    A = opt_alpha.optimize(p, adj, sweeps=60).A
    A0 = opt_alpha.initial_weights(p, adj)
    Hj = jnp.asarray(H)

    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.einsum("bi,ij,bj->b", diff, Hj, diff))

    rounds = 150
    noise = np.asarray(
        jax.random.normal(jax.random.key(1), (rounds, n, T, 8, dim))) * 0.5
    batches = centers[None, :, None, None, :] + noise
    return dict(n=n, T=T, loss_fn=loss_fn, p=p, A=A, A0=A0,
                batches=batches, x_star=x_star, rounds=rounds, dim=dim)


def _run(quad, strategy, A=None, seed=42):
    sim = FLSimulator(
        quad["loss_fn"], n_clients=quad["n"], strategy=strategy, A=A, p=quad["p"],
        local_steps=quad["T"], client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    params = {"x": jnp.zeros((quad["dim"],))}
    ss = sim.init_server_state(params)
    key = jax.random.key(seed)
    for r in range(quad["rounds"]):
        key, sub = jax.random.split(key)
        lr = min(0.4, 4.0 / (r * quad["T"] + 1))
        params, ss, _ = sim.run_round(
            sub, params, ss, {"c": jnp.asarray(quad["batches"][r])}, lr)
    return float(jnp.sum((params["x"] - jnp.asarray(quad["x_star"])) ** 2))


# The convergence claims are about *expected* error; a single τ-stream
# realization fluctuates by >2x around it (the final-iterate error is
# dominated by the last few rounds' Bernoulli draws).  Average a few seeds
# so the bounds test the mean, not one draw — see ROADMAP.md for the
# measured per-seed spread that motivated this.
_SEEDS = (42, 43, 44)


def _run_mean(quad, strategy, A=None):
    return float(np.mean([_run(quad, strategy, A, seed=s) for s in _SEEDS]))


@pytest.mark.slow
def test_colrel_beats_fedavg_dropout(quad):
    err_colrel = _run(quad, "colrel_fused", quad["A"])
    err_blind = _run(quad, "fedavg_blind")
    assert err_colrel < err_blind * 0.3, (err_colrel, err_blind)


@pytest.mark.slow
def test_optimized_weights_beat_init(quad):
    err_opt = _run_mean(quad, "colrel_fused", quad["A"])
    err_init = _run_mean(quad, "colrel_fused", quad["A0"])
    assert err_opt < err_init * 1.05, (err_opt, err_init)


@pytest.mark.slow
def test_colrel_within_reach_of_no_dropout(quad):
    err_colrel = _run_mean(quad, "colrel_fused", quad["A"])
    err_full = _run_mean(quad, "no_dropout")
    # Unbiased relaying closes most of the gap to perfect connectivity, but a
    # residual variance floor ∝ S(p, A)·lr_final remains on this channel
    # (1-ring, several p_i = 0.1): measured mean gap ≈ 120x across seeds
    # (60-170x per seed).  250x bounds the order of magnitude.
    assert err_colrel < 250 * max(err_full, 1e-4), (err_colrel, err_full)


def test_faithful_equals_fused_rounds(quad):
    """The two relay schedules are algebraically identical per round."""
    sim_f = FLSimulator(
        quad["loss_fn"], n_clients=quad["n"], strategy="colrel", A=quad["A"],
        p=quad["p"], local_steps=quad["T"],
        client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    sim_g = FLSimulator(
        quad["loss_fn"], n_clients=quad["n"], strategy="colrel_fused", A=quad["A"],
        p=quad["p"], local_steps=quad["T"],
        client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    params = {"x": jnp.ones((quad["dim"],))}
    batch = {"c": jnp.asarray(quad["batches"][0])}
    key = jax.random.key(7)
    p1, _, _ = sim_f.run_round(key, params, None, batch, 0.1)
    p2, _, _ = sim_g.run_round(key, params, None, batch, 0.1)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]), atol=1e-5)


def test_distributed_round_matches_simulator(quad):
    """fl.distributed (mesh path, T=1) computes the same update as the
    single-host simulator on identical inputs — both relay modes."""
    from repro.fl.distributed import build_round_step

    n = quad["n"]
    params = {"x": jnp.ones((quad["dim"],))}
    batch1 = {"c": jnp.asarray(quad["batches"][0][:, :1])}  # (n,1,b,dim)
    tau = jnp.asarray(np.random.default_rng(0).random(n) < quad["p"], jnp.float32)
    lr = 0.1

    sim = FLSimulator(
        quad["loss_fn"], n_clients=n, strategy="colrel", A=quad["A"], p=quad["p"],
        local_steps=1, client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    want, _, _ = sim._round(params, None, batch1, tau, sim.A, lr, None)

    for mode in ("faithful", "fused"):
        step = build_round_step(
            quad["loss_fn"], n_clients=n, local_steps=1, A=quad["A"],
            relay_mode=mode, client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
        got, _, _ = jax.jit(step)(params, None, batch1, tau, lr)
        np.testing.assert_allclose(
            np.asarray(got["x"]), np.asarray(want["x"]), atol=1e-5,
            err_msg=f"relay_mode={mode}")


@pytest.mark.slow
def test_noniid_failure_mode_and_colrel_rescue():
    """Paper Fig. 4 in miniature: sort-and-partition non-IID + dropout makes
    blind FedAvg fail; ColRel recovers most accuracy."""
    from repro.data.loader import FederatedLoader
    from repro.data.partition import sort_and_partition
    from repro.data.synthetic import gaussian_classification

    n, dim, ncls = 10, 32, 10
    ds = gaussian_classification(4000, dim=dim, n_classes=ncls, snr=0.8, seed=0)
    parts = sort_and_partition(ds, n, shards_per_client=1, seed=0)
    loader = FederatedLoader(ds, parts, seed=0)
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(n, 2)
    A = opt_alpha.optimize(p, adj, sweeps=40).A

    def loss_fn(params, batch):
        logits = batch["inputs"] @ params["w"] + params["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    test = gaussian_classification(2000, dim=dim, n_classes=ncls, snr=0.8, seed=9)

    def acc(params):
        logits = jnp.asarray(test.inputs) @ params["w"] + params["b"]
        return float((jnp.argmax(logits, -1) == jnp.asarray(test.labels)).mean())

    results = {}
    for name, strat, Am in [("blind", "fedavg_blind", None),
                            ("colrel", "colrel_fused", A)]:
        sim = FLSimulator(loss_fn, n_clients=n, strategy=strat, A=Am, p=p,
                          local_steps=4,
                          client_opt=ClientOpt(kind="sgd", weight_decay=1e-4))
        params = {"w": jnp.zeros((dim, ncls)), "b": jnp.zeros((ncls,))}
        ss = sim.init_server_state(params)
        key = jax.random.key(1)
        for r in range(10):
            key, sub = jax.random.split(key)
            batch = loader.round_batch(4, 16)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, ss, _ = sim.run_round(sub, params, ss, batch, 0.5)
        results[name] = acc(params)
    assert results["colrel"] > results["blind"] + 0.15, results
