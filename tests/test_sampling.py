"""Cohort sampling (ISSUE 9): CohortSampler strategies, composition with
churn and correlated shadowing, and the end-to-end contracts that make
per-round cohorts safe — sampled-mask unbiasedness of the aggregate,
trace_count ≤ 2 across cohort changes, and segment-vs-einsum parity under
churn.  Plus the schedule's adjacency-snapshot reuse that makes n ≫ 10³
emission O(1) when the graph is static.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import aggregation, opt_alpha, topology
from repro.fl.simulator import FLSimulator
from repro.optim.sgd import ClientOpt

# ------------------------------------------------------------- strategies


def test_uniform_strategy_rate_and_bounds():
    s = channels.CohortSampler(64, strategy="uniform", rate=0.25, seed=0)
    sizes = []
    for _ in range(300):
        a = s.step()
        assert a.shape == (64,) and a.any()
        sizes.append(a.sum())
    assert np.mean(sizes) == pytest.approx(64 * 0.25, rel=0.15)


def test_fixed_k_strategy_exact_cohort_size():
    s = channels.CohortSampler(40, strategy="fixed_k", k=7, seed=1)
    seen = set()
    for _ in range(50):
        a = s.step()
        assert a.sum() == 7
        seen.add(a.tobytes())
    assert len(seen) > 10  # cohorts genuinely vary


def test_fixed_k_clamps_to_member_count():
    base = channels.StaticMembership(np.arange(10) < 3)
    s = channels.CohortSampler(10, strategy="fixed_k", k=8, base=base, seed=2)
    for _ in range(5):
        a = s.step()
        assert a.sum() == 3 and a[:3].all()


def test_expander_strategy_is_deterministic_and_mixes():
    mk = lambda: channels.CohortSampler(32, strategy="expander", k=4, seed=9)
    s1, s2 = mk(), mk()
    masks = []
    for _ in range(12):
        a1, a2 = s1.step(), s2.step()
        np.testing.assert_array_equal(a1, a2)  # no RNG: reproducible
        assert a1.sum() <= 4 and a1.any()
        masks.append(a1)
    # over a stride cycle the cohorts cover a spread of the index space
    assert np.vstack(masks).any(axis=0).sum() > 16


def test_sampler_never_emits_empty_cohort():
    # rate low enough that raw Bernoulli draws frequently miss everyone
    s = channels.CohortSampler(6, strategy="uniform", rate=0.02, seed=3)
    for _ in range(100):
        assert s.step().any()


def test_resample_every_holds_cohort_between_redraws():
    s = channels.CohortSampler(20, strategy="fixed_k", k=5, resample_every=3,
                               seed=4)
    masks = [s.value().copy()] + [s.step().copy() for _ in range(6)]
    np.testing.assert_array_equal(masks[1], masks[2])
    assert not np.array_equal(masks[2], masks[3])  # step 3: redraw
    np.testing.assert_array_equal(masks[4], masks[5])


def test_sampler_rejects_bad_arguments():
    with pytest.raises(ValueError, match="strategy"):
        channels.CohortSampler(8, strategy="stratified")
    with pytest.raises(ValueError, match="rate"):
        channels.CohortSampler(8, strategy="uniform")
    with pytest.raises(ValueError, match="k <= n_max"):
        channels.CohortSampler(8, strategy="fixed_k", k=9)


# -------------------------------------------------- composition with churn


def test_cohort_is_intersection_of_membership_and_sample():
    base = channels.RotatingCohorts(12, n_cohorts=3, hold=1)
    s = channels.CohortSampler(12, strategy="fixed_k", k=12, base=base, seed=5)
    for _ in range(9):
        a = s.step()
        members = base.value()
        assert not a[~members].any()  # sampled ∧ ¬member never active
        assert a.sum() <= members.sum()


def test_churn_schedule_with_sampler_epochs_track_cohorts():
    n = 16
    sched = channels.ChurnSchedule(
        membership=channels.CohortSampler(
            n, strategy="fixed_k", k=4,
            base=channels.RotatingCohorts(n, n_cohorts=4, hold=2), seed=6,
        ),
        adj=topology.ring(n, 2),
        p=np.full(n, 0.6),
    )
    states = list(sched.rounds(10))
    # per-round cohorts: every round opens a new epoch (static adj and p,
    # so the active mask alone drives the epoch id)
    assert [s.epoch_id for s in states] == list(range(10))
    for s in states:
        assert s.active is not None and 1 <= s.n_active <= 4


def test_sampler_composes_with_correlated_shadowing():
    """The jointly-sampled (adj, p) stream from a shadowing field composes
    with cohort sampling: masks stay consistent and every emitted state is a
    valid channel."""
    n = 12
    field = channels.ShadowingField(
        channels.circle_positions(n), corr_length=0.4, rho=0.9, sigma=1.0,
        seed=7,
    )
    link = channels.ShadowedLinkProcess(
        topology.ring(n, 2), field, threshold=1.0
    )
    sched = channels.ChurnSchedule(
        membership=channels.CohortSampler(n, strategy="fixed_k", k=5, seed=8),
        link_process=link,
        p=np.full(n, 0.7),
        adj_every=2,
    )
    prev = None
    for s in sched.rounds(12):
        topology._validate(s.adj.copy())
        assert s.active.sum() == 5
        if prev is not None:
            assert (s.epoch_id == prev.epoch_id) == (s.key() == prev.key())
        prev = s


# --------------------------------------------- unbiasedness of the aggregate


def test_fixed_k_aggregate_is_unbiased_over_cohorts():
    """E over cohorts of the n/k-corrected blind sum recovers the full-
    membership mean: inclusion probability is k/m for every member, so the
    cohort-masked no_dropout increment, scaled by m_active/k... — here we
    check the *measured* inclusion frequency and the masked-mean identity
    directly, which is what the renormalized weight 1/n_active relies on."""
    n, k, rounds = 24, 6, 4000
    s = channels.CohortSampler(n, strategy="fixed_k", k=k, seed=10)
    counts = np.zeros(n)
    upd = jnp.asarray(np.random.default_rng(11).standard_normal((n, 3)),
                      jnp.float32)
    agg = aggregation.make_aggregator("no_dropout", n=n)
    acc = np.zeros(3)
    for _ in range(rounds):
        a = s.step()
        counts += a
        inc = agg.fn(jnp.ones(n), upd, None, jnp.asarray(a, jnp.float32))
        acc += np.asarray(inc)
    # every client included with frequency k/n
    np.testing.assert_allclose(counts / rounds, k / n, atol=0.02)
    # the average cohort-mean converges to the full mean
    np.testing.assert_allclose(
        acc / rounds, np.asarray(upd).mean(axis=0), atol=0.05
    )


# ------------------------------------------------- trace-count + parity e2e


def _quad_setting(n, dim=4, T=2, seed=0):
    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(diff**2, axis=-1))

    rng = np.random.default_rng(seed)
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, 4, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    return loss_fn, batch, params


def test_trace_count_stays_one_across_cohort_changes():
    """Per-round cohorts + per-round sparse re-solves: the compiled step
    must not retrace — EdgeRelay structure and mask shapes are static."""
    n, T = 18, 2
    loss_fn, batch, params = _quad_setting(n, T=T)
    rng = np.random.default_rng(12)
    sched = channels.ChurnSchedule(
        membership=channels.CohortSampler(
            n, strategy="fixed_k", k=6,
            base=channels.RotatingCohorts(n, n_cohorts=3, hold=2), seed=13,
        ),
        adj=topology.random_geometric(n, 0.5, seed=14),
        p=rng.uniform(0.3, 0.9, n).astype(np.float32),
    )
    pol = channels.SparseOptAlpha(sweeps=30, warm_sweeps=10)
    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                      local_steps=T, relay_backend="segment",
                      client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
    ss = sim.init_server_state(params)
    key = jax.random.key(0)
    cohorts = set()
    for ch in sched.rounds(8):
        cohorts.add(ch.active.tobytes())
        key, sub = jax.random.split(key)
        params, ss, m = sim.run_round(sub, params, ss, batch, 0.1,
                                      A=pol.relay_matrix(ch), p=ch.p,
                                      active=ch.active)
        assert np.isfinite(float(m["loss"]))
    assert len(cohorts) > 1
    assert sim.trace_count == 1
    assert pol.stats.solves == len(cohorts)


def test_segment_vs_einsum_trajectory_parity_under_churn():
    """The same cohort-sampled schedule driven through both backends lands
    on (numerically) the same model: the SparseOptAlpha EdgeRelays feed the
    segment path, their densified twins feed the einsum path."""
    n, T = 14, 2
    loss_fn, batch, params0 = _quad_setting(n, T=T, seed=15)
    rng = np.random.default_rng(16)
    p = rng.uniform(0.2, 0.9, n).astype(np.float32)
    adj = topology.random_geometric(n, 0.55, seed=17)

    def run(backend):
        sched = channels.ChurnSchedule(
            membership=channels.CohortSampler(n, strategy="fixed_k", k=5,
                                              seed=18),
            adj=adj, p=p,
        )
        pol = channels.SparseOptAlpha(sweeps=60, warm_sweeps=20)
        sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                          local_steps=T, relay_backend=backend,
                          client_opt=ClientOpt(kind="sgd", weight_decay=0.0))
        params, ss = params0, sim.init_server_state(params0)
        key = jax.random.key(1)
        for ch in sched.rounds(6):
            key, sub = jax.random.split(key)
            A = pol.relay_matrix(ch)
            params, ss, _ = sim.run_round(sub, params, ss, batch, 0.1,
                                          A=A, p=ch.p, active=ch.active)
        return np.asarray(params["x"])

    np.testing.assert_allclose(run("segment"), run("einsum"),
                               rtol=1e-5, atol=1e-6)


def test_sparse_policy_caches_and_warm_starts_across_cohorts():
    n = 16
    rng = np.random.default_rng(19)
    p = rng.uniform(0.2, 0.9, n).astype(np.float32)
    adj = topology.ring(n, 2)
    m1 = np.arange(n) < 8
    m2 = np.arange(n) >= 8
    pol = channels.SparseOptAlpha(sweeps=40, warm_sweeps=10)
    A1 = pol.relay_matrix(channels.ChannelState(0, 0, adj, p, m1))
    A2 = pol.relay_matrix(channels.ChannelState(1, 1, adj, p, m2))
    A1_again = pol.relay_matrix(channels.ChannelState(2, 0, adj, p, m1))
    assert pol.stats.solves == 2 and pol.stats.cache_hits == 1
    np.testing.assert_array_equal(np.asarray(A1.vals), np.asarray(A1_again.vals))
    # inactive endpoints carry exactly zero on the shared structure
    rows, cols = np.asarray(A1.rows), np.asarray(A1.cols)
    vals = np.asarray(A1.vals)
    dead = ~m1[rows] | ~m1[cols]
    assert np.all(vals[dead] == 0.0)
    assert not np.array_equal(vals, np.asarray(A2.vals))


# ------------------------------------------------ schedule snapshot reuse


def test_static_adjacency_snapshot_is_reused_across_rounds():
    """The O(n²) copy + serialization of an unchanged adjacency happens once
    per run, not once per round — the emitted states share one read-only
    snapshot (value-equal keys, identical buffers)."""
    n = 32
    sched = channels.ChurnSchedule(
        membership=channels.CohortSampler(n, strategy="fixed_k", k=8, seed=20),
        adj=topology.ring(n, 2),
        p=np.full(n, 0.5),
    )
    states = list(sched.rounds(5))
    first = states[0].adj
    assert not first.flags.writeable  # snapshots are frozen
    for s in states[1:]:
        assert s.adj is first  # same buffer object, no per-round copy
        assert s.key()[0] is states[0].key()[0]  # interned bytes too
    # ... but a *changing* adjacency still gets fresh snapshots
    link = channels.MarkovLinkProcess(
        topology.fully_connected(8), p_up_to_down=0.4, p_down_to_up=0.4,
        seed=21,
    )
    sched2 = channels.TimeVaryingChannel(link_process=link, p=np.full(8, 0.5))
    s0, s1 = sched2.next_round(), sched2.next_round()
    if not np.array_equal(s0.adj, s1.adj):
        assert s0.adj is not s1.adj
