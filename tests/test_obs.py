"""Telemetry subsystem (ISSUE 6): tracer invariants, exporter round-trips,
and non-perturbation.

  * **tracer semantics** — nesting depth and buffer ordering (spans record
    at exit, children before ancestors), per-thread depth isolation, the
    bounded buffer's drop accounting, counter accumulation;
  * **thread safety** — worker-thread spans interleave with main-thread
    spans without corrupting either timeline;
  * **null path** — ``NULL_TRACER`` records nothing, and running any engine
    with tracing off is bitwise identical to running it uninstrumented
    (tracing must be a pure observer);
  * **export round-trips** — a fake-clock trace exports to golden Chrome
    trace-event JSON, and both exporters load back into the same phase
    attribution;
  * **bench integration** — a traced scenario run emits a Perfetto-loadable
    trace with the main / prefetcher / device tracks and a telemetry block
    whose phase attribution is sane.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro import channels
from repro.core import topology
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.obs import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    chrome_trace,
    load_trace_file,
    phase_attribution,
    phase_attribution_loaded,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.summary import format_summary, main as summary_main


def _fake_clock(start=1_000, step=10):
    """Deterministic ns clock: start, start+step, start+2*step, ..."""
    state = {"t": start - step}

    def clock():
        state["t"] += step
        return state["t"]

    return clock


# ------------------------------------------------------------------ tracer


def test_span_nesting_depth_and_exit_order():
    tr = Tracer(clock=_fake_clock())
    with tr.span("outer", cat="dispatch"):
        with tr.span("inner", cat="solve"):
            pass
        with tr.span("inner2", cat="solve"):
            pass
    # spans record at exit: children first, ancestor last
    assert [s.name for s in tr.spans] == ["inner", "inner2", "outer"]
    assert [s.depth for s in tr.spans] == [1, 1, 0]
    outer = tr.spans[-1]
    for child in tr.spans[:-1]:
        assert outer.t0_ns <= child.t0_ns and child.t1_ns <= outer.t1_ns
    # depth resets after the stack unwinds
    with tr.span("later"):
        pass
    assert tr.spans[-1].depth == 0


def test_span_records_attrs_and_fake_clock_durations():
    tr = Tracer(clock=_fake_clock(start=1000, step=10))
    # t_start consumed tick 1000; span start 1010, end 1020
    with tr.span("s", cat="stage", epoch=3, rounds=8):
        pass
    (s,) = tr.spans
    assert (s.t0_ns, s.t1_ns, s.dur_ns) == (1010, 1020, 10)
    assert s.attrs == {"epoch": 3, "rounds": 8}
    tr.instant("mark", cat="schedule", epoch=4)
    (i,) = tr.instants
    assert i.t_ns == 1030 and i.attrs == {"epoch": 4}


def test_counters_accumulate_ints_and_floats():
    tr = Tracer()
    tr.count("hits")
    tr.count("hits")
    tr.count("hits", 3)
    tr.count("prep_s", 0.25)
    tr.count("prep_s", 0.5)
    assert tr.counters["hits"] == 5
    assert tr.counters["prep_s"] == pytest.approx(0.75)


def test_bounded_buffer_drops_and_counts():
    tr = Tracer(max_events=3, clock=_fake_clock())
    for k in range(5):
        with tr.span(f"s{k}"):
            pass
    assert len(tr.events) == 3
    assert tr.dropped == 2
    # counters are aggregates, not events: unaffected by the bound
    tr.count("still_counts")
    assert tr.counters["still_counts"] == 1
    with pytest.raises(ValueError):
        Tracer(max_events=0)


def test_exception_inside_span_still_records_and_unwinds_depth():
    tr = Tracer(clock=_fake_clock())
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert [s.name for s in tr.spans] == ["doomed"]
    with tr.span("after"):
        pass
    assert tr.spans[-1].depth == 0


def test_worker_thread_spans_are_thread_safe_and_tracked():
    tr = Tracer()
    barrier = threading.Barrier(3)
    n_each = 200

    def work(label):
        barrier.wait()
        for _ in range(n_each):
            with tr.span(label, cat="stage", track="prefetcher"):
                with tr.span(label + ".inner", cat="h2d", track="prefetcher"):
                    pass

    threads = [
        threading.Thread(target=work, args=(f"w{k}",), name=f"worker-{k}")
        for k in range(2)
    ]
    for t in threads:
        t.start()
    barrier.wait()
    for _ in range(n_each):
        with tr.span("main", cat="dispatch"):
            pass
    for t in threads:
        t.join()
    assert len(tr.events) == 5 * n_each  # nothing lost under contention
    # per-thread depth isolation: main spans never inherit worker nesting
    assert all(s.depth == 0 for s in tr.spans if s.name == "main")
    assert all(s.depth == 1 for s in tr.spans if s.name.endswith(".inner"))
    # thread names captured for the track mapping
    tids = {s.tid for s in tr.spans}
    assert len(tids) == 3
    assert set(tr.thread_names) == tids


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert nt.enabled is False and NULL_TRACER.enabled is False
    with nt.span("x", cat="solve", epoch=1) as s:
        assert s is not None
    assert nt.instant("x") is None
    assert nt.count("x") is None
    # the disabled span is one shared constant — no per-call allocation
    assert nt.span("a") is nt.span("b") is NULL_TRACER.span("c")


# --------------------------------------------------------------- exporters


def _golden_tracer():
    """A fixed two-track trace off the fake clock (main + prefetcher)."""
    tr = Tracer(clock=_fake_clock(start=1_000, step=1_000))
    with tr.span("opt_alpha.solve", cat="solve", n_active=6):
        pass
    with tr.span("pipelined.chunk", cat="dispatch", epoch=0):
        pass
    with tr.span("prefetch.stage", cat="stage", track="prefetcher", epoch=1):
        pass
    tr.instant("segment", cat="schedule", epoch=1)
    tr.count("opt_alpha.solves", 1)
    return tr


def test_chrome_trace_golden_structure():
    doc = chrome_trace(_golden_tracer())
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    insts = [e for e in events if e["ph"] == "i"]
    # track metadata: the process plus one thread_name per track
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    names = [m["args"]["name"] for m in meta if m["name"] == "thread_name"]
    assert names == ["main", "prefetcher"]
    # golden values: fake clock ticks 1000ns apart, exported in µs relative
    # to the tracer's start tick
    assert [(e["name"], e["ts"], e["dur"]) for e in xs] == [
        ("opt_alpha.solve", 1.0, 1.0),
        ("pipelined.chunk", 3.0, 1.0),
        ("prefetch.stage", 5.0, 1.0),
    ]
    assert xs[0]["args"] == {"n_active": 6}
    assert [(e["name"], e["ts"]) for e in insts] == [("segment", 7.0)]
    assert doc["repro"] == {
        "counters": {"opt_alpha.solves": 1},
        "dropped": 0,
        "n_tracks": 2,
    }


def test_export_round_trip_both_formats(tmp_path):
    tr = _golden_tracer()
    chrome = write_chrome_trace(tr, tmp_path / "t.json")
    jsonl = write_jsonl(tr, tmp_path / "t.jsonl")
    live = phase_attribution(tr.events)
    for path in (chrome, jsonl):
        loaded = load_trace_file(path)
        assert [s["name"] for s in loaded["spans"]] == [
            "opt_alpha.solve",
            "pipelined.chunk",
            "prefetch.stage",
        ]
        assert loaded["tracks"] == ["main", "prefetcher"]
        assert loaded["counters"] == {"opt_alpha.solves": 1}
        assert loaded["dropped"] == 0
        loaded_attr = phase_attribution_loaded(loaded["spans"])
        assert loaded_attr == pytest.approx(live)
    # and the summary CLI renders both without error
    out = format_summary(str(chrome), load_trace_file(chrome))
    assert "OPT-α solve" in out and "2 tracks" in out
    assert summary_main([str(chrome), str(jsonl)]) == 0


def test_phase_attribution_skips_same_category_nesting(tmp_path):
    tr = Tracer(clock=_fake_clock(step=100))
    with tr.span("outer", cat="dispatch"):
        with tr.span("inner", cat="dispatch"):  # same cat: already billed
            pass
        with tr.span("other", cat="stage"):  # cross cat: billed separately
            pass
    attr = phase_attribution(tr.events)
    outer = [s for s in tr.spans if s.name == "outer"][0]
    other = [s for s in tr.spans if s.name == "other"][0]
    assert attr["dispatch"] == pytest.approx(outer.dur_ns / 1e9)
    assert attr["stage"] == pytest.approx(other.dur_ns / 1e9)
    # loaded-back attribution applies the same pruning
    loaded = load_trace_file(write_chrome_trace(tr, tmp_path / "prune.json"))
    assert phase_attribution_loaded(loaded["spans"]) == pytest.approx(attr)


# ----------------------------------------- non-perturbation (bitwise) ----


def _quad_loss(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jax.numpy.mean(jax.numpy.sum(diff**2, axis=-1))


def _batch_stream(n, T=2, b=4, dim=4, seed=0):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((n, T, b, dim)).astype(np.float32)}

    return next_batch


def _drift_schedule(n=6, seed=0):
    link = channels.MarkovLinkProcess(
        topology.ring(n, 2), p_up_to_down=0.4, p_down_to_up=0.6, seed=seed
    )
    drift = channels.PiecewiseConstantDrift(
        np.linspace(0.2, 0.9, n), hold=1, low=0.1, high=0.9, seed=seed + 1
    )
    return channels.TimeVaryingChannel(
        link_process=link, p_process=drift, adj_every=3, p_every=4
    )


def _run_traced(engine_name, tracer, n=6, rounds=12, chunk=4, seed=0):
    sim = FLSimulator(_quad_loss, n_clients=n, strategy="colrel_fused")
    params = {"x": jax.numpy.ones((4,))}
    server_state = sim.init_server_state(params)
    key = jax.random.key(seed)
    schedule = _drift_schedule(n, seed)
    if tracer is not None:
        schedule.tracer = tracer
    policy = channels.AdaptiveOptAlpha(sweeps=10, tracer=tracer)
    next_batch = _batch_stream(n, seed=seed)
    if engine_name == "loop":
        return run_rounds_loop(
            sim,
            key,
            params,
            server_state,
            schedule=schedule,
            rounds=rounds,
            next_batch=next_batch,
            lr=0.1,
            policy=policy,
            tracer=tracer,
        )
    cls = EpochScanEngine if engine_name == "scan" else PipelinedScanEngine
    engine = cls(sim, chunk=chunk, tracer=tracer)
    return engine.run_schedule(
        key,
        params,
        server_state,
        schedule=schedule,
        rounds=rounds,
        next_batch=next_batch,
        lr=0.1,
        policy=policy,
    )


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("engine_name", ["loop", "scan", "pipelined"])
def test_tracing_is_a_pure_observer(engine_name):
    """Every engine: tracing off (tracer=None) and tracing on produce
    bitwise-identical trajectories — spans, counters and fences must never
    leak into the math or the RNG stream."""
    bp, bs, bm, bk = _run_traced(engine_name, None)
    tracer = Tracer()
    tp, ts, tm, tk = _run_traced(engine_name, tracer)
    assert _tree_equal(bp, tp)
    assert _tree_equal(bs, ts)
    assert _tree_equal(bm, tm)
    assert np.array_equal(jax.random.key_data(bk), jax.random.key_data(tk))
    # and the traced run actually observed something at every layer
    cats = {s.cat for s in tracer.spans}
    assert {"solve", "dispatch", "device"} <= cats
    assert tracer.counters["opt_alpha.solves"] > 0
    if engine_name != "loop":
        # the fused engines walk segments(); the loop driver walks rounds()
        assert any(i.name == "segment" for i in tracer.instants)
    if engine_name == "pipelined":
        assert "stage" in cats and "h2d" in cats
        # one staged chunk per dispatch, folded onto the counters at close
        n = tracer.counters["pipelined.dispatches"]
        assert n > 0
        assert tracer.counters["prefetch.chunks"] == n
        assert tracer.counters["prefetch.chunks_staged"] == n
        # staging spans land on the logical prefetcher track
        assert {"prefetcher"} <= {s.track for s in tracer.spans if s.track}


def test_null_tracer_default_records_nothing_anywhere():
    """The default (no tracer passed) wires NULL_TRACER end to end: same
    trajectory, and nothing to flush."""
    pol = channels.AdaptiveOptAlpha(sweeps=10)
    assert pol.tracer is NULL_TRACER
    engine = PipelinedScanEngine(
        FLSimulator(_quad_loss, n_clients=6, strategy="colrel_fused"), chunk=4
    )
    assert engine.tracer is NULL_TRACER


# ------------------------------------------------------- bench integration


def test_traced_bench_scenario_end_to_end(tmp_path):
    """A traced scenario run: the pipelined trace carries the three logical
    tracks, the report telemetry block's attribution is sane, and the trace
    file loads back (Perfetto-compatible structure)."""
    from repro.bench import harness, report as report_lib
    from repro.bench.scenarios import ScenarioSpec

    spec = ScenarioSpec(
        name="obs_tiny",
        description="telemetry integration fixture",
        n_clients=4,
        rounds=12,
        local_steps=1,
        local_batch=4,
        dim=8,
        width=4,
        n_train=64,
        adj_every=4,
        p_every=4,
        chunk=4,
        opt_method="bisect",
        opt_sweeps=10,
        warm_sweeps=5,
    )
    result = harness.run_scenario(
        spec,
        engines=("loop", "pipelined"),
        trace_dir=tmp_path,
    )
    rep = report_lib.make_report(spec, result)
    for name in ("loop", "pipelined"):
        run = result["runs"][name]
        assert run.trace_path is not None
        tele = rep["telemetry"][name]
        assert tele is run.telemetry
        # attribution sums to a meaningful share of the traced wall, and
        # same-category pruning keeps it from exceeding it
        assert 0.3 < tele["attributed_fraction"] <= 1.05
        assert tele["dropped"] == 0 and tele["events"] > 0
        # as_dict keeps the engines block JSON-light (telemetry lives once
        # at the report top level)
        assert "telemetry" not in run.as_dict()
        assert run.as_dict()["trace_path"] == run.trace_path
    pipe = result["runs"]["pipelined"]
    loaded = load_trace_file(pipe.trace_path)
    assert {"main", "prefetcher", "device"} <= set(loaded["tracks"])
    assert loaded["counters"]["pipelined.dispatches"] == pipe.dispatches
    # pipelined extras recorded from the untraced warm run
    assert pipe.chunks_staged == pipe.dispatches
    assert 0.0 <= pipe.steady_overlap_fraction <= 1.0
    # the report is valid JSON including the telemetry block
    path = report_lib.write_report(rep, tmp_path)
    assert json.loads(path.read_text())["telemetry"]["pipelined"]["phases"]
