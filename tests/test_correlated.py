"""Correlated-connectivity subsystem: shadowing field, blockage-driven D2D,
coupled uplink, joint (adj, p) epochs, and the contracts the rest of the
stack assumes — maximal segments, scheduler caching, no-retrace, and
loop-vs-scan bit-identity under jointly-sampled state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import topology
from repro.core.aggregation import ServerOpt
from repro.fl.engine import EpochScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator


# ------------------------------------------------------- spatial covariance


def test_spatial_covariance_limits_and_shape():
    pos = channels.circle_positions(8)
    ind = channels.spatial_covariance(pos, corr_length=0.0, sigma=2.0)
    np.testing.assert_array_equal(ind, 4.0 * np.eye(8))
    common = channels.spatial_covariance(pos, corr_length=np.inf, sigma=2.0)
    np.testing.assert_array_equal(common, np.full((8, 8), 4.0))
    cov = channels.spatial_covariance(pos, corr_length=0.3)
    # symmetric PSD with unit diagonal, decaying with distance
    np.testing.assert_allclose(cov, cov.T)
    np.testing.assert_allclose(np.diag(cov), 1.0)
    assert np.all(np.linalg.eigvalsh(cov) > -1e-12)
    d = np.linalg.norm(pos[0] - pos[1]), np.linalg.norm(pos[0] - pos[4])
    assert d[0] < d[1] and cov[0, 1] > cov[0, 4]  # near > far correlation


def test_shadowing_field_marginals_independent_of_structure():
    """Each z_i stays N(0, σ²) while ρ and ℓ only shape co-occurrence."""
    pos = channels.circle_positions(6)
    for ell, rho in ((0.0, 0.0), (0.4, 0.9), (np.inf, 0.5)):
        field = channels.ShadowingField(
            pos, corr_length=ell, rho=rho, sigma=1.5, seed=0
        )
        zs = np.stack([field.step() for _ in range(4000)])
        np.testing.assert_allclose(zs.mean(0), 0.0, atol=0.15)
        np.testing.assert_allclose(zs.std(0), 1.5, atol=0.15)


def test_shadowing_field_spatial_correlation_orders_with_length():
    pos = channels.circle_positions(10)
    samples = {}
    for ell in (0.0, 0.3, np.inf):
        field = channels.ShadowingField(pos, corr_length=ell, rho=0.0, seed=1)
        zs = np.stack([field.step() for _ in range(3000)])
        samples[ell] = np.corrcoef(zs[:, 0], zs[:, 1])[0, 1]  # adjacent nodes
    assert abs(samples[0.0]) < 0.1
    assert samples[0.0] < samples[0.3] < samples[np.inf]
    assert samples[np.inf] > 0.99  # one shared fade


# ------------------------------------------------------- blockage link model


def test_blocked_node_drops_all_incident_edges():
    """The defining correlation: edges sharing a blocked node fail together."""
    base = topology.ring(10, 2)
    field = channels.ShadowingField(
        channels.circle_positions(10), corr_length=0.4, rho=0.8, seed=2
    )
    link = channels.ShadowedLinkProcess(base, field, threshold=0.8)
    for _ in range(60):
        adj = link.step()
        topology._validate(adj)
        assert not np.any(adj & ~base)  # base graph is the envelope
        blocked = link.blocked
        assert not adj[blocked].any() and not adj[:, blocked].any()
        # unblocked base edges survive
        up = ~blocked
        np.testing.assert_array_equal(
            adj, base & up[:, None] & up[None, :]
        )


def test_shadowed_link_with_mobility_refits_covariance():
    mob = channels.RandomWaypointMobility(8, radius=0.5, speed=0.1, seed=3)
    field = channels.ShadowingField(
        mob.positions, corr_length=0.3, rho=0.7, seed=4
    )
    link = channels.ShadowedLinkProcess(
        None, field, threshold=1.0, mobility=mob
    )
    seen = set()
    for _ in range(30):
        adj = link.step()
        topology._validate(adj)
        geo = channels.geometric_adjacency(mob.positions, 0.5)
        assert not np.any(adj & ~geo)  # moving envelope still respected
        seen.add(adj.tobytes())
    assert len(seen) > 1


def test_shadowed_link_rejects_ambiguous_base():
    field = channels.ShadowingField(
        channels.circle_positions(4), corr_length=0.2
    )
    with pytest.raises(ValueError, match="exactly one"):
        channels.ShadowedLinkProcess(None, field)


# ----------------------------------------------------------- coupled uplink


def test_coupled_uplink_bounds_and_zero_gain():
    p0 = np.linspace(0.1, 0.9, 8)
    field = channels.ShadowingField(
        channels.circle_positions(8), corr_length=0.3, seed=5
    )
    flat = channels.CoupledUplinkDrift(p0, field, gain=0.0)
    moving = channels.CoupledUplinkDrift(p0, field, gain=2.0)
    before = flat.value().copy()
    for _ in range(50):
        field.step()
        np.testing.assert_array_equal(flat.step(), before)  # γ=0 decouples
        p = moving.step()
        assert np.all(p >= 0.05) and np.all(p <= 0.95)


def test_coupled_uplink_co_moves_with_blockage():
    """A blocked node's uplink marginal is dragged down by the same fade."""
    n, gain, thr = 10, 2.0, 1.0
    p0 = np.full(n, 0.6)
    field = channels.ShadowingField(
        channels.circle_positions(n), corr_length=0.4, rho=0.5, seed=6
    )
    link = channels.ShadowedLinkProcess(topology.ring(n, 2), field,
                                        threshold=thr)
    up = channels.CoupledUplinkDrift(p0, field, gain=gain)
    logit0 = np.log(0.6 / 0.4)
    cap = 1.0 / (1.0 + np.exp(-(logit0 - gain * thr)))
    saw_blocked = False
    for _ in range(80):
        link.step()
        p = up.step()
        blocked = link.blocked
        if blocked.any():
            saw_blocked = True
            assert np.all(p[blocked] <= cap + 1e-12)
            if (~blocked).any():
                assert p[blocked].max() <= p[~blocked].min() + 1e-12
    assert saw_blocked


def test_coupled_uplink_value_stable_between_steps():
    """value() must cache: the schedule reads it every round but only steps
    it on p_every boundaries (pilot estimates lag the fade)."""
    field = channels.ShadowingField(
        channels.circle_positions(6), corr_length=0.2, seed=7
    )
    up = channels.CoupledUplinkDrift(np.full(6, 0.5), field, gain=2.0)
    held = up.value().copy()
    field.step()  # the fade moves on ...
    np.testing.assert_array_equal(up.value(), held)  # ... the estimate not
    assert not np.array_equal(up.step(), held)


# --------------------------------------------- joint epochs + segmentation


def test_correlated_channel_joint_epochs_align_with_hold():
    n, hold, rounds = 10, 5, 40
    sched = channels.CorrelatedChannel(
        topology.ring(n, 2), np.linspace(0.2, 0.9, n),
        corr_length=0.4, hold=hold, seed=0,
    )
    states = list(sched.rounds(rounds))
    for s in states:
        assert s.p.dtype == np.float32 and s.adj.dtype == bool
    # epoch boundaries only ever at hold multiples: (adj, p) move jointly
    for a, b in zip(states, states[1:]):
        if b.epoch_id != a.epoch_id:
            assert b.round % hold == 0


def test_correlated_segments_are_maximal_constant_runs():
    """Satellite: a *jointly*-sampled state stream still yields maximal
    constant-channel segments — no spurious splits inside a coherence
    interval, segment bounds only at hold multiples, and the segment stream
    is exactly the round stream regrouped."""
    n, hold, rounds = 8, 4, 33

    def make():
        return channels.CorrelatedChannel(
            topology.ring(n, 2), np.linspace(0.3, 0.9, n),
            corr_length=0.5, hold=hold, seed=11,
        )

    states = list(make().rounds(rounds))
    segs = list(make().segments(rounds))
    flat = [s for seg in segs for s in seg.states]
    assert len(flat) == rounds
    for got, want in zip(flat, states):
        assert got.round == want.round and got.key() == want.key()
    for seg in segs:
        assert seg.start_round % hold == 0
        # maximality: every segment spans whole coherence intervals (a
        # value-recurrence across a hold boundary merges, never splits)
        if seg is not segs[-1]:
            assert seg.n_rounds % hold == 0
        for s in seg.states:
            assert s.key() == seg.state.key()
    for a, b in zip(segs, segs[1:]):
        assert a.state.key() != b.state.key()
        assert b.start_round == a.start_round + a.n_rounds


class _InPlaceJointSampler(channels.ChannelSchedule):
    """Adversarial joint sampler: (adj, p) live in buffers that are mutated
    in place on every resample — the idiom `_emit` must defend against."""

    def __init__(self, n: int, *, hold: int, seed: int = 0):
        super().__init__()
        self._rng = np.random.default_rng(seed)
        self._hold = hold
        self._adj = np.zeros((n, n), dtype=bool)
        self._p = np.zeros(n, dtype=np.float32)
        self._resample()

    def _resample(self):
        z = self._rng.standard_normal(self._p.shape[0])
        up = z > -0.5
        self._adj[...] = up[:, None] & up[None, :]
        np.fill_diagonal(self._adj, False)
        self._p[...] = np.clip(0.5 + 0.3 * z, 0.05, 0.95)

    def next_round(self):
        if self._round > 0 and self._round % self._hold == 0:
            self._resample()
        return self._emit(self._adj, self._p)


def test_segments_survive_in_place_joint_resampling():
    """Satellite fix: `segments()` yields a segment only after seeing the
    *next* epoch's first state, by which time an in-place joint sampler has
    already overwritten its buffers — emitted states must therefore own
    snapshots, or the yielded segment silently carries the wrong channel."""
    rounds = 24
    ref_keys = []
    for s in _InPlaceJointSampler(6, hold=4, seed=3).rounds(rounds):
        ref_keys.append(s.key())  # key read while the round is current
    segs = list(_InPlaceJointSampler(6, hold=4, seed=3).segments(rounds))
    assert len(segs) > 2
    for seg in segs:
        for s in seg.states:
            assert s.key() == ref_keys[s.round]


def test_correlated_composes_with_churn():
    """ChurnSchedule over the shadowing pieces: membership, blockage and the
    coupled p stream through one ChannelState; membership changes open
    epochs of their own."""
    n = 9
    field = channels.ShadowingField(
        channels.circle_positions(n), corr_length=0.4, seed=8
    )
    sched = channels.ChurnSchedule(
        membership=channels.RotatingCohorts(n, n_cohorts=3, hold=6),
        link_process=channels.ShadowedLinkProcess(
            topology.ring(n, 2), field, threshold=1.0
        ),
        p_process=channels.CoupledUplinkDrift(
            np.full(n, 0.6), field, gain=2.0
        ),
        adj_every=3,
        p_every=3,
    )
    states = list(sched.rounds(24))
    assert all(s.active is not None for s in states)
    masks = {s.active.tobytes() for s in states}
    assert len(masks) == 3  # all three cohort shifts seen
    # a membership flip alone is an epoch boundary
    for a, b in zip(states, states[1:]):
        if not np.array_equal(a.active, b.active):
            assert b.epoch_id != a.epoch_id


# ---------------------------------------- scheduler + engine contracts


def test_adaptive_policy_caches_recurring_blockage_patterns():
    """Pure shadowing (static p): blockage patterns recur, so the LRU keyed
    on the joint state serves repeats from cache instead of re-solving."""
    n = 8
    sched = channels.CorrelatedChannel(
        topology.ring(n, 2), np.linspace(0.3, 0.9, n),
        corr_length=np.inf, hold=1, couple_uplink=False, rho=0.5, seed=4,
    )
    pol = channels.AdaptiveOptAlpha(sweeps=15, warm_sweeps=6, cache_size=32)
    for state in sched.rounds(60):
        A = pol.relay_matrix(state)
        # feasibility on the live graph every round, even fully blocked
        assert np.all(A >= -1e-12)
    assert pol.stats.cache_hits > 0
    assert pol.stats.solves + pol.stats.cache_hits == pol.stats.rounds
    # hit/miss accounting partitions the rounds; misses are exactly solves
    assert pol.stats.cache_hits + pol.stats.cache_misses == pol.stats.rounds
    assert pol.stats.cache_misses == pol.stats.solves
    assert pol.stats.evictions == 0  # cache_size=32 never overflows here


def _quad_setting(n, dim=4, T=2, b=4, seed=0):
    def loss_fn(params, batch):
        diff = params["x"][None, :] - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(diff**2, axis=-1))

    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((n, T, b, dim)).astype(np.float32)}

    return loss_fn, next_batch, {"x": jnp.ones((dim,))}


def test_no_retrace_under_correlated_channel():
    """Joint (adj, p) sampling is still value-only traffic into the compiled
    step: trace_count stays 1 across correlated epochs."""
    n = 6
    loss_fn, next_batch, params = _quad_setting(n)
    sim = FLSimulator(loss_fn, n_clients=n, strategy="colrel_fused",
                      local_steps=2)
    sched = channels.CorrelatedChannel(
        topology.ring(n, 2), np.linspace(0.2, 0.9, n),
        corr_length=0.4, hold=2, seed=5,
    )
    pol = channels.AdaptiveOptAlpha(sweeps=15, warm_sweeps=6)
    run_rounds_loop(
        sim, jax.random.key(0), params, sim.init_server_state(params),
        schedule=sched, rounds=10, next_batch=next_batch, lr=0.1, policy=pol,
    )
    assert sim.trace_count == 1


def test_scan_bit_identical_to_loop_under_correlated_channel():
    """The tentpole contract: the epoch-segmented scan engine reproduces the
    per-round reference bit-for-bit when (adj, p) are jointly sampled."""
    n, rounds = 6, 17
    loss_fn, _, params0 = _quad_setting(n, seed=7)

    def make_schedule():
        return channels.CorrelatedChannel(
            topology.ring(n, 2), np.linspace(0.25, 0.9, n),
            corr_length=0.5, hold=3, rho=0.7, seed=13,
        )

    runs = {}
    for engine_name in ("loop", "scan"):
        rng = np.random.default_rng(21)

        def next_batch():
            return {"c": rng.standard_normal((n, 2, 4, 4)).astype(np.float32)}

        sim = FLSimulator(
            loss_fn, n_clients=n, strategy="colrel_fused", local_steps=2,
            server_opt=ServerOpt(momentum=0.5),
        )
        policy = channels.AdaptiveOptAlpha(sweeps=15, warm_sweeps=6)
        ss = sim.init_server_state(params0)
        key = jax.random.key(9)
        if engine_name == "loop":
            out = run_rounds_loop(
                sim, key, params0, ss, schedule=make_schedule(),
                rounds=rounds, next_batch=next_batch, lr=0.1, policy=policy)
        else:
            eng = EpochScanEngine(sim, chunk=3)
            out = eng.run_schedule(
                key, params0, ss, schedule=make_schedule(), rounds=rounds,
                next_batch=next_batch, lr=0.1, policy=policy)
            assert eng.trace_count <= 2
        runs[engine_name] = out

    (lp, ls, lm, lk), (sp, ss_, sm, sk) = runs["loop"], runs["scan"]
    for a, b in zip(jax.tree.leaves((lp, ls, lm)),
                    jax.tree.leaves((sp, ss_, sm))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(jax.random.key_data(lk), jax.random.key_data(sk))
