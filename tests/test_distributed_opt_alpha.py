"""Paper Remark 2: OPT-α runs distributively on 2-hop information only."""
import numpy as np
import pytest

from repro.core import connectivity, opt_alpha, topology


@pytest.mark.parametrize("topo", ["ring1", "ring2", "er", "clusters"])
def test_distributed_matches_centralized(topo):
    n = 12
    p = connectivity.heterogeneous_profile(n).p
    adj = {
        "ring1": topology.ring(n, 1),
        "ring2": topology.ring(n, 2),
        "er": topology.erdos_renyi(n, 0.35, seed=3),
        "clusters": topology.clusters(n, 3),
    }[topo]
    central = opt_alpha.optimize(p, adj, sweeps=25)
    dist = opt_alpha.optimize_distributed(p, adj, sweeps=25)
    np.testing.assert_allclose(dist.A, central.A, atol=1e-10)
    np.testing.assert_allclose(dist.S_history, central.S_history, atol=1e-10)


def test_distributed_unbiasedness():
    n = 10
    p = connectivity.paper_heterogeneous().p
    adj = topology.ring(n, 1)
    res = opt_alpha.optimize_distributed(p, adj, sweeps=30)
    assert res.feasible_columns.all()
    assert np.abs(opt_alpha.unbiasedness_residual(p, res.A)).max() < 1e-8
