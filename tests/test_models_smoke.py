"""Per-architecture smoke tests: a REDUCED variant of each assigned family
runs one forward/train step on CPU — output shapes correct, no NaNs — plus
prefill→decode consistency against the teacher-forced oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as creg
from repro.configs.base import ShapeConfig
from repro.models import registry as mreg

ARCHS = list(creg.ASSIGNED)


def _batch(cfg, B, S, key=0):
    specs = mreg.input_specs(cfg, ShapeConfig("t", S, B, "train"))
    out = {}
    for kname, v in specs.items():
        if v.dtype == jnp.int32:
            out[kname] = jax.random.randint(jax.random.key(key), v.shape, 0, max(2, cfg.vocab or 10))
        else:
            out[kname] = jax.random.normal(jax.random.key(key + 1), v.shape, v.dtype)
    return out


@pytest.mark.parametrize("arch", ARCHS + ["resnet20-cifar"])
def test_train_step_no_nans(arch):
    cfg = creg.get_config(arch, reduced=True)
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    if cfg.family == "resnet":
        batch = {
            "images": jax.random.normal(jax.random.key(1), (2, 32, 32, 3)),
            "labels": jnp.zeros((2,), jnp.int32),
        }
    else:
        batch = _batch(cfg, 2, 64)
    loss, grads = jax.jit(jax.value_and_grad(md.loss))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # one SGD step moves the loss
    from repro.optim.sgd import ClientOpt

    opt = ClientOpt(kind="sgd", weight_decay=0.0)
    new_params, _ = opt.step(params, grads, opt.init(params), 0.1)
    loss2 = jax.jit(md.loss)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced(arch):
    cfg = creg.get_config(arch, reduced=True)
    if cfg.family == "moe":
        # capacity dropping is batch-dependent; use generous capacity so the
        # routed computation matches between prefill and decode exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    B, S = 2, 96
    tk = jax.random.randint(jax.random.key(3), (B, S + 1), 0, cfg.vocab)
    extra = {}
    if cfg.family == "audio":
        extra["frame_embeds"] = jax.random.normal(
            jax.random.key(4), (B, cfg.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        extra["img_embeds"] = jax.random.normal(
            jax.random.key(4), (B, cfg.n_image_tokens, cfg.d_model))
    lg_full, _ = jax.jit(md.prefill)(params, {"tokens": tk, **extra})
    _, cache = jax.jit(md.prefill)(params, {"tokens": tk[:, :S], **extra})
    lg_dec, _ = jax.jit(md.decode)(params, cache, tk[:, S:S + 1])
    rel = np.abs(np.asarray(lg_full) - np.asarray(lg_dec)).max() / max(
        1e-9, np.abs(np.asarray(lg_full)).max())
    assert rel < 2e-3, f"{arch}: decode/teacher-forced mismatch {rel:.2e}"
    assert lg_dec.shape == (B, 1, cfg.vocab)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x22b", "falcon-mamba-7b",
                                  "recurrentgemma-9b"])
def test_multi_token_decode_stable(arch):
    cfg = creg.get_config(arch, reduced=True)
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    B, S = 2, 32
    tk = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab)
    logits, cache = jax.jit(md.prefill)(params, {"tokens": tk})
    decode = jax.jit(md.decode)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(8):
        logits, cache = decode(params, cache, tok)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1], -1)[:, None]


def test_sliding_window_variant_for_long_context():
    """long_500k resolution: dense archs get the SWA variant (DESIGN.md §5)."""
    from repro.configs.base import INPUT_SHAPES

    cfg = creg.get_config("qwen3-14b")
    resolved = creg.for_shape(cfg, INPUT_SHAPES["long_500k"])
    assert resolved.sliding_window == cfg.long_context_window
    # natively sub-quadratic archs are untouched
    cfg2 = creg.get_config("falcon-mamba-7b")
    assert creg.for_shape(cfg2, INPUT_SHAPES["long_500k"]) is cfg2


def test_whisper_long500k_skip_reason():
    assert creg.is_skipped("whisper-tiny", "long_500k") is not None
    assert creg.is_skipped("whisper-tiny", "decode_32k") is None
    assert creg.is_skipped("qwen3-14b", "long_500k") is None


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_assigned_config(arch):
    """The full configs carry the exact assigned hyperparameters + citation."""
    spec = {
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51968),  # vocab padded 51865→51968
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }[arch]
    cfg = creg.get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab)
    assert got == spec, f"{arch}: {got} != {spec}"
    assert cfg.source, f"{arch} missing source citation"
