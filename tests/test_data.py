import numpy as np

from repro.data.loader import FederatedLoader
from repro.data.partition import (
    client_label_histogram,
    iid_partition,
    sort_and_partition,
)
from repro.data.synthetic import (
    cifar_like,
    gaussian_classification,
    lm_tokens,
    quadratic_problem,
)


def test_partitions_are_exact_covers():
    ds = cifar_like(1000, seed=0)
    for parts in (iid_partition(ds, 7, seed=1),
                  sort_and_partition(ds, 7, shards_per_client=2, seed=1)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000


def test_sort_and_partition_is_skewed():
    ds = cifar_like(2000, seed=0)
    iid = client_label_histogram(ds, iid_partition(ds, 10, seed=0), 10)
    nid = client_label_histogram(ds, sort_and_partition(ds, 10, shards_per_client=1, seed=0), 10)
    # non-IID clients see few classes; IID clients see ~all
    assert (nid > 0).sum(1).mean() < (iid > 0).sum(1).mean() / 2


def test_lm_tokens_learnable_structure():
    ds = lm_tokens(100, 64, vocab=128, n_streams=4, noise=0.0, seed=0)
    toks = ds.inputs
    assert toks.shape == (100, 65)
    assert toks.min() >= 0 and toks.max() < 128
    # zero-noise streams follow the affine recurrence deterministically:
    # the same (prev, stream) always maps to the same next token
    seen = {}
    for i in range(20):
        s = ds.labels[i]
        for t in range(64):
            key = (int(s), int(toks[i, t]))
            nxt = int(toks[i, t + 1])
            assert seen.setdefault(key, nxt) == nxt


def test_round_batch_shapes():
    ds = gaussian_classification(500, dim=16, seed=0)
    loader = FederatedLoader(ds, iid_partition(ds, 5, seed=0), seed=0)
    b = loader.round_batch(3, 8)
    assert b["inputs"].shape == (5, 3, 8, 16)
    assert b["labels"].shape == (5, 3, 8)
    ds2 = lm_tokens(200, 32, vocab=64, seed=0)
    loader2 = FederatedLoader(ds2, iid_partition(ds2, 4, seed=0), seed=0)
    b2 = loader2.round_batch(2, 6, lm=True)
    assert b2["tokens"].shape == (4, 2, 6, 32)
    assert b2["labels"].shape == (4, 2, 6, 32)
    np.testing.assert_array_equal(b2["tokens"][..., 1:], b2["labels"][..., :-1])


def test_quadratic_problem_conditioning():
    H, centers, x_star = quadratic_problem(16, 8, seed=0)
    eig = np.linalg.eigvalsh(H)
    assert eig.min() > 0.5 and eig.max() < 20  # μ-strongly convex, L-smooth
    np.testing.assert_allclose(x_star, centers.mean(0), atol=1e-6)


def test_vectorized_loader_auto_gate_and_forcing():
    from repro.data.loader import VECTORIZED_MIN_CLIENTS

    ds = gaussian_classification(4096, dim=4, seed=1)
    big = iid_partition(ds, VECTORIZED_MIN_CLIENTS, seed=1)
    small = iid_partition(ds, 8, seed=1)
    assert FederatedLoader(ds, big, seed=0).vectorized  # auto on at scale
    assert not FederatedLoader(ds, small, seed=0).vectorized  # historical path
    assert FederatedLoader(ds, small, seed=0, vectorized=True).vectorized
    assert not FederatedLoader(ds, big, seed=0, vectorized=False).vectorized


def test_vectorized_loader_rejects_unequal_partitions():
    import pytest

    ds = gaussian_classification(100, dim=4, seed=2)
    parts = [np.arange(0, 30), np.arange(30, 100)]
    with pytest.raises(ValueError, match="equal-size partitions"):
        FederatedLoader(ds, parts, vectorized=True)
    # unequal parts are fine on the loop path (auto stays off)
    assert not FederatedLoader(ds, parts).vectorized


def test_vectorized_round_batch_samples_within_partitions():
    ds = gaussian_classification(600, dim=6, seed=3)
    parts = iid_partition(ds, 12, seed=3)
    loader = FederatedLoader(ds, parts, seed=4, vectorized=True)
    b = loader.round_batch(3, 5)
    assert b["inputs"].shape == (12, 3, 5, 6)
    assert b["labels"].shape == (12, 3, 5)
    # every sampled row must belong to its own client's partition: recover
    # dataset indices by matching inputs back (rows are unique gaussians)
    for c, part in enumerate(parts):
        allowed = ds.inputs[part]
        flat = b["inputs"][c].reshape(-1, 6)
        for row in flat:
            assert (np.abs(allowed - row).sum(axis=1) < 1e-12).any()


def test_vectorized_round_batch_lm_path():
    ds = lm_tokens(512, 16, vocab=64, seed=5)
    loader = FederatedLoader(ds, iid_partition(ds, 16, seed=5), seed=6,
                             vectorized=True)
    b = loader.round_batch(2, 4, lm=True)
    assert b["tokens"].shape == (16, 2, 4, 16)
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["labels"][..., :-1])


def test_vectorized_and_loop_paths_agree_in_distribution():
    """Different RNG streams, same sampling law: per-client marginal means
    of many vectorized rounds match the loop path's."""
    ds = gaussian_classification(400, dim=3, seed=7)
    parts = iid_partition(ds, 4, seed=7)

    def mean_of(vectorized, rounds=400):
        ld = FederatedLoader(ds, parts, seed=8, vectorized=vectorized)
        acc = np.zeros((4, 3))
        for _ in range(rounds):
            acc += ld.round_batch(1, 8)["inputs"].reshape(4, -1, 3).mean(1)
        return acc / rounds

    part_means = np.stack([ds.inputs[p].mean(0) for p in parts])
    for v in (True, False):
        np.testing.assert_allclose(mean_of(v), part_means, atol=0.1)
