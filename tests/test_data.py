import numpy as np

from repro.data.loader import FederatedLoader
from repro.data.partition import (
    client_label_histogram,
    iid_partition,
    sort_and_partition,
)
from repro.data.synthetic import (
    cifar_like,
    gaussian_classification,
    lm_tokens,
    quadratic_problem,
)


def test_partitions_are_exact_covers():
    ds = cifar_like(1000, seed=0)
    for parts in (iid_partition(ds, 7, seed=1),
                  sort_and_partition(ds, 7, shards_per_client=2, seed=1)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000


def test_sort_and_partition_is_skewed():
    ds = cifar_like(2000, seed=0)
    iid = client_label_histogram(ds, iid_partition(ds, 10, seed=0), 10)
    nid = client_label_histogram(ds, sort_and_partition(ds, 10, shards_per_client=1, seed=0), 10)
    # non-IID clients see few classes; IID clients see ~all
    assert (nid > 0).sum(1).mean() < (iid > 0).sum(1).mean() / 2


def test_lm_tokens_learnable_structure():
    ds = lm_tokens(100, 64, vocab=128, n_streams=4, noise=0.0, seed=0)
    toks = ds.inputs
    assert toks.shape == (100, 65)
    assert toks.min() >= 0 and toks.max() < 128
    # zero-noise streams follow the affine recurrence deterministically:
    # the same (prev, stream) always maps to the same next token
    seen = {}
    for i in range(20):
        s = ds.labels[i]
        for t in range(64):
            key = (int(s), int(toks[i, t]))
            nxt = int(toks[i, t + 1])
            assert seen.setdefault(key, nxt) == nxt


def test_round_batch_shapes():
    ds = gaussian_classification(500, dim=16, seed=0)
    loader = FederatedLoader(ds, iid_partition(ds, 5, seed=0), seed=0)
    b = loader.round_batch(3, 8)
    assert b["inputs"].shape == (5, 3, 8, 16)
    assert b["labels"].shape == (5, 3, 8)
    ds2 = lm_tokens(200, 32, vocab=64, seed=0)
    loader2 = FederatedLoader(ds2, iid_partition(ds2, 4, seed=0), seed=0)
    b2 = loader2.round_batch(2, 6, lm=True)
    assert b2["tokens"].shape == (4, 2, 6, 32)
    assert b2["labels"].shape == (4, 2, 6, 32)
    np.testing.assert_array_equal(b2["tokens"][..., 1:], b2["labels"][..., :-1])


def test_quadratic_problem_conditioning():
    H, centers, x_star = quadratic_problem(16, 8, seed=0)
    eig = np.linalg.eigvalsh(H)
    assert eig.min() > 0.5 and eig.max() < 20  # μ-strongly convex, L-smooth
    np.testing.assert_allclose(x_star, centers.mean(0), atol=1e-6)
