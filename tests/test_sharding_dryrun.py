"""Sharding rules + a miniature end-to-end dry-run on a small forced-device
mesh.  Device-count overrides must happen before jax initializes, so these
tests run in subprocesses."""
import os
import subprocess
import sys


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_param_specs_divisibility_small_mesh():
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs import registry as creg
from repro.models import registry as mreg
from repro.sharding import rules
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 4)
for arch in creg.ASSIGNED:
    cfg = creg.get_config(arch, reduced=True)
    md = mreg.get_model(cfg)
    params = jax.eval_shape(md.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    for mode in ("tp", "fsdp_tp"):
        specs = rules.param_specs(params, mesh, mode)
        flat_p = jax.tree_util.tree_leaves_with_path(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, axes in enumerate(spec):
                if axes is None: continue
                axes = (axes,) if isinstance(axes, str) else axes
                size = 1
                for a in axes: size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, (arch, mode, path, leaf.shape, spec)
print("OK")
""")
    assert "OK" in out


def test_mini_dryrun_train_and_decode():
    """Lower + compile the ColRel round and decode step for a reduced arch on
    a (2,2,2) pod×data×model mesh — the full multi-pod machinery in miniature,
    then execute one round numerically."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry as creg
from repro.models import registry as mreg
from repro.sharding import rules
from repro.core import topology, opt_alpha, connectivity
from repro.fl.distributed import build_round_step
from repro.optim.sgd import ClientOpt
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 2, pod=2)
n = 4  # pod*data
cfg = creg.get_config("glm4-9b", reduced=True)
md = mreg.get_model(cfg)
p = connectivity.heterogeneous_profile(n).p
A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=10).A
step = build_round_step(md.loss, n_clients=n, local_steps=1, A=A,
                        relay_mode="faithful", client_opt=ClientOpt())
params = md.init(jax.random.key(0))
pspecs = rules.param_specs(params, mesh, "tp")
batch = {"tokens": jnp.ones((n, 1, 2, 64), jnp.int32),
         "labels": jnp.ones((n, 1, 2, 64), jnp.int32)}
bspecs = rules.train_batch_specs(batch, mesh)
tau = jnp.ones((n,), jnp.float32)
with mesh:
    jitted = jax.jit(step, in_shardings=(
        rules.to_shardings(pspecs, mesh), None,
        rules.to_shardings(bspecs, mesh), None, None),
        out_shardings=(rules.to_shardings(pspecs, mesh), None, None))
    lo = jitted.lower(params, None, batch, tau, jnp.float32(0.1))
    co = lo.compile()
    assert co.memory_analysis() is not None
    new_params, _, loss = jitted(params, None, batch, tau, jnp.float32(0.1))
    assert np.isfinite(float(loss)), loss
    # decode step lowers too
    cache = jax.eval_shape(lambda: md.init_cache(8, 128))
    cspecs = rules.cache_specs(cache, mesh, 8)
    tokens = jnp.ones((8, 1), jnp.int32)
    dec = jax.jit(md.decode, in_shardings=(
        rules.to_shardings(pspecs, mesh),
        rules.to_shardings(cspecs, mesh),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(("pod","data")))))
    dl = dec.lower(params, cache, tokens).compile()
    assert "all-" in dl.as_text() or "collective" in dl.as_text()
print("OK", float(loss))
""")
    assert "OK" in out


def test_fused_vs_faithful_identical_on_mesh():
    """Beyond-paper fusion must be bit-compatible with the faithful schedule
    under real sharding."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry as creg
from repro.models import registry as mreg
from repro.sharding import rules
from repro.core import topology, opt_alpha, connectivity
from repro.fl.distributed import build_round_step
from repro.optim.sgd import ClientOpt
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(4, 2)
n = 4
cfg = creg.get_config("qwen3-14b", reduced=True)
md = mreg.get_model(cfg)
p = connectivity.heterogeneous_profile(n).p
A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=10).A
params = md.init(jax.random.key(0))
pspecs = rules.param_specs(params, mesh, "tp")
batch = {"tokens": jax.random.randint(jax.random.key(1), (n, 1, 2, 64), 0, cfg.vocab)}
batch["labels"] = batch["tokens"]
bspecs = rules.train_batch_specs(batch, mesh)
tau = jnp.asarray([1., 0., 1., 1.])
outs = {}
for mode in ("faithful", "fused"):
    step = build_round_step(md.loss, n_clients=n, local_steps=1, A=A,
                            relay_mode=mode, client_opt=ClientOpt())
    with mesh:
        jitted = jax.jit(step, in_shardings=(
            rules.to_shardings(pspecs, mesh), None,
            rules.to_shardings(bspecs, mesh), None, None))
        outs[mode], _, _ = jitted(params, None, batch, tau, jnp.float32(0.1))
a = jax.tree.leaves(outs["faithful"]); b = jax.tree.leaves(outs["fused"])
errs = [float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)))) for x, y in zip(a, b)]
assert max(errs) < 1e-4, max(errs)
print("OK", max(errs))
""")
    assert "OK" in out
