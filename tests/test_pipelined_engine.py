"""Pipelined epoch engine (ISSUE 5): the four load-bearing invariants.

  * **staleness ordering** — a prefetched segment never observes a
    post-dated field state: the worker thread runs the channel processes
    several epochs ahead of the consumer, and what the consumer eventually
    dequeues must equal what a serial walk of an identical schedule yields;
  * **bitwise parity** — the pipelined path reproduces the per-round loop
    bit for bit (params, server state, metrics, final key) under churn and
    under correlated shadowing with a coupled uplink;
  * **compile discipline** — ``trace_count ≤ 2`` across many epochs of a
    fixed client dimension;
  * **single dispatch per chunk** — the τ draw is fused into the chunk
    body, so the engine issues exactly ⌈len/chunk⌉ compiled calls per epoch
    and nothing else.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.core import opt_alpha, topology
from repro.core.aggregation import ServerOpt
from repro.channels.scheduler import SegmentPrefetcher
from repro.fl.distributed import (
    build_fused_scan_round_step,
    build_scan_round_step,
)
from repro.fl.engine import PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator


def _quad_loss(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff**2, axis=-1))


def _batch_stream(n, T=2, b=4, dim=4, seed=0):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((n, T, b, dim)).astype(np.float32)}

    return next_batch


def _churn_drift_schedule(n=6, seed=0):
    link = channels.MarkovLinkProcess(
        topology.ring(n, 2), p_up_to_down=0.4, p_down_to_up=0.6, seed=seed
    )
    drift = channels.PiecewiseConstantDrift(
        np.linspace(0.2, 0.9, n), hold=1, low=0.1, high=0.9, seed=seed + 1
    )
    member = channels.RotatingCohorts(n, n_cohorts=3, hold=5)
    return channels.ChurnSchedule(
        membership=member,
        link_process=link,
        p_process=drift,
        adj_every=3,
        p_every=4,
    )


def _correlated_schedule(n=6, seed=0):
    """Jointly-sampled (adj, p) from one shadowing field — the schedule whose
    in-place samplers originally corrupted lookahead consumers (PR 4), i.e.
    the hardest case for a prefetcher that runs several epochs ahead."""
    return channels.CorrelatedChannel(
        topology.ring(n, 2),
        np.linspace(0.3, 0.9, n),
        corr_length=0.5,
        rho=0.9,
        blockage_threshold=0.8,
        couple_uplink=True,
        uplink_gain=2.0,
        hold=2,
        seed=seed,
    )


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ------------------------------------------------------------- prefetcher


def test_prefetched_segments_never_use_postdated_state():
    """Staleness ordering: the worker advances the channel processes far
    ahead of the consumer; every dequeued chunk must still carry the channel
    value its segment had when emitted, not the (mutated) current one."""
    n, rounds, chunk = 6, 24, 2
    reference = [
        (seg.adj.copy(), seg.p.copy(), seg.epoch_id, seg.start_round, seg.n_rounds)
        for seg in _correlated_schedule(n=n, seed=5).segments(rounds)
    ]
    pf = SegmentPrefetcher(
        _correlated_schedule(n=n, seed=5),
        rounds,
        chunk=chunk,
        next_batch=lambda: {"x": np.zeros((n, 1))},
        depth=64,  # hold the whole run: the worker finishes before we read
        threaded=True,
    )
    time.sleep(0.3)  # let the worker run all the way ahead
    items = list(pf)
    ref_iter = iter(reference)
    seen_rounds = 0
    for item in items:
        if item.start == 0:
            adj, p, epoch_id, start_round, n_rounds = next(ref_iter)
            assert np.array_equal(item.segment.adj, adj)
            assert np.array_equal(item.segment.p, p)
            assert item.segment.epoch_id == epoch_id
            assert item.segment.start_round == start_round
        seen_rounds += item.n_rounds
    assert seen_rounds == rounds
    assert next(ref_iter, None) is None  # every reference segment consumed


def test_prefetcher_batch_and_policy_order_match_serial_driver():
    """The worker must call next_batch() once per round in round order and
    the policy once per segment in segment order — the serial contract."""
    n, rounds = 6, 17

    calls = []

    def next_batch():
        calls.append(len(calls))
        return {"c": np.full((n, 1), float(len(calls)), np.float32)}

    class RecordingPolicy:
        def __init__(self):
            self.keys = []

        def relay_matrix(self, state):
            self.keys.append(state.key())
            return np.eye(n)

    policy = RecordingPolicy()
    pf = SegmentPrefetcher(
        _churn_drift_schedule(n=n, seed=3),
        rounds,
        chunk=4,
        next_batch=next_batch,
        policy=policy,
        threaded=True,
    )
    staged = []
    for item in pf:
        staged.append(item)
    assert calls == list(range(rounds))  # one call per round, in order
    ref_keys = [
        seg.state.key() for seg in _churn_drift_schedule(n=n, seed=3).segments(rounds)
    ]
    assert policy.keys == ref_keys  # one solve per segment, in order
    # the staged batch stream is the calls replayed in order
    flat = np.concatenate([np.asarray(item.batches["c"])[:, 0, 0] for item in staged])
    assert np.array_equal(flat, np.arange(1, rounds + 1, dtype=np.float32))


@pytest.mark.parametrize("threaded", [False, True])
def test_prefetcher_propagates_staging_exceptions(threaded):
    def bad_batch():
        raise RuntimeError("loader died")

    pf = SegmentPrefetcher(
        _churn_drift_schedule(),
        8,
        chunk=4,
        next_batch=bad_batch,
        threaded=threaded,
    )
    with pytest.raises(RuntimeError, match="loader died"):
        list(pf)
    pf.close()  # idempotent after failure


def test_prefetcher_close_unblocks_worker():
    """close() must release a worker blocked on a full queue (no thread
    leak, no deadlock) even when the consumer abandons mid-stream."""
    n = 6
    pf = SegmentPrefetcher(
        _churn_drift_schedule(n=n),
        64,
        chunk=1,
        next_batch=lambda: {"x": np.zeros((n, 1))},
        depth=1,
        threaded=True,
    )
    it = iter(pf)
    next(it)  # consume one chunk, leave the rest staged/blocked
    pf.close()
    assert pf._thread is None  # joined and released
    assert threading.active_count() < 50  # sanity: no runaway threads


def test_prefetcher_overlap_stats_populated():
    pf = SegmentPrefetcher(
        _churn_drift_schedule(),
        12,
        chunk=4,
        next_batch=_batch_stream(6),
        policy=channels.AdaptiveOptAlpha(sweeps=10),
    )
    list(pf)
    assert pf.stats.chunks > 0
    assert pf.stats.segments > 0
    assert pf.stats.prep_s > 0
    assert 0.0 <= pf.stats.overlap_fraction <= 1.0


@pytest.mark.parametrize("threaded", [False, True])
def test_prefetcher_steady_state_overlap_stats(threaded):
    """chunks_staged counts every staged chunk, and the steady-state overlap
    fraction excludes exactly the pipeline-fill first chunk's prep/wait —
    the first chunk has nothing in flight to hide behind, so counting it
    systematically understates a short run's overlap."""
    pf = SegmentPrefetcher(
        _churn_drift_schedule(),
        12,
        chunk=4,
        next_batch=_batch_stream(6),
        policy=channels.AdaptiveOptAlpha(sweeps=10),
        threaded=threaded,
    )
    items = list(pf)
    assert pf.stats.chunks_staged == pf.stats.chunks == len(items)
    # first-chunk accounting: a subset of the totals, never the whole of a
    # multi-chunk run's prep
    assert pf.stats.first_prep_s <= pf.stats.prep_s
    assert pf.stats.first_wait_s <= pf.stats.wait_s
    if not threaded:
        assert pf.stats.first_prep_s > 0.0
        assert pf.stats.first_prep_s < pf.stats.prep_s
    assert 0.0 <= pf.stats.steady_overlap_fraction <= 1.0
    # old field unchanged: overall overlap still includes the first chunk
    assert 0.0 <= pf.stats.overlap_fraction <= 1.0


# --------------------------- full-schedule bit-equivalence (the tentpole)


@pytest.mark.parametrize(
    "strategy,prefetch",
    [
        ("colrel_fused", "inline"),
        ("colrel_fused", "thread"),
        ("fedavg_blind", "inline"),
    ],
)
def test_pipelined_bit_identical_to_loop_under_churn(strategy, prefetch):
    """Pipelined run_schedule == per-round loop, bit for bit, over a
    schedule where adjacency, p and membership all change — including the
    on-device τ key chain's final value.  Both prefetch modes must hold it:
    the staging mode may change timing, never the trajectory."""
    n, rounds = 6, 17
    params0 = {"x": jnp.ones((4,))}

    def make_policy():
        if strategy == "fedavg_blind":
            return None
        return channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)

    runs = {}
    for engine_name in ("loop", "pipelined"):
        next_batch = _batch_stream(n, seed=42)
        sim = FLSimulator(
            loss_fn=_quad_loss,
            n_clients=n,
            strategy=strategy,
            server_opt=ServerOpt(momentum=0.5),  # nontrivial carried state
        )
        ss = sim.init_server_state(params0)
        key = jax.random.key(7)
        schedule = _churn_drift_schedule(n=n, seed=3)
        policy = make_policy()
        if engine_name == "loop":
            out = run_rounds_loop(
                sim,
                key,
                params0,
                ss,
                schedule=schedule,
                rounds=rounds,
                next_batch=next_batch,
                lr=0.1,
                policy=policy,
            )
        else:
            eng = PipelinedScanEngine(sim, chunk=4, prefetch=prefetch)
            out = eng.run_schedule(
                key,
                params0,
                ss,
                schedule=schedule,
                rounds=rounds,
                next_batch=next_batch,
                lr=0.1,
                policy=policy,
            )
        runs[engine_name] = out

    (lp, ls, lm, lk), (sp, ss_, sm, sk) = runs["loop"], runs["pipelined"]
    assert _tree_equal(lp, sp)
    assert _tree_equal(ls, ss_)
    assert _tree_equal(lm, sm)  # per-round loss/tau/delta_norm streams
    assert np.array_equal(jax.random.key_data(lk), jax.random.key_data(sk))


def test_pipelined_bit_identical_under_correlated_shadowing():
    """Same parity bar under the jointly-sampled (adj, p) channel — the
    prefetcher consumes snapshots while the field mutates ahead of it."""
    n, rounds = 6, 20
    params0 = {"x": jnp.ones((4,))}
    runs = {}
    for engine_name in ("loop", "pipelined"):
        next_batch = _batch_stream(n, seed=13)
        sim = FLSimulator(loss_fn=_quad_loss, n_clients=n, strategy="colrel_fused")
        ss = sim.init_server_state(params0)
        key = jax.random.key(11)
        schedule = _correlated_schedule(n=n, seed=9)
        policy = channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)
        if engine_name == "loop":
            out = run_rounds_loop(
                sim,
                key,
                params0,
                ss,
                schedule=schedule,
                rounds=rounds,
                next_batch=next_batch,
                lr=0.1,
                policy=policy,
            )
        else:
            eng = PipelinedScanEngine(sim, chunk=3)
            out = eng.run_schedule(
                key,
                params0,
                ss,
                schedule=schedule,
                rounds=rounds,
                next_batch=next_batch,
                lr=0.1,
                policy=policy,
            )
        runs[engine_name] = out
    (lp, ls, lm, lk), (sp, ss_, sm, sk) = runs["loop"], runs["pipelined"]
    assert _tree_equal(lp, sp)
    assert _tree_equal(ls, ss_)
    assert _tree_equal(lm, sm)
    assert np.array_equal(jax.random.key_data(lk), jax.random.key_data(sk))


# -------------------------------------------------- compile + dispatch caps


def test_pipelined_trace_count_bound():
    """≤ 2 compiles across many epochs of fixed n — fixed-size fused chunks,
    never a per-epoch-length (or per-τ-stream) retrace."""
    n, rounds = 6, 29
    params0 = {"x": jnp.ones((4,))}
    sim = FLSimulator(loss_fn=_quad_loss, n_clients=n, strategy="colrel_fused")
    engine = PipelinedScanEngine(sim, chunk=4)
    schedule = _churn_drift_schedule(n=n, seed=9)
    assert len(list(_churn_drift_schedule(n=n, seed=9).segments(rounds))) > 4
    engine.run_schedule(
        jax.random.key(0),
        params0,
        sim.init_server_state(params0),
        schedule=schedule,
        rounds=rounds,
        next_batch=_batch_stream(n, seed=1),
        lr=0.1,
        policy=channels.AdaptiveOptAlpha(sweeps=10),
    )
    assert engine.trace_count <= 2


def test_single_device_dispatch_per_chunk():
    """The τ draw is folded into the chunk body: the engine's only compiled
    callable fires exactly ⌈len/chunk⌉ times per epoch — there is no
    separate τ dispatch (the EpochScanEngine's ``_taus_fn`` is gone)."""
    n, rounds, chunk = 6, 23, 4
    params0 = {"x": jnp.ones((4,))}
    sim = FLSimulator(loss_fn=_quad_loss, n_clients=n, strategy="colrel_fused")
    engine = PipelinedScanEngine(sim, chunk=chunk)
    assert not hasattr(engine, "_taus_fn")

    calls = []
    inner = engine._chunk_fn

    def counting_chunk(*args, **kwargs):
        calls.append(1)
        return inner(*args, **kwargs)

    engine._chunk_fn = counting_chunk
    engine.run_schedule(
        jax.random.key(0),
        params0,
        sim.init_server_state(params0),
        schedule=_churn_drift_schedule(n=n, seed=9),
        rounds=rounds,
        next_batch=_batch_stream(n, seed=1),
        lr=0.1,
        policy=channels.AdaptiveOptAlpha(sweeps=10),
    )
    expected = sum(
        -(-seg.n_rounds // chunk)
        for seg in _churn_drift_schedule(n=n, seed=9).segments(rounds)
    )
    assert len(calls) == expected
    assert engine.dispatches == expected


# ------------------------------------------------- fused mesh scan wrapper


def test_fused_mesh_scan_matches_host_sampled_scan():
    """build_fused_scan_round_step (τ in the scan body, key in the carry)
    reproduces build_scan_round_step driven by host-side per-round draws —
    params, losses and the advanced key all bit-equal."""
    n, T, R = 4, 2, 6
    rng = np.random.default_rng(1)
    p = np.linspace(0.4, 0.9, n).astype(np.float32)
    A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=20).A
    params0 = {"x": jnp.ones((4,))}
    batches = [
        {"c": rng.standard_normal((n, T, 4, 4)).astype(np.float32)} for _ in range(R)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *batches)
    A_j = jnp.asarray(A, jnp.float32)
    p_j = jnp.asarray(p)
    kw = dict(n_clients=n, local_steps=T, relay_mode="fused")

    # reference: host-side key chain + the τ-as-input scan step
    key = jax.random.key(3)
    taus = []
    for _ in range(R):
        key, sub = jax.random.split(key)
        taus.append(jax.random.bernoulli(sub, p_j).astype(jnp.float32))
    scan_fn = jax.jit(build_scan_round_step(_quad_loss, **kw))
    ref_params, ref_ss, ref_losses = scan_fn(
        params0, None, stacked, jnp.stack(taus), 0.1, A_j
    )

    fused_fn = jax.jit(build_fused_scan_round_step(_quad_loss, **kw))
    got_key, got_params, got_ss, got_losses = fused_fn(
        jax.random.key(3), params0, None, stacked, p_j, 0.1, A_j
    )
    assert _tree_equal(ref_params, got_params)
    assert np.array_equal(np.asarray(ref_losses), np.asarray(got_losses))
    assert np.array_equal(jax.random.key_data(key), jax.random.key_data(got_key))
