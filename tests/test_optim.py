import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.schedules import constant, cosine, paper_lr
from repro.optim.sgd import ClientOpt


def _quad_loss(p, _):
    return 0.5 * jnp.sum(p["x"] ** 2)


@pytest.mark.parametrize("kind", ["sgd", "momentum", "adam"])
def test_optimizers_descend(kind):
    opt = ClientOpt(kind=kind, weight_decay=0.0)
    params = {"x": jnp.ones((8,)) * 3.0}
    state = opt.init(params)
    loss0 = float(_quad_loss(params, None))
    for _ in range(50):
        g = jax.grad(_quad_loss)(params, None)
        params, state = opt.step(params, g, state, 0.1)
    assert float(_quad_loss(params, None)) < loss0 * 0.05


def test_weight_decay_applied():
    opt = ClientOpt(kind="sgd", weight_decay=0.5)
    params = {"x": jnp.ones((2,))}
    zero_g = {"x": jnp.zeros((2,))}
    new, _ = opt.step(params, zero_g, opt.init(params), 0.1)
    np.testing.assert_allclose(np.asarray(new["x"]), 1.0 - 0.1 * 0.5, rtol=1e-6)


def test_paper_schedule():
    lr = paper_lr(mu=1.0, T=8)
    assert np.isclose(lr(0), 4.0)
    assert np.isclose(lr(10), 4.0 / 81.0)
    assert lr(100) < lr(10) < lr(1)


def test_other_schedules():
    assert constant(0.1)(99) == 0.1
    c = cosine(1.0, 100)
    assert c(0) == pytest.approx(1.0)
    assert c(100) == pytest.approx(0.1)
    assert c(50) < c(10)


def test_bf16_params_keep_dtype():
    opt = ClientOpt(kind="sgd", weight_decay=1e-4)
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    g = {"x": jnp.ones((4,), jnp.bfloat16)}
    new, _ = opt.step(params, g, opt.init(params), 0.1)
    assert new["x"].dtype == jnp.bfloat16
