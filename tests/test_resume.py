"""Engine-resume integration: checkpoint mid-run, restore, continue — the
resumed trajectory must be **bitwise-equal** to the uninterrupted one.

The recipe under test (documented in ``repro.checkpoint.io``): save the full
training state (params, server momentum, RNG key, round counter) at round R,
then in a "fresh process" rebuild the schedule / policy / batch stream from
their seeds, advance them R rounds, restore, and continue.  Checked for the
per-round loop and both scan engines, over a churned multi-epoch schedule
with a momentum-carrying server optimizer.  Plus the torn-write test: a
crash mid-``publish`` must leave the previous snapshot loadable and the
``LATEST`` pointer untouched.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels, checkpoint
from repro.core import topology
from repro.core.aggregation import ServerOpt
from repro.fl.engine import EpochScanEngine, PipelinedScanEngine, run_rounds_loop
from repro.fl.simulator import FLSimulator

N = 6
DIM = 4
HALF = 9  # rounds per half; 2*HALF spans several channel epochs


def _loss_fn(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))


def _stream(seed=42):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((N, 2, 4, DIM)).astype(np.float32)}

    return next_batch


def _schedule(seed=3):
    link = channels.MarkovLinkProcess(
        topology.ring(N, 2), p_up_to_down=0.4, p_down_to_up=0.6, seed=seed)
    member = channels.RotatingCohorts(N, n_cohorts=3, hold=5)
    return channels.ChurnSchedule(
        membership=member, link_process=link,
        p=np.linspace(0.3, 0.9, N), adj_every=3, p_every=3)


def _sim():
    return FLSimulator(
        _loss_fn, n_clients=N, strategy="colrel_fused",
        server_opt=ServerOpt(momentum=0.9))


def _policy():
    return channels.AdaptiveOptAlpha(sweeps=15, warm_sweeps=6)


def _drive(engine_name, sim, key, params, ss, *, schedule, rounds,
           next_batch, policy):
    if engine_name == "loop":
        return run_rounds_loop(
            sim, key, params, ss, schedule=schedule, rounds=rounds,
            next_batch=next_batch, lr=0.1, policy=policy)
    cls = EpochScanEngine if engine_name == "scan" else PipelinedScanEngine
    return cls(sim, chunk=4).run_schedule(
        key, params, ss, schedule=schedule, rounds=rounds,
        next_batch=next_batch, lr=0.1, policy=policy)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("engine_name", ["loop", "scan", "pipelined"])
def test_resumed_trajectory_bitwise_equals_uninterrupted(engine_name, tmp_path):
    params0 = {"x": jnp.ones((DIM,))}

    # --- reference: one uninterrupted run over both halves
    sim = _sim()
    ref = _drive(
        engine_name, sim, jax.random.key(7), params0,
        sim.init_server_state(params0), schedule=_schedule(),
        rounds=2 * HALF, next_batch=_stream(), policy=_policy())
    ref_params, ref_ss, ref_metrics, ref_key = ref

    # --- first half + checkpoint
    sim1 = _sim()
    stream1 = _stream()
    p1, s1, _, k1 = _drive(
        engine_name, sim1, jax.random.key(7), params0,
        sim1.init_server_state(params0), schedule=_schedule(),
        rounds=HALF, next_batch=stream1, policy=_policy())
    path = str(tmp_path / "mid.npz")
    checkpoint.save_training_state(
        path, params=p1, server_state=s1, key=k1, round=HALF)

    # --- "fresh process": rebuild everything from seeds, advance to HALF
    sim2 = _sim()
    schedule2 = _schedule()
    policy2 = _policy()
    stream2 = _stream()
    for state in schedule2.rounds(HALF):
        policy2.relay_matrix(state)  # warm the policy exactly as the run did
        stream2()  # replay the consumed batches
    params_like = {"x": jnp.zeros((DIM,))}
    rp, rs, rk, rnd = checkpoint.restore_training_state(
        path, params_like=params_like,
        server_state_like=sim2.server_opt.init(params_like))
    assert rnd == HALF
    got = _drive(
        engine_name, sim2, rk, rp, rs, schedule=schedule2, rounds=HALF,
        next_batch=stream2, policy=policy2)
    got_params, got_ss, got_metrics, got_key = got

    assert _tree_equal(ref_params, got_params)
    assert _tree_equal(ref_ss, got_ss)  # server momentum included
    # the resumed metrics are the reference's second half, bit for bit
    second_half = jax.tree.map(lambda x: x[HALF:], ref_metrics)
    assert _tree_equal(second_half, got_metrics)
    assert np.array_equal(
        jax.random.key_data(ref_key), jax.random.key_data(got_key))


def test_momentum_free_snapshot_round_trips_none_server_state(tmp_path):
    params = {"x": jnp.arange(4.0)}
    path = str(tmp_path / "nomom.npz")
    checkpoint.save_training_state(
        path, params=params, server_state=None, key=jax.random.key(3), round=5)
    rp, rs, rk, rnd = checkpoint.restore_training_state(
        path, params_like={"x": jnp.zeros(4)})
    assert rs is None and rnd == 5
    assert _tree_equal(params, rp)
    assert np.array_equal(
        jax.random.key_data(jax.random.key(3)), jax.random.key_data(rk))
    # a momentum-carrying snapshot refuses restore without the like tree
    path2 = str(tmp_path / "mom.npz")
    checkpoint.save_training_state(
        path2, params=params, server_state={"x": jnp.zeros(4)},
        key=jax.random.key(3), round=5)
    with pytest.raises(ValueError, match="server-optimizer state"):
        checkpoint.restore_training_state(path2, params_like={"x": jnp.zeros(4)})


def test_publish_rotates_latest_and_prunes(tmp_path):
    d = str(tmp_path / "ckpts")
    key = jax.random.key(0)
    for rnd in (10, 20, 30):
        params = {"x": jnp.full((4,), float(rnd))}
        checkpoint.publish(
            d, params=params, server_state=None, key=key, round=rnd, keep=2)
    latest = checkpoint.latest_checkpoint(d)
    assert latest is not None and latest.endswith("ckpt_00000030.npz")
    snaps = sorted(f for f in os.listdir(d)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    assert snaps == ["ckpt_00000020.npz", "ckpt_00000030.npz"]  # keep=2
    rp, _, _, rnd = checkpoint.restore_training_state(
        latest, params_like={"x": jnp.zeros(4)})
    assert rnd == 30 and float(np.asarray(rp["x"])[0]) == 30.0


def test_torn_write_leaves_previous_snapshot_loadable(tmp_path, monkeypatch):
    """A crash mid-save (np.savez raising after the tmp file opened) must
    leave the LATEST pointer and the previous snapshot fully intact — the
    atomic tmp-rename contract the serving loop relies on."""
    d = str(tmp_path / "ckpts")
    params = {"x": jnp.ones((4,))}
    checkpoint.publish(
        d, params=params, server_state=None, key=jax.random.key(0), round=1)
    before = checkpoint.latest_checkpoint(d)

    def torn_savez(f, **arrs):
        f.write(b"partial garbage")
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", torn_savez)
    with pytest.raises(OSError, match="disk full"):
        checkpoint.publish(
            d, params=params, server_state=None, key=jax.random.key(0), round=2)
    monkeypatch.undo()

    assert checkpoint.latest_checkpoint(d) == before
    rp, _, _, rnd = checkpoint.restore_training_state(
        before, params_like={"x": jnp.zeros(4)})
    assert rnd == 1 and _tree_equal(params, rp)
    # no stray tmp files survive the failed publish
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
