"""Logical sharding hints: inert without rules; constraint path on a mesh."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.sharding import hints

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_hint_noop_without_rules():
    x = jnp.ones((4, 8))
    y = hints.hint(x, "batch", "qchunk")
    assert y is x  # literally untouched


def test_hint_rank_mismatch_rejected():
    import pytest

    x = jnp.ones((4, 8))

    class FakeMesh:
        shape = {"model": 2}

    with hints.axis_rules(FakeMesh(), {"qchunk": "model"}):
        with pytest.raises(ValueError):
            hints.hint(x, "batch")


def test_hint_skips_indivisible_dims():
    class FakeMesh:
        shape = {"model": 16}

    x = jnp.ones((3, 5))
    with hints.axis_rules(FakeMesh(), {"batch": "model", "qchunk": "model"}):
        y = hints.hint(x, "batch", "qchunk")  # 3 % 16 and 5 % 16 ≠ 0
    assert y is x


def test_hint_applies_constraint_on_mesh():
    code = """
import jax, jax.numpy as jnp
from repro.sharding import hints
from repro.launch.mesh import make_local_mesh
mesh = make_local_mesh(2, 2)
def f(x):
    return hints.hint(x * 2, "batch", "qchunk")
with hints.axis_rules(mesh, {"batch": "data", "qchunk": "model"}):
    with mesh:
        out = jax.jit(f)(jnp.ones((4, 8)))
s = out.sharding
assert s.spec == jax.sharding.PartitionSpec("data", "model"), s
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_blockwise_attention_unchanged_by_hints():
    """Numerics must be identical with hints active (constraint-only)."""
    from repro.configs.base import ModelConfig
    from repro.models import attention as att

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16)
    rng = np.random.default_rng(0)
    B, S = 2, 256
    q = jnp.asarray(rng.standard_normal((B, S, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    base = att.blockwise_gqa(q, k, v, pos_q=pos, pos_k=pos, causal=True,
                             window=0, cfg=cfg, q_chunk=64, kv_chunk=64)
    # rules active but nothing divisible by a fake huge axis → same result
    class FakeMesh:
        shape = {"model": 1024}

    with __import__("repro.sharding.hints", fromlist=["hints"]).axis_rules(
            FakeMesh(), {"qchunk": "model"}):
        same = att.blockwise_gqa(q, k, v, pos_q=pos, pos_k=pos, causal=True,
                                 window=0, cfg=cfg, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(base), np.asarray(same), atol=0)
