"""Blockwise (flash-style) attention vs the quadratic oracle, SWA paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as att

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16)


def _qkv(B, S, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    return q, k, v, pos


def _quad(q, k, v, pos, causal, window):
    B, S = q.shape[:2]
    s = att._gqa_scores(q, k, CFG)
    m = jnp.ones((B, 1, 1, S, S), bool)
    if causal:
        m &= pos[:, None, None, :, None] >= pos[:, None, None, None, :]
    if window:
        m &= pos[:, None, None, None, :] > pos[:, None, None, :, None] - window
    s = jnp.where(m, s, att.NEG_INF)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(jnp.float32), v)
    return o.reshape(B, S, 64)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (True, 17),
                                           (False, 0)])
@pytest.mark.parametrize("chunks", [(64, 32), (32, 64), (128, 128)])
def test_blockwise_matches_quadratic(causal, window, chunks):
    q, k, v, pos = _qkv(2, 256)
    qc, kc = chunks
    got = att.blockwise_gqa(q, k, v, pos_q=pos, pos_k=pos, causal=causal,
                            window=window, cfg=CFG, q_chunk=qc, kv_chunk=kc)
    want = _quad(q, k, v, pos, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_blockwise_grads_match():
    q, k, v, pos = _qkv(1, 128)

    def f_block(q, k, v):
        return att.blockwise_gqa(q, k, v, pos_q=pos, pos_k=pos, causal=True,
                                 window=0, cfg=CFG, q_chunk=32, kv_chunk=32).sum()

    def f_quad(q, k, v):
        return _quad(q, k, v, pos, True, 0).sum()

    g1 = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_quad, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_swa_padding_path():
    """S not a multiple of the window: end-padding must not change outputs."""
    import dataclasses

    cfg = dataclasses.replace(CFG, sliding_window=32)
    p = att.init_attention(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    S = 77  # 77 % 32 != 0
    x = jnp.asarray(rng.standard_normal((2, S, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S)).astype(jnp.int32)
    out, (k, v) = att.sliding_window_attention(p, x, pos, cfg, window=32)
    assert out.shape == (2, S, 64)
    assert k.shape[1] == S
    # oracle: quadratic with window mask
    q = att._project_q(p, x, cfg)
    from repro.models import common

    qr = common.apply_rope(q, pos, cfg)
    kr = common.apply_rope(att._project_kv(p, x, cfg)[0], pos, cfg)
    vv = att._project_kv(p, x, cfg)[1]
    s = att._gqa_scores(qr, kr, cfg)
    m = (pos[:, None, None, :, None] >= pos[:, None, None, None, :]) & (
        pos[:, None, None, None, :] > pos[:, None, None, :, None] - 32)
    s = jnp.where(m, s, att.NEG_INF)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cfg.cdtype), vv).reshape(2, S, 64)
    want = common.dense(p["o"], o, cdtype=cfg.cdtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_ring_buffer_eviction_is_window_consistent():
    """With SWA, a full ring cache must attend to exactly the last W tokens."""
    import dataclasses

    cfg = dataclasses.replace(CFG, sliding_window=16)
    p = att.init_attention(jax.random.key(0), cfg)
    cache = att.init_cache(cfg, 1, 16)
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.standard_normal((1, 40, 64)), jnp.float32)
    # stream 39 tokens through decode, then check token 39 attends to 24..39
    for t in range(39):
        _, cache = att.decode_attention(p, xs[:, t:t + 1], cache, jnp.int32(t),
                                        cfg, window=16)
    valid = np.asarray(cache["pos"])
    assert sorted(valid.tolist()) == list(range(23, 39))
