"""relay_backend dispatch (ISSUE 7): the flat (n, D) aggregation path.

Holds the three contracts the ravel refactor introduced:

  * **flat == pytree** — every strategy's ``Aggregator.fn`` (ravel → flat_fn
    → unravel) reproduces the legacy pytree increment math;
  * **kernel == einsum** — the pallas / pallas_fused backends match the
    einsum oracle through ``make_aggregator``, the simulator round and the
    mesh round step, with and without churn, with D not a block multiple;
  * **churn stays exact** — an inactive client's (finite) garbage contributes
    *exactly zero* through the kernel backends, not merely approximately.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, opt_alpha, topology
from repro.fl.distributed import build_round_step
from repro.fl.simulator import FLSimulator
from repro.utils import stacked_ravel

N = 6
STRATEGIES = (
    "colrel",
    "colrel_fused",
    "fedavg_blind",
    "fedavg_nonblind",
    "no_dropout",
)


def _setting(seed=0, n=N):
    """(A, tau, stacked updates, active): D = 20·30 + 100 = 700, which is not
    a multiple of the 256 test block — the kernels must pad a tail block."""
    rng = np.random.default_rng(seed)
    p = np.linspace(0.3, 0.9, n)
    A = opt_alpha.optimize(p, topology.ring(n, 2), sweeps=20).A
    upd = {
        "w": jnp.asarray(rng.standard_normal((n, 20, 30)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n, 100)), jnp.float32),
    }
    tau = jnp.asarray(rng.random(n) < p, jnp.float32)
    act = rng.random(n) < 0.7
    act[0] = True  # at least one live client
    active = jnp.asarray(act, jnp.float32)
    return jnp.asarray(A, jnp.float32), tau, upd, active


def _legacy_increment(strategy, A, tau, upd, active):
    """The pre-ravel pytree functions — kept exported as the oracle."""
    if strategy == "colrel":
        return aggregation.colrel_increment(
            A, tau, upd, n=N, fused=False, active=active
        )
    if strategy == "colrel_fused":
        return aggregation.colrel_increment(
            A, tau, upd, n=N, fused=True, active=active
        )
    if strategy == "fedavg_blind":
        return aggregation.fedavg_blind_increment(tau, upd, n=N, active=active)
    if strategy == "fedavg_nonblind":
        return aggregation.fedavg_nonblind_increment(tau, upd, active=active)
    return aggregation.no_dropout_increment(upd, n=N, active=active)


# ------------------------------------------------- flat == pytree (einsum)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("churn", [False, True])
def test_aggregator_fn_matches_legacy_pytree_math(strategy, churn):
    A, tau, upd, active = _setting()
    active = active if churn else None
    agg = aggregation.make_aggregator(strategy, n=N, A=A)
    got = agg.fn(tau, upd, None, active)
    want = _legacy_increment(strategy, A, tau, upd, active)
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.shape == w.shape
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6
        )


def test_increment_leaves_stay_f32_for_low_precision_updates():
    """The fn wrapper unravels with cast=False: aggregation math stays f32
    and the *server optimizer* owns the cast back to the parameter dtype."""
    rng = np.random.default_rng(5)
    upd = {"w": jnp.asarray(rng.standard_normal((N, 8)), jnp.bfloat16)}
    agg = aggregation.make_aggregator("fedavg_blind", n=N)
    inc = agg.fn(jnp.ones(N), upd, None, None)
    assert inc["w"].dtype == jnp.float32
    assert inc["w"].shape == (8,)


# ------------------------------------------------- kernel == einsum (flat)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("churn", [False, True])
def test_kernel_backend_matches_einsum_reference(strategy, backend, churn):
    A, tau, upd, active = _setting(1)
    active = active if churn else None
    buf, _ = stacked_ravel(upd)
    kw = dict(n=N, A=A, block_d=256, interpret=True)
    want = aggregation.make_aggregator(strategy, **kw).flat_fn(
        tau, buf, None, active
    )
    got = aggregation.make_aggregator(
        strategy, relay_backend=backend, **kw
    ).flat_fn(tau, buf, None, active)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("backend", ["einsum", "pallas", "pallas_fused"])
def test_churn_contributes_exactly_zero_through_kernels(backend):
    """Poison the inactive rows with large-but-finite garbage: the masked
    relay matrix / coefficients must cancel it to an *exact* zero (0·x = 0
    for finite x), so the increment is bitwise independent of dead slots."""
    A, tau, upd, active = _setting(2)
    buf, _ = stacked_ravel(upd)
    poisoned = jnp.where(active[:, None] > 0, buf, jnp.float32(1e30))
    clean = buf * active[:, None]
    for strategy in ("colrel", "colrel_fused", "fedavg_blind"):
        agg = aggregation.make_aggregator(
            strategy, n=N, A=A, relay_backend=backend, block_d=256,
            interpret=True,
        )
        got_p = agg.flat_fn(tau, poisoned, None, active)
        got_c = agg.flat_fn(tau, clean, None, active)
        assert np.isfinite(np.asarray(got_p)).all(), strategy
        assert np.array_equal(np.asarray(got_p), np.asarray(got_c)), strategy


def test_make_aggregator_rejects_unknown_backend():
    with pytest.raises(ValueError, match="relay_backend"):
        aggregation.make_aggregator("colrel_fused", n=4, relay_backend="sm90")


# ------------------------------------------ kernel == einsum (full rounds)


def _quad_loss(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff**2, axis=-1))


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_simulator_round_backend_parity(backend):
    """A full simulator round (client SGD → ravel → kernel increment →
    server opt → metrics) under churn matches the einsum reference."""
    n, dim, T, b = 4, 5, 2, 3
    rng = np.random.default_rng(9)
    p = np.linspace(0.4, 0.9, n)
    A = opt_alpha.optimize(p, topology.ring(n, 1), sweeps=15).A
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, b, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    active = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    outs = {}
    for be in ("einsum", backend):
        sim = FLSimulator(
            _quad_loss, n_clients=n, strategy="colrel", A=A, p=p,
            local_steps=T, relay_backend=be, block_d=128, interpret=True,
        )
        outs[be] = sim.run_round(
            jax.random.key(0), params, sim.init_server_state(params),
            batch, 0.1, active=active,
        )
    (pe, _, me), (pk, _, mk) = outs["einsum"], outs[backend]
    np.testing.assert_allclose(
        np.asarray(pe["x"]), np.asarray(pk["x"]), rtol=1e-6, atol=1e-6
    )
    for field in ("loss", "delta_norm"):
        np.testing.assert_allclose(
            float(me[field]), float(mk[field]), rtol=1e-6
        )
    assert np.array_equal(np.asarray(me["tau"]), np.asarray(mk["tau"]))


@pytest.mark.parametrize("T,mode", [(1, "faithful"), (2, "faithful"), (2, "fused")])
@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_mesh_round_step_backend_parity(T, mode, backend):
    """build_round_step under each kernel backend matches its einsum twin on
    every delta-materializing path (T=1 faithful, T>1 both relay modes)."""
    n, dim, b = 4, 6, 3
    rng = np.random.default_rng(21)
    p = np.linspace(0.4, 0.9, n)
    A = jnp.asarray(
        opt_alpha.optimize(p, topology.ring(n, 1), sweeps=15).A, jnp.float32
    )
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, b, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    tau = jnp.asarray(rng.random(n) < p, jnp.float32)
    active = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    kw = dict(n_clients=n, local_steps=T, relay_mode=mode)
    step_ref = build_round_step(_quad_loss, **kw)
    step_ker = build_round_step(
        _quad_loss, relay_backend=backend, block_d=128, interpret=True, **kw
    )
    p_ref, _, l_ref = step_ref(params, None, batch, tau, 0.1, A, active)
    p_ker, _, l_ker = step_ker(params, None, batch, tau, 0.1, A, active)
    np.testing.assert_allclose(
        np.asarray(p_ref["x"]), np.asarray(p_ker["x"]), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(float(l_ref), float(l_ker), rtol=1e-6)
