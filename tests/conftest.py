import os
import sys

# Tests run on the single local CPU device (the 512-device override is
# strictly dry-run-only, per the launcher contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running convergence tests (deselected by `make test-fast`)",
    )
