"""Raveled-view layer (ISSUE 7): tree_ravel / tree_unravel / stacked_ravel.

The load-bearing contract: the flat (D,) / (n, D) buffer is an *exact*
re-encoding of the structured pytree — bit-for-bit round trips for every
dtype the f32 buffer represents exactly (f32/bf16/f16), hard errors for
dtypes it cannot, and a spec static enough to ride through jit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import (
    stacked_ravel,
    tree_dot,
    tree_norm,
    tree_ravel,
    tree_size,
    tree_spec,
    tree_unravel,
)


def _nested(rng, dtype=jnp.float32):
    """A representative nested tree: dict/list mix, rank 0-4 leaves."""
    return {
        "conv": {
            "w": jnp.asarray(rng.standard_normal((3, 3, 2, 4)), dtype),
            "b": jnp.asarray(rng.standard_normal(4), dtype),
        },
        "head": [
            jnp.asarray(rng.standard_normal((4, 10)), dtype),
            jnp.asarray(rng.standard_normal(10), dtype),
        ],
        "scale": jnp.asarray(rng.standard_normal(()), dtype),
    }


def _bit_equal_tree(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        x.dtype == y.dtype
        and x.shape == y.shape
        # f32 represents every supported leaf dtype exactly, so equality of
        # the f32 views is bit equality (values here are finite by draw)
        and np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
        for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_round_trip_bit_exact(dtype):
    tree = _nested(np.random.default_rng(0), dtype)
    flat, spec = tree_ravel(tree)
    assert flat.dtype == jnp.float32
    assert flat.shape == (spec.total,)
    assert spec.total == tree_size(tree)
    assert _bit_equal_tree(tree, tree_unravel(spec, flat))


def test_round_trip_mixed_dtypes():
    rng = np.random.default_rng(1)
    tree = {
        "f32": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32),
        "bf16": jnp.asarray(rng.standard_normal(7), jnp.bfloat16),
    }
    flat, spec = tree_ravel(tree)
    back = tree_unravel(spec, flat)
    assert back["f32"].dtype == jnp.float32
    assert back["bf16"].dtype == jnp.bfloat16
    assert _bit_equal_tree(tree, back)


def test_unravel_cast_false_keeps_buffer_dtype():
    """The increment path: aggregation math stays f32, the server optimizer
    owns the cast back to the parameter dtype."""
    tree = {"w": jnp.ones((4,), jnp.bfloat16)}
    flat, spec = tree_ravel(tree)
    raw = tree_unravel(spec, flat, cast=False)
    assert raw["w"].dtype == jnp.float32
    assert raw["w"].shape == (4,)


def test_spec_is_static_and_hashable():
    tree = _nested(np.random.default_rng(2))
    spec = tree_spec(tree)
    assert spec == tree_spec(tree)
    assert hash(spec) == hash(tree_spec(tree))
    assert spec.sizes == tuple(int(x.size) for x in jax.tree.leaves(tree))
    # static enough for jit: close over the spec, trace only the buffer
    flat, _ = tree_ravel(tree)
    back = jax.jit(lambda f: tree_unravel(spec, f))(flat)
    assert _bit_equal_tree(tree, back)


def test_wrong_buffer_length_rejected():
    tree = {"w": jnp.ones((4,))}
    flat, spec = tree_ravel(tree)
    with pytest.raises(ValueError, match="buffer shape"):
        tree_unravel(spec, jnp.concatenate([flat, flat]))


def test_inexact_leaf_dtype_rejected():
    """An int leaf cannot round trip through the f32 buffer bit-exactly —
    the layer must refuse rather than silently truncate."""
    with pytest.raises(TypeError, match="not exactly representable"):
        tree_ravel({"steps": jnp.arange(4, dtype=jnp.int32)})
    with pytest.raises(TypeError, match="not exactly representable"):
        stacked_ravel({"steps": jnp.zeros((3, 4), jnp.int32)})


def test_empty_tree_round_trip():
    flat, spec = tree_ravel({})
    assert flat.shape == (0,)
    assert spec.total == 0
    assert tree_unravel(spec, flat) == {}


def test_stacked_ravel_rows_match_per_client_ravel():
    rng = np.random.default_rng(3)
    n = 5
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1.0) for i in range(n)]),
        _nested(rng),
    )
    buf, spec = stacked_ravel(stacked)
    assert buf.shape == (n, spec.total)
    for i in range(n):
        client = jax.tree.map(lambda x: x[i], stacked)
        row, client_spec = tree_ravel(client)
        assert client_spec == spec
        assert np.array_equal(np.asarray(buf[i]), np.asarray(row))
        assert _bit_equal_tree(client, tree_unravel(spec, buf[i]))


def test_stacked_ravel_inconsistent_leading_dim_rejected():
    bad = {"a": jnp.zeros((3, 2)), "b": jnp.zeros((4, 2))}
    with pytest.raises(ValueError, match="leading"):
        stacked_ravel(bad)


def test_tree_dot_and_norm_match_raveled():
    rng = np.random.default_rng(4)
    a, b = _nested(rng), _nested(rng)
    fa, _ = tree_ravel(a)
    fb, _ = tree_ravel(b)
    np.testing.assert_allclose(
        float(tree_dot(a, b)), float(jnp.vdot(fa, fb)), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(
        float(tree_norm(a)), float(jnp.linalg.norm(fa)), rtol=1e-6
    )
