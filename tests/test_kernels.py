"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import relay as core_relay
from repro.kernels import ops, ref
from repro.kernels import relay_mix as k


@pytest.mark.parametrize("n", [4, 10, 16, 32])
@pytest.mark.parametrize("D", [64, 100, 4096, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relay_mix_2d_sweep(n, D, dtype):
    rng = np.random.default_rng(hash((n, D)) % 2**31)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((n, D)), dtype)
    got = k.relay_mix_2d(A, d, interpret=True)
    want = ref.relay_mix_2d(A, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("n", [4, 16])
@pytest.mark.parametrize("D", [100, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_aggregate_2d_sweep(n, D, dtype):
    rng = np.random.default_rng(hash((n, D, 1)) % 2**31)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    tau = jnp.asarray(rng.random(n) < 0.5, jnp.float32)
    c = (1.0 / n) * tau @ A
    d = jnp.asarray(rng.standard_normal((n, D)), dtype)
    got = k.fused_aggregate_2d(c, d, interpret=True)
    want = ref.fused_aggregate_2d(c, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("block_d", [128, 512, 4096])
def test_block_size_invariance(block_d):
    rng = np.random.default_rng(7)
    A = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((8, 1000)), jnp.float32)
    got = k.relay_mix_2d(A, d, block_d=block_d, interpret=True)
    want = ref.relay_mix_2d(A, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_pytree_wrapper_matches_core_relay():
    rng = np.random.default_rng(3)
    n = 10
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    upd = {
        "w": jnp.asarray(rng.standard_normal((n, 33, 7)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n, 257)), jnp.float32),
    }
    got = ops.relay_mix(A, upd, interpret=True)
    want = core_relay.relay(A, upd)
    for key in upd:
        np.testing.assert_allclose(
            np.asarray(got[key]), np.asarray(want[key]), atol=1e-4
        )


def test_pytree_fused_matches_core():
    rng = np.random.default_rng(4)
    n = 10
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    tau = jnp.asarray(rng.random(n) < 0.5, jnp.float32)
    upd = {"w": jnp.asarray(rng.standard_normal((n, 65)), jnp.float32)}
    got = ops.fused_aggregate(A, tau, upd, w=0.1, interpret=True)
    want = core_relay.fused_aggregate(A, tau, upd, w=0.1)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]), atol=1e-4)


def test_kernel_under_jit_and_grad():
    """The kernel wrapper composes with jit (and is linear, so its vjp must
    reproduce Aᵀ on cotangents)."""
    n, D = 6, 300
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)

    def f(d):
        return ref.relay_mix_2d(A, d).sum()

    def f_kernel(d):
        return k.relay_mix_2d(A, d, interpret=True).sum()

    np.testing.assert_allclose(float(f(d)), float(f_kernel(d)), rtol=1e-5)
    g_ref = jax.grad(f)(d)
    g_k = jax.grad(f_kernel)(d)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_ref), atol=1e-4)


# ----------------------------------------------------------------------
# ISSUE 7: flat-buffer dispatch parity (ops.mix_flat / ops.reduce_flat)
# ----------------------------------------------------------------------


def _flat_case(n, dtype, masked, salt=0):
    """One (A, buf, active, coeffs) draw for the flat-path sweep.  D=1000 is
    deliberately not a multiple of block_d=256 so the kernels pad a tail
    block; A and coeffs are scaled by 1/√n to keep outputs O(1) across n."""
    D = 1000
    rng = np.random.default_rng(hash((n, D, masked, salt)) % 2**31)
    scale = 1.0 / max(1.0, np.sqrt(n))
    A = jnp.asarray(rng.standard_normal((n, n)) * scale, jnp.float32)
    buf = jnp.asarray(rng.standard_normal((n, D)), dtype)
    active = None
    if masked:
        act = rng.random(n) < 0.6
        act[rng.integers(n)] = True  # at least one live client
        active = jnp.asarray(act, jnp.float32)
    coeffs = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    if active is not None:
        coeffs = coeffs * active
    return A, buf, active, coeffs


@pytest.mark.parametrize("n", [1, 7, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("masked", [False, True])
def test_mix_flat_backend_parity(n, dtype, masked):
    """The streaming mix kernel vs the einsum oracle through the ops
    dispatch: degenerate n=1 up to n=128, f32/bf16 buffers, tail padding,
    with and without the churn active mask."""
    A, buf, active, _ = _flat_case(n, dtype, masked)
    got = ops.mix_flat(
        A, buf, active=active, backend="pallas", block_d=256, interpret=True
    )
    want = ops.mix_flat(A, buf, active=active, backend="einsum")
    assert got.shape == want.shape == buf.shape
    tol = 1e-4 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("n", [1, 7, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("masked", [False, True])
def test_reduce_flat_backend_parity(n, dtype, masked):
    """The fused reduction kernel vs the einsum oracle on the same sweep;
    churn masking rides in the coefficients (the reduce_flat contract)."""
    _, buf, _, coeffs = _flat_case(n, dtype, masked, salt=1)
    got = ops.reduce_flat(
        coeffs, buf, backend="pallas_fused", block_d=256, interpret=True
    )
    want = ops.reduce_flat(coeffs, buf, backend="einsum")
    assert got.shape == want.shape == (buf.shape[1],)
    tol = 1e-4 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


def test_flat_dispatch_rejects_unknown_backend():
    buf = jnp.zeros((2, 8))
    with pytest.raises(ValueError, match="relay_backend"):
        ops.mix_flat(jnp.eye(2), buf, backend="triton")
    with pytest.raises(ValueError, match="relay_backend"):
        ops.reduce_flat(jnp.ones(2), buf, backend="cuda")


def test_custom_vjp_gradient_parity_vs_einsum():
    """The mix kernel's custom_vjp must reproduce the einsum reference's
    cotangents for BOTH operands — dΔ (the transposed kernel pass) and dA
    (the (n, n) reduction) — through a padded tail block."""
    n, D = 5, 700  # 700 = 2·256 + 188: the bwd kernel also crosses padding
    rng = np.random.default_rng(17)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    cot = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)

    def loss_kernel(A_, d_):
        return jnp.vdot(k.relay_mix_2d(A_, d_, block_d=256, interpret=True), cot)

    def loss_ref(A_, d_):
        return jnp.vdot(ref.relay_mix_2d(A_, d_), cot)

    np.testing.assert_allclose(
        float(loss_kernel(A, d)), float(loss_ref(A, d)), rtol=1e-5
    )
    gA_k, gd_k = jax.grad(loss_kernel, argnums=(0, 1))(A, d)
    gA_r, gd_r = jax.grad(loss_ref, argnums=(0, 1))(A, d)
    np.testing.assert_allclose(np.asarray(gA_k), np.asarray(gA_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gd_k), np.asarray(gd_r), atol=1e-4)
