"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import relay as core_relay
from repro.kernels import ops, ref
from repro.kernels import relay_mix as k


@pytest.mark.parametrize("n", [4, 10, 16, 32])
@pytest.mark.parametrize("D", [64, 100, 4096, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relay_mix_2d_sweep(n, D, dtype):
    rng = np.random.default_rng(hash((n, D)) % 2**31)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((n, D)), dtype)
    got = k.relay_mix_2d(A, d, interpret=True)
    want = ref.relay_mix_2d(A, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("n", [4, 16])
@pytest.mark.parametrize("D", [100, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_aggregate_2d_sweep(n, D, dtype):
    rng = np.random.default_rng(hash((n, D, 1)) % 2**31)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    tau = jnp.asarray(rng.random(n) < 0.5, jnp.float32)
    c = (1.0 / n) * tau @ A
    d = jnp.asarray(rng.standard_normal((n, D)), dtype)
    got = k.fused_aggregate_2d(c, d, interpret=True)
    want = ref.fused_aggregate_2d(c, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("block_d", [128, 512, 4096])
def test_block_size_invariance(block_d):
    rng = np.random.default_rng(7)
    A = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((8, 1000)), jnp.float32)
    got = k.relay_mix_2d(A, d, block_d=block_d, interpret=True)
    want = ref.relay_mix_2d(A, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_pytree_wrapper_matches_core_relay():
    rng = np.random.default_rng(3)
    n = 10
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    upd = {
        "w": jnp.asarray(rng.standard_normal((n, 33, 7)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n, 257)), jnp.float32),
    }
    got = ops.relay_mix(A, upd, interpret=True)
    want = core_relay.relay(A, upd)
    for key in upd:
        np.testing.assert_allclose(
            np.asarray(got[key]), np.asarray(want[key]), atol=1e-4
        )


def test_pytree_fused_matches_core():
    rng = np.random.default_rng(4)
    n = 10
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    tau = jnp.asarray(rng.random(n) < 0.5, jnp.float32)
    upd = {"w": jnp.asarray(rng.standard_normal((n, 65)), jnp.float32)}
    got = ops.fused_aggregate(A, tau, upd, w=0.1, interpret=True)
    want = core_relay.fused_aggregate(A, tau, upd, w=0.1)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]), atol=1e-4)


def test_kernel_under_jit_and_grad():
    """The kernel wrapper composes with jit (and is linear, so its vjp must
    reproduce Aᵀ on cotangents)."""
    n, D = 6, 300
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)

    def f(d):
        return ref.relay_mix_2d(A, d).sum()

    def f_kernel(d):
        return k.relay_mix_2d(A, d, interpret=True).sum()

    np.testing.assert_allclose(float(f(d)), float(f_kernel(d)), rtol=1e-5)
    g_ref = jax.grad(f)(d)
    g_k = jax.grad(f_kernel)(d)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_ref), atol=1e-4)
