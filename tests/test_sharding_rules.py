"""Rule resolution in `sharding/rules.py` is pure shape arithmetic — these
unit tests exercise it against stub meshes (axis_names + shape dict), no
multi-device runtime required.  `make_client_mesh` is covered on the single
local CPU device (the error path plus axis naming); the real 8-device mesh
behaviour lives in the subprocess tests (`test_ring_relay.py`,
`test_sharded_engine.py`)."""
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_client_mesh
from repro.sharding import rules


class StubMesh:
    """Just enough mesh for rule resolution: named axes and their sizes."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


CLIENT8 = StubMesh(clients=8)
MODEL4 = StubMesh(model=4)
PROD = StubMesh(data=4, model=4)
POD = StubMesh(pod=2, data=4, model=4)


# --------------------------------------------------------------------- axes


def test_shard_axis_prefers_clients_axis():
    assert rules.shard_axis(CLIENT8) == "clients"


def test_shard_axis_falls_back_to_client_axes():
    assert rules.shard_axis(PROD) == "data"
    assert rules.shard_axis(POD) == "pod"


def test_client_axes_single_and_multi_pod():
    assert rules.client_axes(PROD) == ("data",)
    assert rules.client_axes(POD) == ("pod", "data")


# --------------------------------------------- epoch-stacked round batches


def test_round_batch_specs_shards_dim1_only():
    batch = {"c": np.zeros((6, 8, 2, 4, 3)), "y": np.zeros((6, 8, 2, 4))}
    specs = rules.round_batch_specs(batch, CLIENT8)
    assert specs["c"] == P(None, "clients", None, None, None)
    assert specs["y"] == P(None, "clients", None, None)


def test_round_batch_specs_rank2_leaf():
    specs = rules.round_batch_specs({"m": np.zeros((6, 8))}, CLIENT8)
    assert specs["m"] == P(None, "clients")


def test_round_batch_specs_on_production_mesh():
    specs = rules.round_batch_specs({"c": np.zeros((6, 8, 2))}, PROD)
    assert specs["c"] == P(None, "data", None)


def test_train_batch_specs_shards_client_dim():
    specs = rules.train_batch_specs({"c": np.zeros((8, 2, 4, 3))}, PROD)
    assert specs["c"] == P(("data",), None, None, None)
    specs = rules.train_batch_specs({"c": np.zeros((8, 2))}, POD)
    assert specs["c"] == P(("pod", "data"), None)


# ------------------------------------------------- flat (n, D) delta buffer


def test_flat_buffer_specs_divisible_d():
    assert rules.flat_buffer_specs(MODEL4, n=8, d=12) == P(None, "model")


def test_flat_buffer_specs_indivisible_d_replicates():
    # a constraint that does not divide is worse than none
    assert rules.flat_buffer_specs(MODEL4, n=8, d=10) == P(None, None)
    assert rules.flat_buffer_specs(MODEL4, n=8, d=2) == P(None, None)


def test_flat_buffer_specs_no_model_axis_replicates():
    assert rules.flat_buffer_specs(CLIENT8, n=8, d=64) == P(None, None)


def test_flat_buffer_specs_unknown_d_defers_to_gspmd():
    assert rules.flat_buffer_specs(MODEL4, n=8, d=None) == P(None, "model")


# -------------------------------------------------------- parameter specs


def test_param_specs_tp_shards_largest_divisible_dim():
    params = {"w": np.zeros((8, 12)), "b": np.zeros((7,))}
    specs = rules.param_specs(params, PROD, mode="tp")
    assert specs["w"] == P(None, "model")  # 12 > 8, both divide 4
    assert specs["b"] == P(None)  # 7 not divisible: replicated


def test_param_specs_fsdp_tp_adds_data_dim():
    specs = rules.param_specs({"w": np.zeros((8, 12))}, PROD, mode="fsdp_tp")
    assert specs["w"] == P("data", "model")


def test_param_specs_never_shards_stack_dims():
    params = {"blocks": {"w": np.zeros((3, 8, 8))}}
    specs = rules.param_specs(params, PROD)
    # dim 0 is the stacked-layer dim: skipped even though 3 < 4 anyway;
    # the tie between the two 8s resolves to the later dim
    assert specs["blocks"]["w"] == P(None, None, "model")


# ------------------------------------------------------------ real meshes


def test_to_shardings_wraps_specs():
    mesh = make_client_mesh()  # all local devices (1 in-process)
    spec_tree = {"c": P(None, "clients")}
    shardings = rules.to_shardings(spec_tree, mesh)
    assert isinstance(shardings["c"], NamedSharding)
    assert shardings["c"].spec == P(None, "clients")
    assert shardings["c"].mesh.axis_names == ("clients",)


def test_make_client_mesh_axis_naming():
    assert make_client_mesh().axis_names == ("clients",)
    assert make_client_mesh(1, axis="model").axis_names == ("model",)
    assert make_client_mesh(1).devices.ndim == 1


def test_make_client_mesh_too_many_devices_raises():
    with pytest.raises(RuntimeError, match="need 4096 devices"):
        make_client_mesh(4096)
