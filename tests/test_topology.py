import numpy as np
import pytest

from repro.core import topology


def test_ring_degrees():
    adj = topology.ring(10, k=1)
    assert adj.sum(1).tolist() == [2] * 10
    adj2 = topology.ring(10, k=2)  # 4 nearest neighbors (paper Fig. 4)
    assert adj2.sum(1).tolist() == [4] * 10


def test_fully_connected():
    adj = topology.fully_connected(6)
    assert adj.sum() == 6 * 5
    assert not adj.diagonal().any()


def test_symmetry_and_no_self_loops():
    for adj in [topology.ring(7, 2), topology.erdos_renyi(12, 0.4, seed=1),
                topology.clusters(10, 3), topology.fully_connected(5)]:
        assert np.array_equal(adj, adj.T)
        assert not adj.diagonal().any()


def test_clusters_disconnected_across():
    adj = topology.clusters(9, 3)
    assert not adj[0, 3] and not adj[3, 6]
    assert adj[0, 1] and adj[3, 4]


def test_closed_mask_includes_self():
    adj = topology.ring(5, 1)
    m = topology.closed_mask(adj)
    assert m.diagonal().all()


def test_common_neighborhood_literal():
    adj = topology.ring(6, 1)
    m3 = topology.common_neighborhood_sets(adj)
    m = topology.closed_mask(adj)
    for j in range(6):
        for i in range(6):
            for l in range(6):
                assert m3[j, i, l] == (m[j, i] and m[j, l])


def test_from_edges_roundtrip():
    adj = topology.from_edges(4, [(0, 1), (2, 3), (1, 1)])
    assert adj[0, 1] and adj[1, 0] and adj[2, 3]
    assert not adj[1, 1]


def test_asymmetric_rejected():
    bad = np.zeros((3, 3), bool)
    bad[0, 1] = True
    with pytest.raises(ValueError):
        topology.neighborhoods(bad)
