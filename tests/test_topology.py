import numpy as np
import pytest

from repro.core import topology


def test_ring_degrees():
    adj = topology.ring(10, k=1)
    assert adj.sum(1).tolist() == [2] * 10
    adj2 = topology.ring(10, k=2)  # 4 nearest neighbors (paper Fig. 4)
    assert adj2.sum(1).tolist() == [4] * 10


def test_fully_connected():
    adj = topology.fully_connected(6)
    assert adj.sum() == 6 * 5
    assert not adj.diagonal().any()


def test_symmetry_and_no_self_loops():
    for adj in [topology.ring(7, 2), topology.erdos_renyi(12, 0.4, seed=1),
                topology.clusters(10, 3), topology.fully_connected(5)]:
        assert np.array_equal(adj, adj.T)
        assert not adj.diagonal().any()


def test_clusters_disconnected_across():
    adj = topology.clusters(9, 3)
    assert not adj[0, 3] and not adj[3, 6]
    assert adj[0, 1] and adj[3, 4]


def test_closed_mask_includes_self():
    adj = topology.ring(5, 1)
    m = topology.closed_mask(adj)
    assert m.diagonal().all()


def test_common_neighborhood_literal():
    adj = topology.ring(6, 1)
    m3 = topology.common_neighborhood_sets(adj)
    m = topology.closed_mask(adj)
    for j in range(6):
        for i in range(6):
            for l in range(6):
                assert m3[j, i, l] == (m[j, i] and m[j, l])


def test_from_edges_roundtrip():
    adj = topology.from_edges(4, [(0, 1), (2, 3), (1, 1)])
    assert adj[0, 1] and adj[1, 0] and adj[2, 3]
    assert not adj[1, 1]


def test_asymmetric_rejected():
    bad = np.zeros((3, 3), bool)
    bad[0, 1] = True
    with pytest.raises(ValueError):
        topology.neighborhoods(bad)


def test_closed_csc_matches_dense_nonzero():
    rng = np.random.default_rng(4)
    for _ in range(6):
        n = int(rng.integers(3, 20))
        adj = topology.erdos_renyi(n, 0.3, seed=int(rng.integers(1 << 30)))
        g = topology.closed_csc(adj)
        m = topology.closed_mask(adj)
        assert g.n == n and g.nnz == int(m.sum())
        np.testing.assert_array_equal(g.todense_mask(), m)
        np.testing.assert_array_equal(g.column_counts(), m.sum(axis=0))
        for i in range(n):
            col = g.column(i)
            np.testing.assert_array_equal(col, np.nonzero(m[:, i])[0])
            assert i in col  # diagonal always stored
        # flat (rows, cols) walk is column-major and sorted within columns
        np.testing.assert_array_equal(
            g.cols, np.repeat(np.arange(n), np.diff(g.indptr))
        )


def test_random_geometric_matches_brute_force():
    """The grid-binned neighbor search finds exactly the pairs within
    ``radius`` — same positions recomputed from the seeded RNG stream."""
    for n, radius, seed in [(40, 0.3, 0), (120, 0.17, 5), (25, 0.9, 2)]:
        adj = topology.random_geometric(n, radius, seed=seed)
        pos = np.random.default_rng(seed).random((n, 2))
        d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
        want = d2 <= radius * radius
        np.fill_diagonal(want, False)
        np.testing.assert_array_equal(adj, want)


def test_random_geometric_invariants_and_target_degree():
    n, deg = 2000, 8.0
    radius = float(np.sqrt(deg / (np.pi * n)))
    adj = topology.random_geometric(n, radius, seed=7)
    assert adj.dtype == bool and adj.shape == (n, n)
    np.testing.assert_array_equal(adj, adj.T)
    assert not np.diag(adj).any()
    mean_deg = adj.sum() / n
    assert deg * 0.6 < mean_deg < deg * 1.4  # boundary effects shave a bit
    with pytest.raises(ValueError, match="radius"):
        topology.random_geometric(4, 0.0)
