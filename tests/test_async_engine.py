"""Asynchronous round engine (staleness-weighted aggregation) + the delay
processes feeding it.

The acceptance invariant: at delay 0 the async engine is **bit-identical**
to ``run_rounds_loop`` — params, server state, per-round metrics and the
final RNG key — across a churned, correlated-shadowing schedule (the
hardest synchronous setting the repo has).  On top of that: delay-stream
determinism, freshest-k buffer selection, never-arrived rounds applying a
zero increment, supersession of stale in-flight updates, strategy refusal,
and burst continuation (``reset=False``) matching one uninterrupted run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels
from repro.channels.delay import (
    DelayProcess,
    GeometricDelays,
    PoissonDelays,
    ZeroDelays,
    make_delays,
)
from repro.core import topology
from repro.core.aggregation import ServerOpt
from repro.fl.async_engine import AsyncRoundEngine, select_freshest
from repro.fl.engine import run_rounds_loop
from repro.fl.simulator import FLSimulator

N = 6
DIM = 4


def _loss_fn(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))


def _params0():
    return {"x": jnp.ones((DIM,))}


def _batch_stream(seed=42):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((N, 2, 4, DIM)).astype(np.float32)}

    return next_batch


def _churn_shadow_schedule(seed=3):
    """Rotating-cohort churn over a correlated-shadowing D2D graph."""
    field = channels.ShadowingField(
        channels.circle_positions(N), corr_length=0.4, rho=0.9, sigma=1.0,
        seed=seed)
    link = channels.ShadowedLinkProcess(
        topology.ring(N, 2), field, threshold=1.0)
    member = channels.RotatingCohorts(N, n_cohorts=3, hold=5)
    return channels.ChurnSchedule(
        membership=member, link_process=link,
        p=np.linspace(0.3, 0.9, N), adj_every=3, p_every=4)


def _static_schedule():
    return channels.StaticChannel(
        topology.ring(N, 2), np.linspace(0.3, 0.9, N))


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _run(engine_kind, *, rounds=17, delays=None, schedule_fn=_churn_shadow_schedule,
         strategy="colrel_fused", momentum=0.5, seed=42, **engine_kw):
    next_batch = _batch_stream(seed)
    sim = FLSimulator(
        _loss_fn, n_clients=N, strategy=strategy,
        server_opt=ServerOpt(momentum=momentum))
    params = _params0()
    ss = sim.init_server_state(params)
    key = jax.random.key(7)
    policy = (
        channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)
        if strategy == "colrel_fused" else None)
    schedule = schedule_fn()
    if engine_kind == "loop":
        return run_rounds_loop(
            sim, key, params, ss, schedule=schedule, rounds=rounds,
            next_batch=next_batch, lr=0.1, policy=policy)
    engine = AsyncRoundEngine(sim, delays=delays, **engine_kw)
    return engine.run_schedule(
        key, params, ss, schedule=schedule, rounds=rounds,
        next_batch=next_batch, lr=0.1, policy=policy)


# -------------------------------------------------------------- delay procs


def test_delay_processes_deterministic_and_reset():
    for proc in (PoissonDelays(8, rate=1.5, seed=4),
                 GeometricDelays(8, mean=2.0, seed=4)):
        first = [proc.sample() for _ in range(5)]
        proc.reset()
        replay = [proc.sample() for _ in range(5)]
        for a, b in zip(first, replay):
            assert np.array_equal(a, b)
        assert any(d.max() > 0 for d in first)  # genuinely nonzero stream


def test_delay_samples_clipped_and_typed():
    proc = PoissonDelays(16, rate=50.0, max_delay=3, seed=0)
    for _ in range(4):
        d = proc.sample()
        assert d.dtype == np.int64 and d.shape == (16,)
        assert d.min() >= 0 and d.max() <= 3


def test_zero_delays_and_factory():
    assert np.array_equal(ZeroDelays(5).sample(), np.zeros(5, np.int64))
    assert isinstance(make_delays("none", 5), ZeroDelays)
    assert isinstance(make_delays("poisson", 5), PoissonDelays)
    assert isinstance(make_delays("geometric", 5), GeometricDelays)
    with pytest.raises(ValueError):
        make_delays("uniform", 5)


def test_geometric_delays_support_includes_zero():
    d = np.concatenate(
        [GeometricDelays(64, mean=0.5, seed=1).sample() for _ in range(8)])
    assert d.min() == 0  # support {0, 1, ...}, not the raw geometric {1, ...}


# ------------------------------------------------------- freshest-k buffer


def test_select_freshest_caps_and_orders():
    stale = np.array([3, 0, 2, 0, 5, 1])
    elig = np.ones(6, bool)
    sel = select_freshest(stale, elig, 3)
    # two s=0 slots, then the s=1 slot; index breaks the s=0 tie
    assert np.array_equal(sel, [False, True, False, True, False, True])
    # k=0 and k >= eligible count select everything eligible
    assert np.array_equal(select_freshest(stale, elig, 0), elig)
    assert np.array_equal(select_freshest(stale, elig, 99), elig)
    # ineligible slots never selected, even when fresh
    elig2 = np.array([True, False, True, True, True, True])
    assert not select_freshest(stale, elig2, 3)[1]


# --------------------------------------------- delay-0 bitwise (acceptance)


@pytest.mark.parametrize("strategy", ["colrel_fused", "fedavg_blind"])
def test_delay0_bitwise_identical_to_loop_under_churn_shadowing(strategy):
    """The tentpole contract: ZeroDelays ⇒ the async engine reproduces the
    per-round loop bit-for-bit — params, server momentum, every per-round
    metric and the final RNG key — under rotating churn + correlated
    shadowing."""
    lp, ls, lm, lk = _run("loop", strategy=strategy)
    ap, as_, am, ak = _run("async", delays=ZeroDelays(N), strategy=strategy)
    assert _tree_equal(lp, ap)
    assert _tree_equal(ls, as_)
    assert _tree_equal(lm, am)
    assert np.array_equal(jax.random.key_data(lk), jax.random.key_data(ak))


def test_delay0_bitwise_on_static_channel_full_sync_fast_path():
    """No churn + delay 0 exercises the static-1/n fast path (the compiled
    constant the synchronous active=None program uses)."""
    lp, _, lm, _ = _run("loop", schedule_fn=_static_schedule)
    ap, _, am, _ = _run(
        "async", delays=ZeroDelays(N), schedule_fn=_static_schedule)
    assert _tree_equal(lp, ap)
    assert _tree_equal(lm, am)


# ----------------------------------------------------------- delayed runs


def test_nonzero_delay_diverges_but_stays_finite():
    lp, _, _, _ = _run("loop", schedule_fn=_static_schedule)
    ap, _, am, _ = _run(
        "async", delays=PoissonDelays(N, rate=1.0, seed=5),
        schedule_fn=_static_schedule)
    assert not _tree_equal(lp, ap)  # buffered staleness really changes math
    assert np.isfinite(np.asarray(am["loss"])).all()
    assert np.isfinite(np.asarray(jax.tree.leaves(ap)[0])).all()


def test_never_arrived_rounds_apply_zero_increment():
    """Until the first arrival lands, the aggregate is exactly zero: params
    stay bit-identical to the broadcast model."""

    class FixedDelay(DelayProcess):
        def _draw(self, rng):
            return np.full(self.n, 3)

    next_batch = _batch_stream()
    sim = FLSimulator(_loss_fn, n_clients=N, strategy="fedavg_blind")
    params = _params0()
    engine = AsyncRoundEngine(sim, delays=FixedDelay(N, max_delay=8))
    seen = []
    engine.run_schedule(
        jax.random.key(0), params, sim.init_server_state(params),
        schedule=_static_schedule(), rounds=5, next_batch=next_batch,
        lr=0.1, on_round=lambda r, p: seen.append(np.asarray(p["x"])))
    # rounds 0..2 aggregate an empty buffer (first arrivals land at t=3)
    for r in range(3):
        assert np.array_equal(seen[r], np.asarray(params["x"]))
    assert not np.array_equal(seen[3], np.asarray(params["x"]))


def test_newest_arrival_supersedes_older_in_flight():
    """Client updates from rounds 0 and 1 both landing at t=2 keep only the
    round-1 row (newest source wins)."""

    class TwoThenZero(DelayProcess):
        def _draw(self, rng):
            return np.full(self.n, 2 if self.round == 0 else 1)

    sim = FLSimulator(_loss_fn, n_clients=N, strategy="fedavg_blind")
    params = _params0()
    engine = AsyncRoundEngine(sim, delays=TwoThenZero(N))
    engine.run_schedule(
        jax.random.key(0), params, sim.init_server_state(params),
        schedule=_static_schedule(), rounds=3, next_batch=_batch_stream(),
        lr=0.1)
    assert np.array_equal(engine._held_round, np.full(N, 1))


def test_buffer_k_truncates_even_at_delay0():
    full = _run("async", delays=ZeroDelays(N), schedule_fn=_static_schedule)
    capped = _run("async", delays=ZeroDelays(N),
                  schedule_fn=_static_schedule, buffer_k=3)
    assert not _tree_equal(full[0], capped[0])


# ------------------------------------------------------------- validation


def test_unsupported_strategies_refused():
    for strategy in ("colrel", "fedavg_nonblind"):
        sim = FLSimulator(_loss_fn, n_clients=N, strategy=strategy)
        with pytest.raises(ValueError, match="supports strategies"):
            AsyncRoundEngine(sim)


def test_constructor_validation():
    sim = FLSimulator(_loss_fn, n_clients=N, strategy="colrel_fused")
    with pytest.raises(ValueError, match="staleness_decay"):
        AsyncRoundEngine(sim, staleness_decay=0.0)
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncRoundEngine(sim, buffer_k=-1)
    with pytest.raises(ValueError, match="delay process"):
        AsyncRoundEngine(sim, delays=ZeroDelays(N + 1))


# ------------------------------------------------------------ continuation


def test_burst_continuation_matches_uninterrupted_run():
    """Two reset=False bursts through one engine equal one uninterrupted
    run bit-for-bit — delays, pending arrivals and the held buffer all
    continue across the burst boundary (the ContinuousTrainer contract)."""
    rounds = 12

    def run_bursts(splits):
        next_batch = _batch_stream()
        sim = FLSimulator(_loss_fn, n_clients=N, strategy="colrel_fused",
                          server_opt=ServerOpt(momentum=0.5))
        params = _params0()
        ss = sim.init_server_state(params)
        key = jax.random.key(7)
        policy = channels.AdaptiveOptAlpha(sweeps=20, warm_sweeps=8)
        schedule = _churn_shadow_schedule()
        engine = AsyncRoundEngine(
            sim, delays=PoissonDelays(N, rate=1.0, seed=5))
        first = True
        for r in splits:
            params, ss, _, key = engine.run_schedule(
                key, params, ss, schedule=schedule, rounds=r,
                next_batch=next_batch, lr=0.1, policy=policy,
                reset=first)
            first = False
        return params, ss, key

    p1, s1, k1 = run_bursts([rounds])
    p2, s2, k2 = run_bursts([5, rounds - 5])
    assert _tree_equal(p1, p2)
    assert _tree_equal(s1, s2)
    assert np.array_equal(jax.random.key_data(k1), jax.random.key_data(k2))


def test_trace_count_stays_bounded_across_rounds():
    """The per-round host loop reuses four compiled programs — no retrace
    as the round index, buffer contents or staleness pattern change."""
    next_batch = _batch_stream()
    sim = FLSimulator(_loss_fn, n_clients=N, strategy="fedavg_blind")
    params = _params0()
    engine = AsyncRoundEngine(
        sim, delays=GeometricDelays(N, mean=1.0, seed=2), buffer_k=4)
    engine.run_schedule(
        jax.random.key(0), params, sim.init_server_state(params),
        schedule=_static_schedule(), rounds=20, next_batch=next_batch,
        lr=0.1)
    assert engine.trace_count <= 4
