import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint


def _tree():
    return {
        "blocks": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "head": [jnp.zeros((2, 2)), jnp.int32(7)],
    }


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, t, metadata={"round": 3, "arch": "x"})
    like = jax.tree.map(jnp.zeros_like, t)
    got = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype
    assert checkpoint.load_metadata(path) == {"round": 3, "arch": "x"}


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, t)
    bad = jax.tree.map(lambda x: jnp.zeros(x.shape + (1,), x.dtype), t)
    with pytest.raises(ValueError):
        checkpoint.restore(path, bad)


def test_missing_leaf_rejected(tmp_path):
    t = _tree()
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, t)
    bigger = {**t, "extra": jnp.zeros((1,))}
    with pytest.raises(KeyError):
        checkpoint.restore(path, bigger)


def test_model_params_roundtrip(tmp_path):
    from repro.configs import registry as creg
    from repro.models import registry as mreg

    cfg = creg.get_config("glm4-9b", reduced=True)
    md = mreg.get_model(cfg)
    params = md.init(jax.random.key(0))
    path = str(tmp_path / "model.npz")
    checkpoint.save(path, params)
    got = checkpoint.restore(path, jax.tree.map(jnp.zeros_like, params))
    batch_tokens = jnp.ones((1, 8), jnp.int32)
    l1 = md.loss(params, {"tokens": batch_tokens, "labels": batch_tokens})
    l2 = md.loss(got, {"tokens": batch_tokens, "labels": batch_tokens})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
