"""Sparse aggregation (ISSUE 9): EdgeRelay operands + the segment backend,
and the neighborhood-blocked OPT-α solver behind them.

Contracts held here:

  * **EdgeRelay == dense** — ``fused_coefficients`` / ``segment_mix`` /
    ``colrel_increment_flat`` on an EdgeRelay match the dense einsum math on
    ``todense()`` of the same structure;
  * **churn stays exact** — inactive-slot garbage contributes *exactly zero*
    through the segment backend, and an all-inactive cohort yields the exact
    zero increment (never NaN) on every backend;
  * **optimize_sparse == optimize_masked** — the sparse solver's active
    block matches the dense masked solve to 1e-8 on random sparse graphs
    (converged solves: unconverged Gauss–Seidel trajectories amplify fp
    noise in degenerate columns, so the comparison fixes sweeps=200 and
    keeps p off the {0, 1} endpoints).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, opt_alpha, relay as relay_lib, topology
from repro.fl.simulator import FLSimulator
from repro.kernels import ops as kops
from repro.utils import stacked_ravel

ALL_BACKENDS = ("einsum", "pallas", "pallas_fused", "segment")


def _sparse_setting(seed=0, n=12):
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.05, 0.95, n)
    adj = topology.random_geometric(n, 0.5, seed=seed)
    res = opt_alpha.optimize_sparse(p, adj, sweeps=200)
    er = res.edge_relay()
    A = res.todense().astype(np.float32)
    tau = jnp.asarray(rng.random(n) < p, jnp.float32)
    act = rng.random(n) < 0.6
    act[0] = True
    active = jnp.asarray(act, jnp.float32)
    buf = jnp.asarray(rng.standard_normal((n, 37)), jnp.float32)
    return er, A, p, tau, active, buf


# ------------------------------------------------------- EdgeRelay operand


def test_edge_relay_dense_roundtrip():
    er, A, *_ = _sparse_setting(1)
    np.testing.assert_allclose(np.asarray(er.todense(A.shape[0])), A, atol=1e-7)
    er2 = relay_lib.edge_relay_from_dense(A)
    np.testing.assert_allclose(
        np.asarray(er2.todense(A.shape[0])), A, atol=1e-7
    )


def test_fused_coefficients_edge_relay_matches_dense():
    er, A, _, tau, active, _ = _sparse_setting(2)
    want = np.asarray(tau) @ A
    got = relay_lib.fused_coefficients(er, tau)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
    # masked: the EdgeRelay branch zeros entries with either endpoint dead
    er_m = relay_lib.mask_relay_matrix(er, active)
    A_m = np.asarray(relay_lib.mask_relay_matrix(jnp.asarray(A), active))
    got_m = relay_lib.fused_coefficients(er_m, tau)
    np.testing.assert_allclose(
        np.asarray(got_m), np.asarray(tau) @ A_m, rtol=1e-5, atol=1e-6
    )


def test_segment_mix_matches_dense_relay():
    er, A, _, _, _, buf = _sparse_setting(3)
    got = relay_lib.segment_mix(er, buf)
    np.testing.assert_allclose(
        np.asarray(got), A @ np.asarray(buf), rtol=1e-5, atol=1e-5
    )
    with pytest.raises(TypeError):
        relay_lib.segment_mix(jnp.asarray(A), buf)


@pytest.mark.parametrize("strategy", ["colrel", "colrel_fused"])
@pytest.mark.parametrize("churn", [False, True])
def test_segment_backend_matches_einsum_reference(strategy, churn):
    er, A, _, tau, active, buf = _sparse_setting(4)
    n = A.shape[0]
    active = active if churn else None
    want = aggregation.make_aggregator(strategy, n=n, A=jnp.asarray(A)).flat_fn(
        tau, buf, None, active
    )
    got = aggregation.make_aggregator(
        strategy, n=n, A=er, relay_backend="segment"
    ).flat_fn(tau, buf, None, active)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


def test_dense_backends_densify_edge_relay_operands():
    """Small-n parity convenience: an EdgeRelay through a dense backend is
    the same increment as its todense() matrix."""
    er, A, _, tau, active, buf = _sparse_setting(5)
    n = A.shape[0]
    for backend in ("einsum", "pallas_fused"):
        got = aggregation.colrel_increment_flat(
            er, tau, buf, n=n, active=active, backend=backend,
            block_d=256, interpret=True,
        )
        want = aggregation.colrel_increment_flat(
            jnp.asarray(A), tau, buf, n=n, active=active, backend=backend,
            block_d=256, interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )


def test_segment_backend_refuses_dense_matrix():
    er, A, _, tau, _, buf = _sparse_setting(6)
    with pytest.raises(ValueError, match="EdgeRelay"):
        aggregation.colrel_increment_flat(
            jnp.asarray(A), tau, buf, n=A.shape[0], backend="segment"
        )


def test_validate_sharded_backend_refuses_segment():
    for shard, exchange in (("clients", "gather"), ("clients", "ring"), ("d", "gather")):
        with pytest.raises(ValueError, match="single-host"):
            kops.validate_sharded_backend("segment", shard=shard, exchange=exchange)


# ------------------------------------------------- exact-zero churn contract


def test_segment_churn_contributes_exactly_zero():
    """Poisoned inactive rows (large-but-finite) must cancel to exact zeros
    through the segment backend — masking multiplies edge values, not the
    buffer, so 0·1e30 never appears."""
    er, A, _, tau, active, buf = _sparse_setting(7)
    n = A.shape[0]
    poisoned = jnp.where(active[:, None] > 0, buf, jnp.float32(1e30))
    clean = buf * active[:, None]
    for strategy in ("colrel", "colrel_fused"):
        agg = aggregation.make_aggregator(
            strategy, n=n, A=er, relay_backend="segment"
        )
        got_p = agg.flat_fn(tau, poisoned, None, active)
        got_c = agg.flat_fn(tau, clean, None, active)
        assert np.isfinite(np.asarray(got_p)).all(), strategy
        assert np.array_equal(np.asarray(got_p), np.asarray(got_c)), strategy


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize(
    "strategy",
    ["colrel", "colrel_fused", "fedavg_blind", "fedavg_nonblind", "no_dropout"],
)
def test_all_inactive_cohort_yields_exact_zero_increment(backend, strategy):
    """Satellite 3: an empty cohort must produce the exact-zero increment on
    every backend and every flat path — the 1/max(n_active, 1) guard keeps
    the weight finite, and the masked coefficients are exact zeros, so no
    0/0 or 0·inf can surface as NaN."""
    er, A, _, tau, _, buf = _sparse_setting(8)
    n = A.shape[0]
    # poison the buffer too: dead slots must not even be read into the sum
    buf = jnp.where(jnp.ones((n, 1)) > 0, buf, buf)
    none_active = jnp.zeros((n,), jnp.float32)
    operand = er if backend == "segment" else jnp.asarray(A)
    if strategy not in ("colrel", "colrel_fused"):
        operand = None
    agg = aggregation.make_aggregator(
        strategy, n=n, A=operand, relay_backend=backend,
        block_d=256, interpret=True,
    )
    got = np.asarray(agg.flat_fn(tau, buf, None, none_active))
    assert np.all(got == 0.0), (backend, strategy, got)


# ------------------------------------------- sparse solver == masked solver


@pytest.mark.parametrize("method", ["bisect", "exact"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_optimize_sparse_matches_optimize_masked(method, seed):
    """Acceptance: the neighborhood-blocked solver matches the dense masked
    solve's active block to 1e-8 on random sparse graphs."""
    n = 20
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.05, 0.95, n)
    adj = topology.random_geometric(n, 0.35, seed=seed + 10)
    active = rng.random(n) < 0.7
    active[:2] = True
    dense = opt_alpha.optimize_masked(p, adj, active, sweeps=200, method=method)
    sparse = opt_alpha.optimize_sparse(
        p, adj, active, sweeps=200, method=method
    )
    np.testing.assert_allclose(sparse.todense(), dense.A, atol=1e-8)
    np.testing.assert_array_equal(
        sparse.feasible_columns, dense.feasible_columns
    )
    assert sparse.S_history[-1] == pytest.approx(dense.S_history[-1])


def test_optimize_sparse_full_membership_matches_dense():
    n = 16
    rng = np.random.default_rng(3)
    p = rng.uniform(0.05, 0.95, n)
    adj = topology.ring(n, 2)
    dense = opt_alpha.optimize(p, adj, sweeps=200)
    sparse = opt_alpha.optimize_sparse(p, adj, sweeps=200)
    np.testing.assert_allclose(sparse.todense(), dense.A, atol=1e-8)
    # unbiasedness holds on the sparse solution directly
    np.testing.assert_allclose(
        opt_alpha.unbiasedness_residual(p, sparse.todense()), 0.0, atol=1e-9
    )


def test_optimize_sparse_feasible_columns_false_for_inactive():
    """Satellite 2's contract on the sparse path: inactive and padded
    columns report infeasible, never the all-True init."""
    n = 10
    rng = np.random.default_rng(4)
    p = rng.uniform(0.1, 0.9, n)
    adj = topology.ring(n, 1)
    active = np.ones(n, bool)
    active[[3, 7]] = False
    res = opt_alpha.optimize_sparse(p, adj, active, sweeps=50)
    assert not res.feasible_columns[3] and not res.feasible_columns[7]
    assert res.feasible_columns[active].all()


def test_warm_start_vals_matches_dense_warm_start():
    """The CSC warm start is warm_start_weights on the shared structure."""
    n = 14
    rng = np.random.default_rng(5)
    p_old = rng.uniform(0.1, 0.9, n)
    p_new = np.clip(p_old + rng.normal(0, 0.1, n), 0.05, 0.95)
    adj = topology.random_geometric(n, 0.45, seed=6)
    g = topology.closed_csc(adj)
    prev = opt_alpha.optimize_sparse(p_old, graph=g, sweeps=100)
    vals = opt_alpha.warm_start_vals(p_new, g, prev.vals)
    A_dense = opt_alpha.warm_start_weights(p_new, adj, prev.todense())
    A_sparse = np.zeros((n, n))
    A_sparse[g.rows, g.cols] = vals
    np.testing.assert_allclose(A_sparse, A_dense, atol=1e-12)


def test_optimize_sparse_accepts_prebuilt_graph_and_seed():
    n = 12
    rng = np.random.default_rng(6)
    p = rng.uniform(0.1, 0.9, n)
    adj = topology.ring(n, 2)
    g = topology.closed_csc(adj)
    cold = opt_alpha.optimize_sparse(p, graph=g, sweeps=200)
    warm = opt_alpha.optimize_sparse(p, graph=g, sweeps=20, vals0=cold.vals)
    # seeding from the converged optimum keeps the objective (the argmin has
    # flat directions — row masses pin S, not individual entries — so the
    # matrix itself may slide; S and feasibility must not move)
    assert warm.S_history[-1] == pytest.approx(cold.S_history[-1], rel=1e-9)
    np.testing.assert_array_equal(warm.feasible_columns, cold.feasible_columns)
    np.testing.assert_allclose(
        opt_alpha.unbiasedness_residual(p, warm.todense()), 0.0, atol=1e-9
    )


# --------------------------------------------------- full simulator parity


def _quad_loss(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff**2, axis=-1))


def test_simulator_round_segment_matches_einsum():
    """A full round on relay_backend='segment' (EdgeRelay operand) matches
    the einsum reference fed the same matrix densely, under churn."""
    n, dim, T, b = 12, 5, 2, 3
    er, A, p, _, active, _ = _sparse_setting(9, n=n)
    rng = np.random.default_rng(10)
    batch = {"c": jnp.asarray(rng.standard_normal((n, T, b, dim)), jnp.float32)}
    params = {"x": jnp.ones((dim,))}
    outs = {}
    for be, operand in (("einsum", jnp.asarray(A)), ("segment", er)):
        sim = FLSimulator(
            _quad_loss, n_clients=n, strategy="colrel_fused", A=operand,
            p=p, local_steps=T, relay_backend=be,
        )
        outs[be] = sim.run_round(
            jax.random.key(0), params, sim.init_server_state(params),
            batch, 0.1, active=active,
        )
    (pe, _, me), (ps, _, ms) = outs["einsum"], outs["segment"]
    np.testing.assert_allclose(
        np.asarray(pe["x"]), np.asarray(ps["x"]), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(float(me["loss"]), float(ms["loss"]), rtol=1e-6)


def test_edge_relay_is_a_static_pytree_leaf_set():
    """EdgeRelay flows through jit as a pytree whose *structure* is fixed by
    the graph — swapping vals between rounds must not retrace."""
    er, A, *_ = _sparse_setting(11)
    calls = {"n": 0}

    @jax.jit
    def f(e, tau):
        calls["n"] += 1
        return relay_lib.fused_coefficients(e, tau)

    tau = jnp.ones((A.shape[0],), jnp.float32)
    f(er, tau)
    er2 = relay_lib.EdgeRelay(er.rows, er.cols, er.vals * 0.5)
    f(er2, tau)
    assert calls["n"] == 1
