"""The loop-aware HLO cost model (roofline input) on known-flops programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    L, B, D = 7, 8, 32

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    co = _compile(
        f,
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
    )
    res = hlo_cost.analyze(co.as_text())
    want = L * 2 * B * D * D
    assert abs(res["flops"] - want) / want < 0.05, (res["flops"], want)


def test_grad_of_scan_counts_three_dots_per_layer():
    L, B, D = 5, 4, 16

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    co = _compile(
        jax.grad(f),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
    )
    res = hlo_cost.analyze(co.as_text())
    want = L * 3 * 2 * B * D * D  # fwd + dx + dw
    assert abs(res["flops"] - want) / want < 0.10, (res["flops"], want)


def test_unlooped_dot_exact():
    def f(a, b):
        return (a @ b).sum()

    co = _compile(
        f,
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 16), jnp.float32),
    )
    res = hlo_cost.analyze(co.as_text())
    assert res["flops"] == 2 * 32 * 64 * 16


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    co = _compile(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    want = 4 * 3 * 2 * 8 * 8 * 8
    assert abs(res["flops"] - want) / want < 0.05, (res["flops"], want)


def test_collectives_counted_with_shapes():
    # single-device module has no collectives; the parser must return zero
    co = _compile(lambda x: x * 2, jax.ShapeDtypeStruct((8,), jnp.float32))
    res = hlo_cost.analyze(co.as_text())
    assert res["collectives"]["total"] == 0.0
