"""Benchmark subsystem: registry, harness, JSON schema, regression gate."""
import dataclasses
import json

import pytest

from repro.bench import harness, report as report_lib, scenarios

TINY = scenarios.ScenarioSpec(
    name="tiny_test",
    description="harness unit-test scenario",
    n_clients=4,
    rounds=8,
    local_steps=1,
    local_batch=4,
    dim=8,
    width=4,
    n_train=64,
    adj_every=4,
    p_every=4,
    drift_hold=1,
    chunk=4,
)


# -------------------------------------------------------------- registry


def test_registry_contains_the_shipped_scenarios():
    names = [s.name for s in scenarios.list_scenarios()]
    assert "bench_smoke" in names
    assert "fig5_500" in names
    assert "fig6_500" in names
    assert "corr_shadow_500" in names
    assert "corr_uplink_500" in names
    assert "mesh_corr_500" in names
    for name in names:
        assert scenarios.get_scenario(name).name == name
    corr = scenarios.get_scenario("corr_uplink_500")
    assert corr.fading == "corr_uplink" and corr.drift == "static"
    assert scenarios.get_scenario("mesh_corr_500").step == "mesh"


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError, match="unknown scenario"):
        scenarios.get_scenario("no_such_scenario")
    spec = scenarios.get_scenario("bench_smoke")
    with pytest.raises(ValueError, match="already registered"):
        scenarios.register(spec)


def test_acceptance_scenario_is_fig5_at_paper_scale():
    spec = scenarios.get_scenario("fig5_500")
    assert spec.rounds == 500
    assert spec.n_clients == 10
    assert spec.topology == "ring" and spec.fading == "markov"
    assert spec.policy == "adaptive"


# ---------------------------------------------------------------- harness


def test_harness_runs_all_engines_bitwise_identical():
    result = harness.run_scenario(TINY)
    runs = result["runs"]
    assert set(runs) == {"loop", "scan", "pipelined"}
    assert result["bitwise_match"] is True
    assert result["speedup"] > 0
    assert set(result["speedups"]) == {"scan", "pipelined"}
    for run in runs.values():
        assert run.wall_s > 0
        assert run.rounds_per_sec > 0
        assert run.final_loss == runs["loop"].final_loss  # same trajectory
    assert runs["loop"].trace_count == 1
    assert runs["scan"].trace_count <= 2
    assert runs["scan"].dispatches < runs["loop"].dispatches
    # the pipelined engine fuses τ into the chunk: same dispatch count as
    # scan, plus measured overlap stats (loop/scan report None there)
    assert runs["pipelined"].trace_count <= 2
    assert runs["pipelined"].dispatches == runs["scan"].dispatches
    assert 0.0 <= runs["pipelined"].overlap_fraction <= 1.0
    assert runs["pipelined"].host_prep_s > 0
    assert runs["loop"].overlap_fraction is None
    assert runs["scan"].overlap_fraction is None


TINY_CORR = dataclasses.replace(
    TINY,
    name="tiny_corr_test",
    fading="corr_uplink",
    drift="static",
    corr_length=0.5,
)


def test_harness_correlated_scenario_bitwise_identical():
    """Jointly-sampled (adj, p) through every engine: the fused paths must
    still reproduce the loop bit-for-bit."""
    result = harness.run_scenario(TINY_CORR)
    assert result["bitwise_match"] is True
    assert result["runs"]["loop"].trace_count == 1
    assert result["runs"]["scan"].trace_count <= 2
    assert result["runs"]["pipelined"].trace_count <= 2


def test_mesh_step_bitwise_and_trace_bound_under_correlated_schedule():
    """Satellite: the mesh round step (build_scan_round_step) benched under
    a correlated multi-epoch schedule — per-epoch scan dispatches, bitwise
    equal to the per-round mesh step, and trace_count ≤ 2 (fixed coherence
    time ⇒ fixed scan length; at most a shorter final remainder epoch)."""
    spec = dataclasses.replace(TINY_CORR, name="tiny_mesh_test", step="mesh")
    result = harness.run_scenario(spec)
    runs = result["runs"]
    assert result["bitwise_match"] is True
    assert runs["loop"].trace_count == 1
    assert runs["scan"].trace_count <= 2
    assert runs["scan"].dispatches == spec.rounds // spec.adj_every
    assert runs["loop"].dispatches == spec.rounds
    # the τ-fused mesh step: same per-epoch dispatch grid as scan, overlap
    # measured, and still bit-identical (checked above for all engines)
    assert runs["pipelined"].trace_count <= 2
    assert runs["pipelined"].dispatches == runs["scan"].dispatches
    assert 0.0 <= runs["pipelined"].overlap_fraction <= 1.0


# ---------------------------------------------------------- report + gate


def _engine_run(rps):
    return harness.EngineRun(
        engine="x",
        wall_s=TINY.rounds / rps,
        compile_s=0.5,
        rounds_per_sec=rps,
        trace_count=1,
        dispatches=8,
        final_loss=1.0,
    )


def _fake_result():
    return {
        "runs": {"loop": _engine_run(100.0), "scan": _engine_run(500.0)},
        "speedup": 5.0,
        "bitwise_match": True,
    }


def test_report_schema_and_roundtrip(tmp_path):
    rep = report_lib.make_report(TINY, _fake_result())
    assert rep["schema_version"] == report_lib.SCHEMA_VERSION
    assert rep["scenario"] == "tiny_test"
    # the spec lands verbatim, with tuples as JSON-round-trippable lists
    assert rep["spec"] == {
        k: list(v) if isinstance(v, tuple) else v
        for k, v in dataclasses.asdict(TINY).items()
    }
    assert rep["spec"]["engines"] == list(TINY.engines)
    assert set(rep["engines"]) == {"loop", "scan"}
    path = report_lib.write_report(rep, tmp_path)
    assert path.name == "BENCH_tiny_test.json"
    assert report_lib.load_report(path) == rep


def test_load_report_rejects_wrong_schema(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({"schema_version": 999, "scenario": "x"}))
    with pytest.raises(ValueError, match="schema_version"):
        report_lib.load_report(path)


def test_gate_passes_against_itself_and_catches_regressions():
    base = report_lib.make_report(TINY, _fake_result())
    assert report_lib.check_regression(base, base) == []

    # >2x rounds/sec regression on one engine
    slow = json.loads(json.dumps(base))
    slow["engines"]["scan"]["rounds_per_sec"] /= 3.0
    fails = report_lib.check_regression(slow, base, factor=2.0)
    assert any("scan" in f and "regressed" in f for f in fails)
    # within 2x: no failure
    ok = json.loads(json.dumps(base))
    ok["engines"]["scan"]["rounds_per_sec"] /= 1.5
    assert report_lib.check_regression(ok, base, factor=2.0) == []

    # retracing engine
    traced = json.loads(json.dumps(base))
    traced["engines"]["scan"]["trace_count"] = 7
    assert any("trace_count" in f for f in report_lib.check_regression(traced, base))

    # lost bit-identity
    diverged = json.loads(json.dumps(base))
    diverged["bitwise_match"] = False
    assert any(
        "bit-identical" in f for f in report_lib.check_regression(diverged, base)
    )

    # collapsed speedup
    flat = json.loads(json.dumps(base))
    flat["speedup_rounds_per_sec"] = 1.0
    assert any("speedup" in f for f in report_lib.check_regression(flat, base))

    # mismatched scenario
    other = json.loads(json.dumps(base))
    other["scenario"] = "something_else"
    assert any("mismatch" in f for f in report_lib.check_regression(other, base))


def test_cli_list_and_tiny_run(tmp_path, capsys):
    from repro.bench import run as run_cli

    assert run_cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "bench_smoke" in out and "fig5_500" in out


def test_sample_sweep_scenarios_registered():
    for name, n in [("sample_sweep_smoke", 256), ("sample_sweep_n1e3", 1_000),
                    ("sample_sweep_n1e4", 10_000)]:
        spec = scenarios.get_scenario(name)
        assert spec.n_clients == n
        assert spec.relay_backend == "segment" and spec.policy == "sparse"
        assert spec.topology == "geometric" and spec.sampling == "fixed_k"
    smoke = scenarios.get_scenario("sample_sweep_smoke")
    assert smoke.check_backend == "einsum"  # parity gate built in


def test_spec_validation_for_sampling_and_segment():
    base = scenarios.get_scenario("sample_sweep_smoke")
    with pytest.raises(ValueError, match="sim path only"):
        dataclasses.replace(base, step="mesh")  # sampling check fires first
    with pytest.raises(ValueError, match="single-host"):
        dataclasses.replace(base, sampling="none", step="mesh")
    with pytest.raises(ValueError, match="sparse"):
        dataclasses.replace(base, policy="adaptive")
    with pytest.raises(ValueError, match="sampling"):
        dataclasses.replace(base, sampling="importance")
    with pytest.raises(ValueError, match="sample_k"):
        dataclasses.replace(base, sample_k=0)
    with pytest.raises(ValueError, match="geo_degree"):
        dataclasses.replace(base, geo_degree=0.0)
    # sampling requires the sim step path (mask handoff lives there)
    dense = scenarios.get_scenario("bench_smoke")
    with pytest.raises(ValueError, match="sim"):
        dataclasses.replace(dense, sampling="uniform", sample_rate=0.5,
                            step="mesh")


def test_sample_sweep_smoke_bundle_builds_sparse_stack():
    from repro import channels
    from repro.core import relay as relay_lib

    spec = dataclasses.replace(
        scenarios.get_scenario("sample_sweep_smoke"),
        n_clients=32, n_train=64, rounds=3, sample_k=8,
    )
    bundle = scenarios.build(spec)
    adj = bundle.base_adjacency()
    assert adj.shape == (32, 32)
    assert bundle.base_adjacency() is adj  # memoized, built once
    sched = bundle.make_schedule()
    pol = bundle.make_policy()
    assert isinstance(pol, channels.SparseOptAlpha)
    states = list(sched.rounds(3))
    assert all(s.active is not None and s.n_active <= 8 for s in states)
    A = pol.relay_matrix(states[0])
    assert isinstance(A, relay_lib.EdgeRelay)
