"""End-to-end coverage of the continuous-training service: the
:class:`~repro.launch.train.ContinuousTrainer` burst driver, checkpoint
publication, resume, and the :class:`~repro.launch.serve.SnapshotEvalLoop`
live-eval side — all at reduced config on CPU, fast-suite sized.

The key contracts:

* bursting through the trainer is the *same trajectory* as one uninterrupted
  engine call (the stream objects are shared and advance only when rounds
  run) — checked bitwise against ``run_rounds_loop``;
* ``restore_latest`` + ``advance_stream`` resumes a crashed run bitwise
  (sync engines);
* the serve loop sees exactly the snapshots the trainer publishes, in
  order, and scores them with the caller's eval function.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels, checkpoint
from repro.channels.delay import GeometricDelays
from repro.core import topology
from repro.core.aggregation import ServerOpt
from repro.fl.engine import run_rounds_loop
from repro.fl.simulator import FLSimulator
from repro.launch.serve import SnapshotEvalLoop
from repro.launch.train import ContinuousTrainer, build_connectivity, build_topology

N = 6
DIM = 4


def _loss_fn(params, batch):
    diff = params["x"][None, :] - batch["c"]
    return 0.5 * jnp.mean(jnp.sum(diff ** 2, axis=-1))


def _stream(seed=42):
    rng = np.random.default_rng(seed)

    def next_batch():
        return {"c": rng.standard_normal((N, 2, 4, DIM)).astype(np.float32)}

    return next_batch


def _schedule():
    return channels.StaticChannel(topology.ring(N, 2), np.full(N, 0.8))


def _sim(momentum=0.9):
    return FLSimulator(
        _loss_fn, n_clients=N, strategy="fedavg_blind",
        server_opt=ServerOpt(momentum=momentum))


def _trainer(sim, **kw):
    kw.setdefault("schedule", _schedule())
    kw.setdefault("next_batch", _stream())
    kw.setdefault("lr", 0.1)
    return ContinuousTrainer(sim, **kw)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _params0():
    return {"x": jnp.ones((DIM,))}


def test_trainer_bursts_match_one_uninterrupted_run(tmp_path):
    """15 rounds in publish-sized bursts of 5 ≡ one 15-round loop call,
    bitwise — and each burst published a snapshot."""
    sim = _sim()
    ref_p, ref_ss, ref_metrics, ref_key = run_rounds_loop(
        sim, jax.random.key(1), _params0(), sim.init_server_state(_params0()),
        schedule=_schedule(), rounds=15, next_batch=_stream(), lr=0.1)

    d = str(tmp_path / "ckpts")
    published = []
    trainer = _trainer(_sim(), ckpt_dir=d, publish_every=5, keep=0)
    trainer.init(_params0(), jax.random.key(1))
    metrics = trainer.run(15, on_publish=lambda p, r: published.append((p, r)))

    assert trainer.round == 15
    assert _tree_equal(ref_p, trainer.params)
    assert _tree_equal(ref_ss, trainer.server_state)
    assert _tree_equal(ref_metrics, metrics)
    assert np.array_equal(
        jax.random.key_data(ref_key), jax.random.key_data(trainer.key))
    assert [r for _, r in published] == [5, 10, 15]
    assert checkpoint.latest_checkpoint(d).endswith("ckpt_00000015.npz")
    meta = checkpoint.load_metadata(checkpoint.latest_checkpoint(d))
    assert meta["round"] == 15 and meta["engine"] == "loop"


def test_trainer_restore_latest_resumes_bitwise(tmp_path):
    ref = _trainer(_sim())
    ref.init(_params0(), jax.random.key(1))
    ref.run(18)

    d = str(tmp_path / "ckpts")
    first = _trainer(_sim(), ckpt_dir=d, publish_every=6)
    first.init(_params0(), jax.random.key(1))
    first.run(12)  # "crash" after the round-12 snapshot

    resumed = _trainer(_sim(), ckpt_dir=d, publish_every=6)
    resumed.init(_params0(), jax.random.key(1))
    assert resumed.restore_latest()
    assert resumed.round == 12
    resumed.advance_stream()  # fast-forward the fresh schedule/batch stream
    resumed.run(6)

    assert resumed.round == 18
    assert _tree_equal(ref.params, resumed.params)
    assert _tree_equal(ref.server_state, resumed.server_state)
    assert np.array_equal(
        jax.random.key_data(ref.key), jax.random.key_data(resumed.key))


def test_trainer_restore_latest_edge_cases(tmp_path):
    t = _trainer(_sim())
    with pytest.raises(RuntimeError, match="init"):
        t.restore_latest()
    with pytest.raises(RuntimeError, match="init"):
        t.run(1)
    t.init(_params0(), jax.random.key(0))
    assert not t.restore_latest()  # no ckpt_dir configured
    t2 = _trainer(_sim(), ckpt_dir=str(tmp_path / "empty"))
    t2.init(_params0(), jax.random.key(0))
    assert not t2.restore_latest()  # dir has no snapshot
    with pytest.raises(ValueError, match="unknown engine"):
        _trainer(_sim(), engine="warp")


def test_trainer_async_engine_streams_across_bursts(tmp_path):
    """The async engine keeps its arrival buffer across bursts (reset only
    on the first) — bursting equals one uninterrupted run_schedule call."""
    delays = GeometricDelays(N, mean=1.0, max_delay=4, seed=5)
    one = _trainer(_sim(momentum=0.0), engine="async", delays=delays,
                   staleness_decay=0.7)
    one.init(_params0(), jax.random.key(1))
    m_one = one.run(12)

    delays2 = GeometricDelays(N, mean=1.0, max_delay=4, seed=5)
    burst = _trainer(_sim(momentum=0.0), engine="async", delays=delays2,
                     staleness_decay=0.7, ckpt_dir=str(tmp_path / "c"),
                     publish_every=4)
    burst.init(_params0(), jax.random.key(1))
    m_burst = burst.run(12)

    assert m_one["loss"].shape == (12,)
    assert _tree_equal(one.params, burst.params)
    assert _tree_equal(m_one, m_burst)
    assert checkpoint.latest_checkpoint(str(tmp_path / "c")) is not None


def test_trainer_stop_callback_halts_between_bursts():
    t = _trainer(_sim(), publish_every=3)
    t.init(_params0(), jax.random.key(0))
    calls = []

    def stop():
        calls.append(len(calls))
        return len(calls) >= 2  # allow two bursts, then halt

    metrics = t.run(30, stop=stop)
    assert t.round == 6
    assert metrics["loss"].shape == (6,)


@pytest.mark.parametrize("engine", ["scan", "pipelined"])
def test_trainer_scan_engines_run_and_publish(engine, tmp_path):
    d = str(tmp_path / "ckpts")
    t = _trainer(_sim(), engine=engine, chunk=4, ckpt_dir=d)
    t.init(_params0(), jax.random.key(1))
    metrics = t.run(8)  # publish_every=0 → one final snapshot
    assert metrics["loss"].shape == (8,)
    latest = checkpoint.latest_checkpoint(d)
    assert latest is not None and latest.endswith("ckpt_00000008.npz")
    assert checkpoint.load_metadata(latest)["engine"] == engine


def test_snapshot_eval_loop_follows_published_snapshots(tmp_path):
    """The live-eval side: a trainer publishing into a directory, a
    SnapshotEvalLoop polling it — every new snapshot is reloaded and scored,
    an unchanged pointer is a no-op, and the watch() history tracks the
    published rounds in order."""
    d = str(tmp_path / "ckpts")
    trainer = _trainer(_sim(), ckpt_dir=d, publish_every=4)
    trainer.init(_params0(), jax.random.key(1))

    eval_batch = {"c": np.zeros((N, 2, 4, DIM), np.float32)}
    loop = SnapshotEvalLoop(
        d, params_like=_params0(), eval_fn=jax.jit(_loss_fn))

    with pytest.raises(RuntimeError, match="poll"):
        loop.eval_batch(eval_batch)
    assert not loop.poll()  # nothing published yet

    trainer.run(4)
    assert loop.poll() and loop.round == 4
    assert not loop.poll()  # pointer unchanged → no reload
    direct = float(_loss_fn(trainer.params, eval_batch))
    assert loop.eval_batch(eval_batch) == direct

    # watch(): train between polls via the injectable sleep
    def sleep(_interval):
        trainer.run(4)

    history = loop.watch(eval_batch, max_polls=3, interval=0.0, sleep=sleep)
    assert [rnd for rnd, _ in history] == [8, 12]
    assert all(np.isfinite(loss) for _, loss in history)
    # training reduces the quadratic eval loss round over round
    assert history[-1][1] < direct


def test_snapshot_eval_loop_requires_eval_fn(tmp_path):
    d = str(tmp_path / "ckpts")
    checkpoint.publish(d, params=_params0(), server_state=None,
                       key=jax.random.key(0), round=1)
    loop = SnapshotEvalLoop(d, params_like=_params0())
    assert loop.poll()
    with pytest.raises(RuntimeError, match="eval_fn"):
        loop.eval_batch({"c": np.zeros((N, 2, 4, DIM), np.float32)})


def test_build_topology_and_connectivity_helpers():
    assert build_topology("ring", 8, 2).sum() == 8 * 4
    assert build_topology("fct", 5, 1).sum() == 5 * 4
    assert build_topology("disconnected", 4, 1).sum() == 0
    assert build_topology("clusters", 8, 1).shape == (8, 8)
    with pytest.raises(ValueError):
        build_topology("moebius", 4, 1)
    assert np.allclose(build_connectivity("homogeneous", 6, 0.3).p, 0.3)
    assert build_connectivity("paper", 10, 0.2).p.shape == (10,)
    assert build_connectivity("heterogeneous", 7, 0.2).p.shape == (7,)


def test_trainer_run_zero_rounds_returns_empty():
    t = _trainer(_sim())
    t.init(_params0(), jax.random.key(0))
    assert t.run(0) == {}
